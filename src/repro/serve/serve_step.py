"""Serving steps: batched prefill and single-token decode with KV/SSM caches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import forward_prefill, forward_decode


def make_prefill_step(cfg, compute_dtype=jnp.bfloat16):
    def prefill(params, batch):
        return forward_prefill(cfg, params, batch, compute_dtype)
    return prefill


def make_decode_step(cfg, compute_dtype=jnp.bfloat16):
    """decode(params, cache, token (B,1), pos scalar) -> (logits (B,1,V), cache)."""
    def decode(params, cache, token, pos):
        return forward_decode(cfg, params, cache, token, pos, compute_dtype)
    return decode


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
