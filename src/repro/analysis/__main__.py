"""Render the roofline tables from dry-run records:

    PYTHONPATH=src python -m repro.analysis [--mesh single|multi] [--tag opt]
"""
import argparse

from .report import load_records, roofline_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load_records(args.mesh, args.tag)
    print(f"## Roofline — mesh={args.mesh} tag={args.tag or 'baseline'} "
          f"({len(recs)} cells)\n")
    print(roofline_table_with_tag(args.mesh, args.tag))


def roofline_table_with_tag(mesh, tag):
    rows = ["| arch | shape | bound | compute s | memory s | collective s | "
            "useful FLOP ratio | HBM/chip GB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load_records(mesh, tag):
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — | — | — |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — | — |")
            continue
        t = r["roofline"]
        ur = r.get("useful_flop_ratio")
        urs = f"{ur:.3f}" if ur else "—"
        rows.append(
            f"| {r['arch']} | {r['shape']} | **{t['bound']}** | "
            f"{t['compute_s']:.4f} | {t['memory_s']:.4f} | "
            f"{t['collective_s']:.4f} | {urs} | {r['hbm_per_chip_gb']} |")
    return "\n".join(rows)


if __name__ == "__main__":
    main()
