"""Render the EXPERIMENTS.md roofline table from experiments/dryrun/*.json,
and per-cell collective breakdowns for the §Perf hillclimb."""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Dict, List

from .hlo_parse import HloCosts, split_computations, _WHILE_RE, _trip_count, _SHAPE_RE, _shape_bytes

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load_records(mesh: str = "single", tag: str = "") -> List[dict]:
    recs = []
    suffix = f"_{mesh}" + (f"_{tag}" if tag else "") + ".json"
    for p in sorted(DRYRUN_DIR.glob(f"*{suffix}")):
        if tag == "" and re.search(r"_(single|multi)_[^.]+\.json$", p.name):
            continue                      # skip tagged variants
        recs.append(json.loads(p.read_text()))
    return recs


def roofline_table(mesh: str = "single") -> str:
    rows = ["| arch | shape | bound | compute s | memory s | collective s | "
            "useful FLOP ratio | HBM/chip GB | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load_records(mesh):
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — |"
                        f" skipped: {r['skipped'][:60]}… |")
            continue
        t = r["roofline"]
        ur = r.get("useful_flop_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | **{t['bound']}** | "
            f"{t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{ur:.3f} | {r['hbm_per_chip_gb']} | |" if ur else
            f"| {r['arch']} | {r['shape']} | **{t['bound']}** | "
            f"{t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"— | {r['hbm_per_chip_gb']} | |")
    return "\n".join(rows)


def collective_breakdown(hlo: str, top: int = 20) -> List[dict]:
    """Every collective instruction with its loop-multiplied byte cost."""
    comps = split_computations(hlo)
    # computation -> multiplier (product of enclosing loop trip counts)
    mult: Dict[str, float] = {}
    entry = next((n for n in comps if n == "main" or n.startswith("main.")),
                 next(iter(comps), None))

    def walk(name: str, m: float, seen):
        if name in seen:
            return
        seen = seen | {name}
        mult[name] = mult.get(name, 0.0) + m
        for line in comps.get(name, []):
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                trips = _trip_count(comps.get(cond, []))
                walk(body, m * trips, seen)
                continue
            for callee in re.findall(
                    r"(?:calls|to_apply|body|condition|branch_computations)=%?([\w.\-]+)",
                    line):
                if callee in comps:
                    walk(callee, m, seen)

    if entry:
        walk(entry, 1.0, frozenset())
    out = []
    for name, m in mult.items():
        for line in comps.get(name, []):
            ls = line.strip()
            if "=" not in ls:
                continue
            rhs = ls.split("=", 1)[1]
            mm = re.match(r"\s*(\([^)]*\)|[\w\[\]{},]+)\s+([\w\-]+)", rhs)
            if not mm:
                continue
            op = mm.group(2)
            base = None
            for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                      "collective-permute"):
                if op == k or op == k + "-start":
                    base = k
            if base is None:
                continue
            nbytes = sum(_shape_bytes(dt, d) for dt, d in _SHAPE_RE.findall(mm.group(1)))
            meta = re.search(r'op_name="([^"]+)"', ls)
            out.append({"op": base, "bytes": nbytes, "mult": m,
                        "total": nbytes * m, "comp": name,
                        "shape": mm.group(1)[:60],
                        "src": (meta.group(1)[-90:] if meta else "")})
    out.sort(key=lambda d: -d["total"])
    return out[:top]
