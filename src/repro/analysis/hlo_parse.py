"""While-loop-aware HLO accounting.

``compiled.cost_analysis()`` and naive text scans count a while-loop body
ONCE, but a scanned 88-layer model executes it 88 times. This module parses
the post-optimization HLO text into computations, finds ``while`` ops, infers
trip counts from the loop condition's comparison constant, and rolls up
collective bytes (and dot FLOPs) with loop multiplication — recursively, so
the q-chunk scan inside the layer scan is handled.

Heuristics (documented in EXPERIMENTS.md §Roofline methodology):
  * trip count = the integer constant compared against the induction variable
    in the condition computation (max constant if several);
  * all-reduce is weighted 2x in the wire-byte summary (ring = RS + AG);
  * dot FLOPs are 2 * prod(output dims) * contraction size, computed from the
    dot's operand/result shapes — batch/contracting dims read from the
    ``dot(...)`` attributes.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(result_part: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(result_part))


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        # computation headers start at column 0 and end with '{'
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            hdr = line.strip()
            if hdr.startswith("ENTRY"):
                hdr = hdr[len("ENTRY"):].strip()
            name = re.split(r"[\s(]", hdr.lstrip("%"), maxsplit=1)[0]
            if name and name != "{":
                cur = name
                comps[cur] = []
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


_WHILE_RE = re.compile(
    r"while\((?:[^)]*)\)[^,]*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=%?([\w.\-]+)")


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the loop condition — scan conditions
    compare the induction variable against the trip count."""
    best = 1
    for line in cond_lines:
        if "constant(" not in line:
            continue
        for c in re.findall(r"constant\((\d+)\)", line):
            best = max(best, int(c))
    return best


def _dot_flops(line: str) -> float:
    """2 * (prod result dims) * contraction_size for a dot instruction."""
    m = re.match(r"\s*%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},]+)\s+dot\(", line)
    if not m:
        return 0.0
    shapes = _SHAPE_RE.findall(m.group(1))
    if not shapes:
        return 0.0
    out_elems = 1
    for d in (shapes[0][1].split(",") if shapes[0][1] else []):
        out_elems *= int(d)
    # contraction size: lhs dims at lhs_contracting_dims
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ml = re.search(r"dot\(\s*%?[\w.\-]+\s*,", line)
    csize = 1
    if mc:
        # find lhs operand shape: first operand's shape appears in operand list
        mo = re.search(r"dot\(([^)]*)\)", line)
        # operand shapes are not inline in post-opt HLO; fall back to
        # f(result, contracting from attributes is unavailable) — use the
        # conservative result-only estimate with contraction guessed below.
        pass
    # Without operand shapes inline we cannot recover contraction size from a
    # single line; callers preferring exact numbers should use unrolled runs.
    return 2.0 * out_elems * csize


class HloCosts:
    """Roll-up of collective bytes with loop multiplication."""

    def __init__(self, hlo: str):
        self.comps = split_computations(hlo)
        self._memo: Dict[str, Dict[str, float]] = {}
        entry = None
        for name in self.comps:
            if name == "main" or name.startswith("main."):
                entry = name
        self.entry = entry or (next(iter(self.comps)) if self.comps else None)

    def _line_callees(self, line: str) -> List[Tuple[str, float]]:
        """(callee, multiplier) pairs for one instruction line."""
        out: List[Tuple[str, float]] = []
        mw = _WHILE_RE.search(line)
        if mw:
            cond, body = mw.group(1), mw.group(2)
            trips = _trip_count(self.comps.get(cond, []))
            out.append((body, float(trips)))
            out.append((cond, float(trips)))
            return out
        for callee in _CALL_RE.findall(line):
            if callee in self.comps:
                out.append((callee, 1.0))
        return out

    def comp_coll_bytes(self, name: str) -> Dict[str, float]:
        if name in self._memo:
            return self._memo[name]
        totals = {k: 0.0 for k in _COLL_OPS}
        totals["_f32"] = 0.0          # f32 share (CPU-backend dot promotion)
        self._memo[name] = totals  # break cycles
        for line in self.comps.get(name, []):
            ls = line.strip()
            if "=" in ls:
                rhs = ls.split("=", 1)[1]
                m = re.match(r"\s*(\([^)]*\)|[\w\[\]{},]+)\s+([\w\-]+)", rhs)
                if m:
                    op = m.group(2)
                    for k in _COLL_OPS:
                        if op == k or op == k + "-start":
                            nb = _result_bytes(m.group(1))
                            totals[k] += nb
                            f32b = sum(_shape_bytes(dt, d) for dt, d in
                                       _SHAPE_RE.findall(m.group(1)) if dt == "f32")
                            totals["_f32"] += f32b * (2 if k == "all-reduce" else 1)
                            break
            for callee, mult in self._line_callees(ls):
                sub = self.comp_coll_bytes(callee)
                for k in totals:
                    totals[k] += mult * sub.get(k, 0.0)
        self._memo[name] = totals
        return totals

    def collective_bytes(self) -> Dict[str, object]:
        if self.entry is None:
            return {"per_op": {}, "raw_bytes": 0, "weighted_bytes": 0,
                    "tpu_bf16_adjusted_bytes": 0}
        per_op = self.comp_coll_bytes(self.entry)
        f32w = per_op.pop("_f32", 0.0)
        raw = sum(per_op.values())
        weighted = sum(v * (2 if k == "all-reduce" else 1) for k, v in per_op.items())
        # On TPU, bf16 dot operands/outputs move over ICI in bf16; the CPU
        # backend promotes them to f32 before SPMD partitioning, doubling the
        # measured bytes. Adjusted = halve the f32 share (methodology in
        # EXPERIMENTS.md §Roofline).
        adjusted = weighted - f32w / 2
        return {"per_op": {k: int(v) for k, v in per_op.items()},
                "raw_bytes": int(raw), "weighted_bytes": int(weighted),
                "f32_weighted_bytes": int(f32w),
                "tpu_bf16_adjusted_bytes": int(adjusted)}


def loop_trip_summary(hlo: str) -> List[Tuple[str, int]]:
    """(body computation, trip count) for every while in the module."""
    comps = split_computations(hlo)
    out = []
    for name, lines in comps.items():
        for line in lines:
            mw = _WHILE_RE.search(line)
            if mw:
                out.append((mw.group(2), _trip_count(comps.get(mw.group(1), []))))
    return out
