"""Analytic per-step FLOP and HBM-traffic model, per (arch x shape).

Why analytic: XLA's ``cost_analysis()`` counts a while-loop body once, so a
scanned L-layer model under-reports by ~L x. We compute FLOPs from the model
definition (this repo's own code, so the count is exact for the implemented
algorithm — including its inefficiencies, e.g. full masked S^2 attention and
MoE capacity overcount), and validate against fully-unrolled compiles for
spot-check cells (EXPERIMENTS.md §Roofline).

Backward multipliers:
  matmul fwd F  ->  train total 4F   (bwd 2F + remat re-forward 1F)
  attention fwd -> train total 5F    (extra inner recompute: checkpointed
                                      _attend_block recomputes scores in bwd)
"""
from __future__ import annotations

from typing import Dict

from ..configs import ModelConfig, ShapeConfig


def _attn_flops(B, S, Sk, H, dh):
    """Full (unskipped) masked attention as implemented: QK^T + PV."""
    return 4.0 * B * H * S * Sk * dh


def _dense_layer_matmul_params(cfg) -> float:
    D, F = cfg.d_model, cfg.d_ff
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    attn = D * H * dh + 2 * D * KV * dh + H * dh * D
    kmlp = 3 if cfg.mlp == "swiglu" else 2
    return attn + kmlp * D * F


def step_flops(cfg: ModelConfig, shape: ShapeConfig, kind: str) -> Dict[str, float]:
    """Global (all-chip) FLOPs for one step of the implemented algorithm."""
    B = shape.global_batch
    S = shape.seq_len if kind in ("train", "prefill") else 1
    Sk = shape.seq_len                      # decode attends against the cache
    T = B * S                               # tokens processed
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    mm = 0.0      # matmul (param) flops, fwd
    at = 0.0      # attention score/value flops, fwd
    if cfg.rwkv is not None:
        r = cfg.rwkv
        proj = 5 * D * H * r.head_size + D * r.decay_lora + r.decay_lora * H * r.head_size
        cmix = D * cfg.d_ff + cfg.d_ff * D + D * D
        mm += L * T * 2 * (proj + cmix)
        # chunked wkv: decay (T'^2 dh) + scores + out + state terms per chunk
        C = r.chunk
        nc = max(S // C, 1) if S > 1 else 0
        if S > 1:
            at += L * B * cfg.n_heads * nc * (4 * C * C * r.head_size   # scores+out
                                              + 4 * C * r.head_size * r.head_size)
        else:
            at += L * B * cfg.n_heads * 4 * r.head_size * r.head_size
    elif cfg.family == "hybrid":
        s = cfg.ssm
        di = s.expand * D
        Hm = di // s.d_head
        mm += L * T * 2 * (D * (2 * di + 2 * s.d_state + Hm) + di * D)
        C = s.chunk
        nc = max(S // C, 1)
        if S > 1:
            at += L * B * (2 * C * C * s.d_state * nc            # CB
                           + 2 * Hm * C * C * s.d_head * nc      # intra y
                           + 4 * Hm * C * s.d_head * s.d_state * nc)  # state+inter
        else:
            at += L * B * Hm * 4 * s.d_head * s.d_state
        # shared attention block every Nth layer
        n_sh = L // cfg.shared_attn_every
        mm += n_sh * T * 2 * _dense_layer_matmul_params(cfg)
        at += n_sh * _attn_flops(B, S, Sk, H, dh)
    else:
        per_layer = D * H * dh + 2 * D * KV * dh + H * dh * D
        if cfg.moe is not None:
            m = cfg.moe
            # capacity-buffer expert matmuls (compute includes unfilled slots)
            cap_tokens = T * m.top_k * m.capacity_factor if S > 1 else B * m.n_experts
            mm += L * (T * 2 * per_layer + T * 2 * D * m.n_experts
                       + cap_tokens * 2 * 3 * D * m.d_ff_expert)
        else:
            mm += L * T * 2 * _dense_layer_matmul_params(cfg)
        win = cfg.sliding_window
        Sk_eff = min(Sk, win) if win else Sk
        S_eff = S if S > 1 else 1
        at += L * _attn_flops(B, S_eff, Sk_eff if S == 1 else min(S, Sk), H, dh)
        if cfg.encoder is not None and kind in ("train", "prefill"):
            Le, Se = cfg.encoder.n_layers, cfg.encoder.enc_seq
            mm += Le * B * Se * 2 * _dense_layer_matmul_params(cfg)
            at += Le * _attn_flops(B, Se, Se, H, dh)
            # decoder cross-attention
            mm += L * T * 2 * (D * H * dh + 2 * D * KV * dh + H * dh * D)
            at += L * _attn_flops(B, S, Se, H, dh)
        if cfg.vlm is not None:
            pass  # patch embeds are inputs; token count already covers S

    head = T * 2 * D * V                   # lm head
    loss = T * 5 * V if kind == "train" else 0.0

    if kind == "train":
        total = 4 * mm + 5 * at + 3 * head + loss   # head: fwd+bwd, no remat
    else:
        total = mm + at + head
    return {"matmul_fwd": mm, "attention_fwd": at, "head_fwd": head,
            "total": total}


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, kind: str,
                   n_chips: int, tp: int = 16) -> float:
    """Per-chip HBM traffic estimate (bytes) for one step.

    Weight reads shard only by TP (each chip reads its 1/tp slice per matmul,
    regardless of FSDP, which gathers over ICI not HBM); activations,
    optimizer state and caches shard by all chips.

    train:   3x weight reads (fwd, remat, bwd) in bf16 + fp32 grads + 3x fp32
             optimizer state r/w + saved activations r/w
    prefill: 1x bf16 weights + cache write
    decode:  1x bf16 weights + full cache read + cache write
    """
    P = cfg.n_params()
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    if kind == "train":
        T = B * S
        weights = (3 * P * 2 + P * 4) / tp      # bf16 reads + fp32 grad write
        opt = 3 * P * 4 * 2 / n_chips           # m, v read+write; params rw
        acts = 2 * L * T * D * 2 * 2 / n_chips  # saved stack write+read (bf16)
        return weights + opt + acts
    if kind == "prefill":
        cache = 2 * L * B * S * cfg.n_kv_heads * cfg.d_head * 2
        return P * 2 / tp + (cache + B * S * D * 2 * L) / n_chips
    if True:  # decode
        if cfg.rwkv is not None:
            st = L * B * cfg.n_heads * cfg.rwkv.head_size ** 2 * 4
            cache_rw = 2 * st
        elif cfg.family == "hybrid":
            s = cfg.ssm
            di = s.expand * D
            st = L * B * (di // s.d_head) * s.d_head * s.d_state * 4
            n_sh = L // cfg.shared_attn_every
            Smax = S
            kv = 2 * n_sh * B * Smax * cfg.n_kv_heads * cfg.d_head * 2
            cache_rw = 2 * st + kv
        else:
            win = cfg.sliding_window
            Smax = min(S, win) if win else S
            kv = 2 * L * B * Smax * cfg.n_kv_heads * cfg.d_head * 2
            cache_rw = kv  # read whole cache, write one slot
        return P * 2 / tp + cache_rw / n_chips
