from .roofline import (HW, collective_bytes_from_hlo, roofline_terms,
                       summarize_memory, model_flops)

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms",
           "summarize_memory", "model_flops"]
