"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three per-chip terms (seconds), per EXPERIMENTS.md §Roofline:
    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``cost_analysis()`` reports the per-device (post-SPMD) module, so flops/bytes
are already per-chip. Collective bytes are parsed from the post-optimization
HLO text: we sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, weighting all-reduce 2x
(ring = reduce-scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-like hardware constants (assignment-specified)
@dataclasses.dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link


HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
# shapes like bf16[8,512,256]{2,1,0} or f32[] — capture dtype + dims
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind byte totals from post-SPMD HLO (per-device shapes)."""
    totals: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    counts: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        m = re.match(r"\s*(\([^)]*\)|[\w\[\]{},]+)\s+([\w-]+)", rhs)
        if not m:
            continue
        op = m.group(2)
        # match e.g. 'all-gather', 'all-reduce-start', 'all-gather-done'
        base = None
        for k in _COLL_OPS:
            if op == k or op == k + "-start":
                base = k
                break
        if base is None:
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        totals[base] += nbytes
        counts[base] += 1
    weighted = sum(v * (2 if k == "all-reduce" else 1) for k, v in totals.items())
    return {"per_op": totals, "counts": counts,
            "raw_bytes": sum(totals.values()), "weighted_bytes": weighted}


def model_flops(cfg, shape, kind: str) -> float:
    """Useful model FLOPs for the whole step (all chips):
    6·N·tokens (train), 2·N·tokens (prefill/decode); MoE uses active params."""
    n = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if kind in ("train", "prefill") else 1)
    mult = 6 if kind == "train" else 2
    return float(mult) * n * tokens


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float, hw: _HW = HW) -> Dict[str, float]:
    t_c = flops_per_chip / hw.peak_flops
    t_m = bytes_per_chip / hw.hbm_bw
    t_x = coll_bytes_per_chip / hw.link_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bound": dom, "step_s_lower_bound": max(t_c, t_m, t_x)}


def summarize_memory(mem) -> Dict[str, int]:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        out["peak_est_bytes"] = (out["argument_size_in_bytes"]
                                 + out["temp_size_in_bytes"]
                                 + out.get("output_size_in_bytes", 0)
                                 - out.get("alias_size_in_bytes", 0))
    return out
