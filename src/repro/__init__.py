"""repro: scalable, reproducible, cost-effective large-scale processing — in JAX.

A multi-pod training/inference framework whose data/orchestration substrate
implements Kim et al. 2024 (BIDS-style manifests, automated work queries,
content-addressed pipelines, checksummed tiered storage, provenance, cost
modeling) and whose compute plane supports 10 published architectures on a
512-chip production mesh. See DESIGN.md.
"""

__version__ = "1.0.0"
