"""Training step: mixed-precision forward/backward + AdamW, pjit-ready."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import forward_train
from .optimizer import OptConfig, adamw_init, adamw_update


def make_train_step(cfg, opt: OptConfig, compute_dtype=jnp.bfloat16, remat=True,
                    accum_steps: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    Params stay fp32 (master); compute runs in ``compute_dtype``.
    ``accum_steps`` > 1 splits the global batch into microbatches and
    accumulates gradients in a scan — the saved-activation stack (the peak
    memory term for deep models) shrinks by the same factor (§Perf G3).
    """

    def loss_and_grads(params, batch):
        def loss_fn(p):
            # pre-cast fp32 master weights to bf16 ONCE — FSDP all-gathers then
            # move bf16 (half the wire bytes) instead of gathering fp32 and
            # casting after (EXPERIMENTS.md §Perf P4a). With accumulation, the
            # cast copy is additionally constrained TP-only (FSDP axis
            # gathered) so the gather hoists out of the microbatch scan (G3b).
            pc = jax.tree.map(
                lambda w: w.astype(compute_dtype)
                if w.dtype == jnp.float32 and w.ndim > 1 else w, p)
            if accum_steps > 1:
                from ..dist.sharding import constrain_params_gathered
                pc = constrain_params_gathered(pc)
            return forward_train(cfg, pc, batch, compute_dtype, remat=remat)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(params, opt_state, batch):
        if accum_steps <= 1:
            (loss, metrics), grads = loss_and_grads(params, batch)
        else:
            mbs = jax.tree.map(
                lambda a: a.reshape((accum_steps, a.shape[0] // accum_steps)
                                    + a.shape[1:]), batch)

            def micro(carry, mb):
                g_acc, m_acc = carry
                (loss, m), g = loss_and_grads(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"loss": jnp.float32(0), "acc": jnp.float32(0),
                       "tokens": jnp.float32(0)}
            if cfg.moe is not None:
                zeros_m["aux_loss"] = jnp.float32(0)
            (grads, msum), _ = jax.lax.scan(micro, (zeros_g, zeros_m), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = {k: v / accum_steps for k, v in msum.items()}
            metrics["tokens"] = msum["tokens"]
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params, opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return step


def init_train_state(cfg, key, dtype=jnp.float32):
    from ..models import init_params
    params = init_params(cfg, key, dtype)
    return params, adamw_init(params)
