"""First-party AdamW (no optax in this environment).

Moments are fp32 and inherit the parameters' 2-D (FSDP x TP) sharding, so
optimizer state is fully sharded (ZeRO-style) with no extra code. Global-norm
gradient clipping and decoupled weight decay included.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(opt: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(opt.warmup_steps, 1)
    t = jnp.clip((step - opt.warmup_steps) /
                 jnp.maximum(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = opt.min_lr_ratio + (1 - opt.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return opt.lr * jnp.where(step < opt.warmup_steps, warm, cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(grads, opt_state, params, opt: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(opt, step)
    b1, b2 = opt.b1, opt.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + opt.eps) + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
