from .optimizer import OptConfig, adamw_init, adamw_update, lr_schedule, global_norm
from .train_step import make_train_step, init_train_state

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_schedule", "global_norm",
           "make_train_step", "init_train_state"]
