"""Content-addressed processing pipelines (paper §2.3).

The paper runs 16 black-box Singularity pipelines (FreeSurfer, PreQual,
SLANT, UNesT, ...). Here a pipeline is a pure-JAX function plus a canonical
config; its SHA-256 digest plays the role of the container image digest —
same digest => byte-reproducible outputs. Three representative neuroimaging
stages are implemented in JAX (the paper's compute is the pipeline *content*;
the contribution is the orchestration around it):

  * bias_correct — N4-style low-order polynomial bias-field estimation
  * affine_register — gradient-descent affine registration to an atlas
  * segment_unest — UNesT-like patch-transformer tissue segmentation
    (backbone = configs/paper_unest.py)
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    name: str
    version: str
    required_suffixes: Sequence[str]       # e.g. ("T1w",) or ("T1w", "dwi")
    config: Dict[str, object]

    def digest(self) -> str:
        blob = json.dumps({"name": self.name, "version": self.version,
                           "config": self.config}, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


class Pipeline:
    def __init__(self, spec: PipelineSpec,
                 fn: Callable[[Dict[str, np.ndarray]], Dict[str, np.ndarray]]):
        self.spec = spec
        self.fn = fn

    @property
    def name(self) -> str:
        return self.spec.name

    def digest(self) -> str:
        return self.spec.digest()

    def run(self, inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        return self.fn(inputs)


# ---------------------------------------------------------------------------
# bias-field correction (N4-style)
# ---------------------------------------------------------------------------

def _poly_basis(shape, order):
    grids = [jnp.linspace(-1, 1, s) for s in shape]
    gx, gy, gz = jnp.meshgrid(*grids, indexing="ij")
    basis = []
    for i in range(order + 1):
        for j in range(order + 1 - i):
            for k in range(order + 1 - i - j):
                basis.append(gx ** i * gy ** j * gz ** k)
    return jnp.stack(basis, -1)                      # (X,Y,Z,nb)


@jax.jit
def _fit_bias(logv, basis):
    A = basis.reshape(-1, basis.shape[-1])
    b = logv.reshape(-1)
    coef, *_ = jnp.linalg.lstsq(A, b)
    return (A @ coef).reshape(logv.shape)


def _bias_correct_fn(inputs):
    vol = jnp.asarray(inputs["T1w"], jnp.float32)
    logv = jnp.log(jnp.clip(vol, 1e-3))
    basis = _poly_basis(vol.shape, order=2)
    field = _fit_bias(logv - jnp.mean(logv), basis)
    corrected = jnp.exp(logv - field)
    return {"T1w_biascorr": np.asarray(corrected, np.float32),
            "bias_field": np.asarray(jnp.exp(field), np.float32)}


# ---------------------------------------------------------------------------
# affine registration to a synthetic atlas
# ---------------------------------------------------------------------------

def _affine_grid(shape, theta):
    """theta: (3,4) affine. Returns warped sampling coords (X,Y,Z,3) in voxels."""
    grids = [jnp.linspace(-1, 1, s) for s in shape]
    gx, gy, gz = jnp.meshgrid(*grids, indexing="ij")
    coords = jnp.stack([gx, gy, gz, jnp.ones_like(gx)], -1)     # (X,Y,Z,4)
    warped = coords @ theta.T                                   # (X,Y,Z,3)
    scale = (jnp.array(shape, jnp.float32) - 1) / 2
    return (warped + 1) * scale


def _trilinear(vol, coords):
    x, y, z = coords[..., 0], coords[..., 1], coords[..., 2]
    x0, y0, z0 = (jnp.clip(jnp.floor(c).astype(jnp.int32), 0, s - 2)
                  for c, s in zip((x, y, z), vol.shape))
    dx, dy, dz = x - x0, y - y0, z - z0
    out = 0.0
    for ix, wx in ((x0, 1 - dx), (x0 + 1, dx)):
        for iy, wy in ((y0, 1 - dy), (y0 + 1, dy)):
            for iz, wz in ((z0, 1 - dz), (z0 + 1, dz)):
                out = out + vol[ix, iy, iz] * wx * wy * wz
    return out


def _register_fn(inputs, steps=60, lr=5e-3):
    moving = jnp.asarray(inputs["T1w"], jnp.float32)
    moving = (moving - moving.mean()) / (moving.std() + 1e-6)
    # synthetic atlas: centered sphere intensity prior
    shape = moving.shape
    grids = [jnp.linspace(-1, 1, s) for s in shape]
    gx, gy, gz = jnp.meshgrid(*grids, indexing="ij")
    atlas = jnp.exp(-4 * (gx ** 2 + gy ** 2 + gz ** 2))
    atlas = (atlas - atlas.mean()) / (atlas.std() + 1e-6)

    def loss(theta):
        warped = _trilinear(moving, _affine_grid(shape, theta))
        return jnp.mean((warped - atlas) ** 2)

    theta = jnp.concatenate([jnp.eye(3), jnp.zeros((3, 1))], 1)
    g = jax.jit(jax.value_and_grad(loss))

    def body(theta, _):
        val, grad = g(theta)
        return theta - lr * grad, val
    theta, losses = jax.lax.scan(body, theta, jnp.arange(steps))
    warped = _trilinear(moving, _affine_grid(shape, theta))
    return {"T1w_reg": np.asarray(warped, np.float32),
            "affine": np.asarray(theta, np.float32),
            "reg_loss": np.asarray(losses, np.float32)}


# ---------------------------------------------------------------------------
# UNesT-like segmentation (transformer backbone over 3D patches)
# ---------------------------------------------------------------------------

def _segment_fn(inputs, n_classes=4, patch=4, seed=0):
    from ..configs import get_config
    from ..models import init_params
    from ..models.model import _txf_stack, rmsnorm

    vol = jnp.asarray(inputs["T1w"], jnp.float32)
    X, Y, Z = vol.shape
    cfg = get_config("paper-unest").reduced(vocab_size=max(n_classes, 8))
    params = init_params(cfg, jax.random.PRNGKey(seed))
    px, py, pz = X // patch, Y // patch, Z // patch
    patches = vol[:px * patch, :py * patch, :pz * patch] \
        .reshape(px, patch, py, patch, pz, patch) \
        .transpose(0, 2, 4, 1, 3, 5).reshape(px * py * pz, patch ** 3)
    patches = (patches - patches.mean()) / (patches.std() + 1e-6)
    proj = jax.random.normal(jax.random.PRNGKey(seed + 1),
                             (patch ** 3, cfg.d_model)) / patch ** 1.5
    x = (patches @ proj)[None]                       # (1, npatch, D)
    x, _, _ = _txf_stack(cfg, params, x.astype(jnp.bfloat16),
                         jnp.arange(x.shape[1]), None,
                         remat=False, collect_cache=False)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype))[0, :, :n_classes]
    seg = jnp.argmax(logits, -1).reshape(px, py, pz)
    seg_full = jnp.repeat(jnp.repeat(jnp.repeat(seg, patch, 0), patch, 1), patch, 2)
    return {"segmentation": np.asarray(seg_full, np.int32),
            "class_logits": np.asarray(logits, np.float32)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def builtin_pipelines() -> Dict[str, Pipeline]:
    return {
        "bias_correct": Pipeline(
            PipelineSpec("bias_correct", "1.0", ("T1w",), {"order": 2}),
            _bias_correct_fn),
        "affine_register": Pipeline(
            PipelineSpec("affine_register", "1.0", ("T1w",),
                         {"steps": 60, "lr": 5e-3}),
            _register_fn),
        "segment_unest": Pipeline(
            PipelineSpec("segment_unest", "1.0", ("T1w",),
                         {"n_classes": 4, "patch": 4}),
            _segment_fn),
        "dwi_prequal": Pipeline(
            PipelineSpec("dwi_prequal", "1.0", ("T1w", "dwi"),
                         {"denoise": "pca"}),
            lambda inputs: {
                "dwi_denoised": _pca_denoise(np.asarray(inputs["dwi"]))}),
    }


def _pca_denoise(dwi: np.ndarray, keep: int = 3) -> np.ndarray:
    """MP-PCA-flavoured denoising: truncated SVD over the volume dimension."""
    X, Y, Z, V = dwi.shape
    flat = dwi.reshape(-1, V).astype(np.float32)
    mu = flat.mean(0)
    u, s, vt = np.linalg.svd(flat - mu, full_matrices=False)
    s[keep:] = 0.0
    return ((u * s) @ vt + mu).reshape(X, Y, Z, V)
