"""BIDS-style manifest (paper §2.1, Fig. 2).

Layout mirrors the paper's tree:

    <root>/<dataset>/sub-<id>/ses-<id>/<modality>/sub-..._ses-..._<suffix>.npy
    <root>/<dataset>/derivatives/<pipeline>/sub-<id>/ses-<id>/...

Raw files may live on a *different* (secure) store and be symlinked into the
general namespace — the paper's GDPR arrangement. The manifest scans the
tree, validates naming, records checksums + sizes, and persists as JSON so
queries don't re-walk millions of files.
"""
from __future__ import annotations

import dataclasses
import json
import re
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from .integrity import sha256_file

# image payloads only — .json sidecars are metadata, not images
_NAME_RE = re.compile(
    r"^sub-(?P<sub>[A-Za-z0-9]+)_ses-(?P<ses>[A-Za-z0-9]+)_(?P<suffix>[A-Za-z0-9]+)\.(npy|nii)$")

MODALITIES = ("anat", "dwi", "func", "fmap")


@dataclasses.dataclass
class ImageRecord:
    path: str                    # relative to dataset root
    subject: str
    session: str
    modality: str                # anat | dwi | ...
    suffix: str                  # T1w | dwi | ...
    size_bytes: int
    sha256: str
    is_symlink: bool = False


@dataclasses.dataclass
class DatasetManifest:
    name: str
    root: str
    security_tier: str = "general"        # general | gdpr
    images: List[ImageRecord] = dataclasses.field(default_factory=list)
    scanned_at: float = 0.0

    # ---- construction ----------------------------------------------------
    @classmethod
    def scan(cls, root: Path, name: Optional[str] = None,
             security_tier: str = "general", checksum: bool = True
             ) -> "DatasetManifest":
        root = Path(root)
        m = cls(name=name or root.name, root=str(root), security_tier=security_tier)
        for p in sorted(root.rglob("*")):
            if not p.is_file() or "derivatives" in p.parts:
                continue
            nm = _NAME_RE.match(p.name)
            if not nm:
                continue
            rel = p.relative_to(root)
            modality = rel.parts[2] if len(rel.parts) >= 4 else "anat"
            m.images.append(ImageRecord(
                path=str(rel), subject=nm["sub"], session=nm["ses"],
                modality=modality, suffix=nm["suffix"],
                size_bytes=p.stat().st_size,
                sha256=sha256_file(p) if checksum else "",
                is_symlink=p.is_symlink()))
        m.scanned_at = time.time()
        return m

    # ---- validation (paper: python BIDS validator) ------------------------
    def validate(self) -> List[str]:
        problems = []
        for rec in self.images:
            parts = Path(rec.path).parts
            if len(parts) < 4:
                problems.append(f"{rec.path}: not sub-*/ses-*/<modality>/<file>")
                continue
            if not parts[0].startswith("sub-") or parts[0] != f"sub-{rec.subject}":
                problems.append(f"{rec.path}: subject dir mismatch")
            if not parts[1].startswith("ses-") or parts[1] != f"ses-{rec.session}":
                problems.append(f"{rec.path}: session dir mismatch")
            if parts[2] not in MODALITIES:
                problems.append(f"{rec.path}: unknown modality dir {parts[2]}")
        return problems

    # ---- queries -----------------------------------------------------------
    def sessions(self) -> Dict[tuple, List[ImageRecord]]:
        out: Dict[tuple, List[ImageRecord]] = {}
        for rec in self.images:
            out.setdefault((rec.subject, rec.session), []).append(rec)
        return out

    def derivatives_dir(self, pipeline: str) -> Path:
        return Path(self.root) / "derivatives" / pipeline

    # ---- persistence --------------------------------------------------------
    def save(self, path: Path):
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(dataclasses.asdict(self), indent=1))

    @classmethod
    def load(cls, path: Path) -> "DatasetManifest":
        d = json.loads(Path(path).read_text())
        d["images"] = [ImageRecord(**r) for r in d["images"]]
        return cls(**d)


def synthesize_dataset(root: Path, name: str, n_subjects: int = 4,
                       sessions_per_subject: int = 2, shape=(16, 16, 16),
                       seed: int = 0, with_dwi: bool = True) -> DatasetManifest:
    """Create a small synthetic BIDS dataset of .npy 'volumes' (tests/examples)."""
    rng = np.random.default_rng(seed)
    root = Path(root) / name
    for s in range(n_subjects):
        for ses in range(sessions_per_subject):
            base = root / f"sub-{s:03d}" / f"ses-{ses:02d}"
            t1 = base / "anat" / f"sub-{s:03d}_ses-{ses:02d}_T1w.npy"
            t1.parent.mkdir(parents=True, exist_ok=True)
            vol = rng.normal(100.0, 20.0, shape).astype(np.float32)
            # add a synthetic low-frequency bias field for the correction pipeline
            g = np.linspace(-1, 1, shape[0])
            bias = 1.0 + 0.3 * np.add.outer(np.add.outer(g, g), g)
            np.save(t1, vol * bias)
            if with_dwi and s % 2 == 0:    # some sessions lack DWI (exclusion CSV)
                dwi = base / "dwi" / f"sub-{s:03d}_ses-{ses:02d}_dwi.npy"
                dwi.parent.mkdir(parents=True, exist_ok=True)
                np.save(dwi, rng.normal(80.0, 15.0, shape + (6,)).astype(np.float32))
    return DatasetManifest.scan(root, name=name)
