"""The paper's contribution as a composable substrate: manifests, automated
work queries, content-addressed pipelines, checksummed tiered storage,
provenance, the workflow engine, and the cost model."""
from .integrity import (IntegrityError, fletcher64, fletcher64_file,
                        sha256_file, sha256_load_array, sha256_save_array,
                        array_checksum, verified_copy)
from .manifest import DatasetManifest, ImageRecord, synthesize_dataset
from .pipelines import Pipeline, PipelineSpec, builtin_pipelines
from .provenance import Provenance, make_provenance, is_complete
from .query import (WorkUnit, Exclusion, dump_units, load_units,
                    query_available_work, write_exclusion_csv)
from .storage import TieredStore, TIERS
from .workflow import (JobPlan, LocalRunner, StragglerDetector, UnitResult,
                       dedupe_results, generate_jobs, load_unit_inputs,
                       resource_status, run_unit, run_unit_with_retries)
from .cost import (PAPER_ENVS, TPU_ENVS, job_cost, paper_table1,
                   cost_ratio_cloud_vs_hpc, training_run_cost)
from .ingest import IngestRule, ingest_directory, write_raw_dump

__all__ = [
    "IntegrityError", "fletcher64", "fletcher64_file", "sha256_file",
    "sha256_load_array", "sha256_save_array", "array_checksum",
    "verified_copy", "DatasetManifest", "ImageRecord", "synthesize_dataset",
    "Pipeline", "PipelineSpec", "builtin_pipelines", "Provenance",
    "make_provenance", "is_complete", "WorkUnit", "Exclusion",
    "query_available_work", "write_exclusion_csv", "TieredStore", "TIERS",
    "JobPlan", "LocalRunner", "StragglerDetector", "UnitResult",
    "dedupe_results", "generate_jobs", "load_unit_inputs", "resource_status",
    "run_unit", "run_unit_with_retries",
    "PAPER_ENVS", "TPU_ENVS", "job_cost", "paper_table1",
    "cost_ratio_cloud_vs_hpc", "training_run_cost",
    "IngestRule", "ingest_directory", "write_raw_dump",
    "dump_units", "load_units",
    "CampaignPlan", "Cohort", "Shard", "admission_throttle",
    "cohort_from_query", "plan_campaign", "summaries_from_queue",
]

_CAMPAIGN_NAMES = ("CampaignPlan", "Cohort", "Shard", "admission_throttle",
                   "cohort_from_query", "plan_campaign",
                   "summaries_from_queue")


def __getattr__(name):
    # campaign is loaded lazily: it imports repro.dist (for the shared
    # placement scorer + digest summaries), and repro.dist.cache imports
    # repro.core.integrity — an eager import here would cycle whenever
    # repro.dist is imported first
    if name in _CAMPAIGN_NAMES:
        from . import campaign
        return getattr(campaign, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
