"""Workflow engine (paper §2.3, Fig. 3): query -> job scripts -> execution.

Generates a SLURM job-array script (the paper's HPC path) *and* a local
parallel runner (the paper's burst/debug path) from the same work list.
Execution is idempotent (provenance-gated), checksums all I/O, retries failed
units with exponential backoff, and speculatively re-executes stragglers
(the known long-tail mitigation the paper's ACCRE scheduler handles for them;
here it's first-party, as a 1000-node deployment requires).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import traceback
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from .integrity import sha256_file
from .manifest import DatasetManifest
from .pipelines import Pipeline
from .provenance import make_provenance, is_complete
from .query import WorkUnit, query_available_work, write_exclusion_csv


# ---------------------------------------------------------------------------
# script generation
# ---------------------------------------------------------------------------

SLURM_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={name}
#SBATCH --array=0-{last_idx}%{throttle}
#SBATCH --cpus-per-task={cpus}
#SBATCH --mem={mem_gb}G
#SBATCH --time={walltime}
#SBATCH --output={log_dir}/%x_%a.out

set -euo pipefail
MANIFEST={manifest_json}
UNIT=$(python -m repro.core.workflow --unit-from {units_json} --index $SLURM_ARRAY_TASK_ID)
# copy inputs to node-local scratch, run containerized pipeline, copy back
python -m repro.core.workflow --run-one {units_json} --index $SLURM_ARRAY_TASK_ID \\
    --data-root {data_root} --scratch $SLURM_TMPDIR
"""


@dataclasses.dataclass
class JobPlan:
    units: List[WorkUnit]
    slurm_script: Optional[str] = None
    units_file: Optional[str] = None
    exclusion_csv: Optional[str] = None


def generate_jobs(manifest: DatasetManifest, pipeline: Pipeline, out_dir: Path,
                  *, cpus: int = 4, mem_gb: int = 16, walltime: str = "24:00:00",
                  throttle: int = 100) -> JobPlan:
    """The paper's single-line script generation: query + job array + CSV."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    units, excluded = query_available_work(manifest, pipeline)
    excl_csv = out_dir / f"{manifest.name}_{pipeline.name}_excluded.csv"
    write_exclusion_csv(excluded, excl_csv)
    units_file = out_dir / f"{manifest.name}_{pipeline.name}_units.json"
    units_file.write_text(json.dumps([dataclasses.asdict(u) for u in units], indent=1))
    plan = JobPlan(units=units, units_file=str(units_file),
                   exclusion_csv=str(excl_csv))
    if units:
        script = SLURM_TEMPLATE.format(
            name=f"{manifest.name}_{pipeline.name}",
            last_idx=len(units) - 1, throttle=throttle, cpus=cpus,
            mem_gb=mem_gb, walltime=walltime,
            log_dir=str(out_dir / "logs"),
            manifest_json=str(out_dir / "manifest.json"),
            units_json=str(units_file), data_root=manifest.root)
        sp = out_dir / f"{manifest.name}_{pipeline.name}.slurm"
        sp.write_text(script)
        plan.slurm_script = str(sp)
    return plan


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UnitResult:
    unit: WorkUnit
    status: str                  # ok | failed | skipped
    seconds: float
    attempts: int
    error: Optional[str] = None


def run_unit(unit: WorkUnit, pipeline: Pipeline, data_root: Path,
             attempt: int = 1,
             fault_hook: Optional[Callable[[WorkUnit, int], None]] = None
             ) -> UnitResult:
    """Execute one work unit: verify inputs, run, write outputs + provenance."""
    t0 = time.time()
    data_root = Path(data_root)
    out_dir = Path(unit.out_dir)
    if is_complete(out_dir, unit.pipeline_digest):
        return UnitResult(unit, "skipped", 0.0, attempt)
    try:
        if fault_hook is not None:
            fault_hook(unit, attempt)       # test hook: injected node failures
        inputs, in_sums = {}, {}
        for suffix, rel in unit.inputs.items():
            p = data_root / rel
            in_sums[rel] = sha256_file(p)
            inputs[suffix] = np.load(p)
        outputs = pipeline.run(inputs)
        out_sums = {}
        out_dir.mkdir(parents=True, exist_ok=True)
        for name, arr in outputs.items():
            op = out_dir / f"sub-{unit.subject}_ses-{unit.session}_{name}.npy"
            np.save(op, arr)
            out_sums[op.name] = sha256_file(op)
        make_provenance(unit.pipeline, unit.pipeline_digest, in_sums, out_sums,
                        t0, attempt=attempt).save(out_dir)
        return UnitResult(unit, "ok", time.time() - t0, attempt)
    except Exception as e:  # noqa: BLE001 — recorded, retried by the runner
        out_dir.mkdir(parents=True, exist_ok=True)
        make_provenance(unit.pipeline, unit.pipeline_digest, {}, {}, t0,
                        status="failed", error=f"{type(e).__name__}: {e}",
                        attempt=attempt).save(out_dir)
        return UnitResult(unit, "failed", time.time() - t0, attempt,
                          error=traceback.format_exc(limit=3))


class LocalRunner:
    """The paper's burst-to-local path, with retry + straggler duplication."""

    def __init__(self, pipeline: Pipeline, data_root: Path, *,
                 max_retries: int = 2, backoff_s: float = 0.05,
                 straggler_factor: float = 3.0,
                 fault_hook: Optional[Callable[[WorkUnit, int], None]] = None):
        self.pipeline = pipeline
        self.data_root = Path(data_root)
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.straggler_factor = straggler_factor
        self.fault_hook = fault_hook

    def run(self, units: List[WorkUnit]) -> List[UnitResult]:
        results: List[UnitResult] = []
        durations: List[float] = []
        for unit in units:
            res = None
            for attempt in range(1, self.max_retries + 2):
                res = run_unit(unit, self.pipeline, self.data_root,
                               attempt=attempt, fault_hook=self.fault_hook)
                if res.status in ("ok", "skipped"):
                    break
                time.sleep(self.backoff_s * (2 ** (attempt - 1)))
            results.append(res)
            if res.status == "ok":
                durations.append(res.seconds)
            # straggler mitigation: if this unit ran much longer than the
            # median so far, schedule a speculative duplicate (idempotent:
            # provenance gating makes the copy a no-op if the original won)
            if (len(durations) >= 4 and res.status == "ok"
                    and res.seconds > self.straggler_factor * float(np.median(durations))):
                dup = run_unit(unit, self.pipeline, self.data_root,
                               attempt=res.attempts + 1)
                results.append(dup)
        return results


def resource_status(root: Path) -> Dict[str, float]:
    """The paper's resource query informing when to submit (disk here; SLURM
    queue depth would come from `squeue` on a real cluster)."""
    st = os.statvfs(root)
    return {"disk_free_gb": st.f_bavail * st.f_frsize / 2**30,
            "disk_total_gb": st.f_blocks * st.f_frsize / 2**30,
            "load_1m": os.getloadavg()[0]}


# ---------------------------------------------------------------------------
# CLI used by the generated SLURM array scripts
# ---------------------------------------------------------------------------

def _main():
    import argparse
    from .pipelines import builtin_pipelines
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-one", dest="units_json")
    ap.add_argument("--unit-from", dest="unit_from")
    ap.add_argument("--index", type=int, default=0)
    ap.add_argument("--data-root", default=".")
    ap.add_argument("--scratch", default="/tmp")
    args = ap.parse_args()
    src = args.units_json or args.unit_from
    units = [WorkUnit(**u) for u in json.loads(Path(src).read_text())]
    unit = units[args.index]
    if args.unit_from:
        print(unit.job_id)
        return
    pipe = builtin_pipelines()[unit.pipeline]
    res = run_unit(unit, pipe, Path(args.data_root))
    print(f"{unit.job_id}: {res.status} ({res.seconds:.1f}s)")
    if res.status == "failed":
        raise SystemExit(1)


if __name__ == "__main__":
    _main()
