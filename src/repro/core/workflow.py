"""Workflow engine (paper §2.3, Fig. 3): query -> job scripts -> execution.

Generates a SLURM job-array script (the paper's HPC path) *and* a local
parallel runner (the paper's burst/debug path) from the same work list.

Execution data plane (``LocalRunner``) is built for throughput:

* **Multi-worker executor** — ``workers=N`` compute threads drain the unit
  list concurrently (XLA/BLAS release the GIL, so pipeline compute overlaps).
* **Pipelined prefetch** — a loader stage verifies+hashes+loads the next
  units' inputs (one read per byte, see ``integrity.sha256_load_array``)
  while compute runs the current ones; lookahead is bounded by
  ``workers + prefetch`` units so memory stays flat.
* **Idempotent, concurrency-safe commits** — outputs are written via atomic
  tmp-file + rename; the ok-provenance commit is arbitrated per output dir
  (re-check under lock), so two workers racing the same unit produce exactly
  one committed provenance — the loser reports ``skipped``.
* **Retry + backoff** — failed units retry with exponential backoff, each
  attempt recorded in provenance.
* **Straggler speculation** — while a unit runs longer than
  ``straggler_factor`` x the running median (and ``workers > 1`` so there is
  spare capacity), a speculative duplicate is launched; provenance gating
  picks a single winner. Speculative results are reported with
  ``status="speculative"`` and never inflate per-image ok-counts.

``workers=1`` (the default) degrades to the serial paper behaviour with
prefetch still overlapping I/O and compute.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import traceback
import weakref
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import stream as stream_mod
from .integrity import sha256_load_array, sha256_save_array
from .manifest import DatasetManifest
from .pipelines import Pipeline
from .provenance import make_provenance, is_complete
from .query import (WorkUnit, dump_units, load_units, query_available_work,
                    write_exclusion_csv)


# ---------------------------------------------------------------------------
# script generation
# ---------------------------------------------------------------------------

SLURM_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={name}
#SBATCH --array=0-{last_idx}%{throttle}
#SBATCH --cpus-per-task={cpus}
#SBATCH --mem={mem_gb}G
#SBATCH --time={walltime}
#SBATCH --output={log_dir}/%x_%a.out

set -euo pipefail
MANIFEST={manifest_json}
UNIT=$(python -m repro.core.workflow --unit-from {units_json} --index $SLURM_ARRAY_TASK_ID)
# copy inputs to node-local scratch, run containerized pipeline, copy back
python -m repro.core.workflow --run-one {units_json} --index $SLURM_ARRAY_TASK_ID \\
    --data-root {data_root} --scratch $SLURM_TMPDIR
"""


@dataclasses.dataclass
class JobPlan:
    units: List[WorkUnit]
    slurm_script: Optional[str] = None
    units_file: Optional[str] = None
    exclusion_csv: Optional[str] = None
    manifest_file: Optional[str] = None
    # campaign mode (admission-time locality, repro.core.campaign): the
    # deterministic plan artifact plus one script + units file per shard
    campaign_file: Optional[str] = None
    shard_scripts: List[str] = dataclasses.field(default_factory=list)
    shard_units_files: List[str] = dataclasses.field(default_factory=list)


def generate_jobs(manifest: DatasetManifest, pipeline: Pipeline, out_dir: Path,
                  *, cpus: int = 4, mem_gb: int = 16, walltime: str = "24:00:00",
                  throttle: int = 100, campaign=None, summaries=None) -> JobPlan:
    """The paper's single-line script generation: query + job array + CSV.

    Blind mode (default) emits one untargeted array script over the whole
    unit list. Campaign mode — ``summaries=`` (per-node digest-summary
    wires, a summaries-file path, or live :class:`DigestSummary` objects) or
    a pre-built ``campaign=`` :class:`~repro.core.campaign.CampaignPlan` —
    shards the array by data placement instead: one SLURM script per shard,
    warm shards pinned to the host holding their bytes, plus a
    deterministic ``campaign.json`` stamped with the planner-inputs hash so
    the submitted campaign is replayable and auditable. Either way the
    manifest and units JSON land next to the scripts, so every path the
    generated scripts reference exists at submit time."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "logs").mkdir(exist_ok=True)      # SBATCH --output target
    units, excluded = query_available_work(manifest, pipeline)
    excl_csv = out_dir / f"{manifest.name}_{pipeline.name}_excluded.csv"
    write_exclusion_csv(excluded, excl_csv)
    units_file = dump_units(
        units, out_dir / f"{manifest.name}_{pipeline.name}_units.json")
    manifest_file = out_dir / "manifest.json"
    manifest.save(manifest_file)                 # referenced by every script
    plan = JobPlan(units=units, units_file=str(units_file),
                   exclusion_csv=str(excl_csv),
                   manifest_file=str(manifest_file))
    if not units:
        return plan

    if campaign is None and summaries is not None:
        from .campaign import Cohort, plan_campaign
        cohort = Cohort(manifest.name, pipeline.name, pipeline.digest(),
                        units, excluded)
        campaign = plan_campaign([cohort], summaries, throttle=throttle,
                                 status=resource_status(out_dir))
    if campaign is not None:
        from .campaign import as_plan
        from ..launch.slurm import write_shard_script
        campaign = as_plan(campaign)
        plan.campaign_file = str(campaign.save(out_dir / "campaign.json"))
        by_job = {u.job_id: u for u in units}
        # resolve every shard to THIS cohort's units first (a multi-cohort
        # plan names other cohorts' units too), then catch the admitted
        # units the plan never covered — sessions that appeared after
        # planning, replayed stale plans — in one untargeted shard, so a
        # submitted campaign always schedules the whole work list (the same
        # fail-soft contract as WorkQueue plan seeding: degrade to blind,
        # never lose work)
        arrays: List[Tuple[str, Optional[str], List[WorkUnit]]] = []
        covered: set = set()
        for shard in campaign.shards:
            shard_units = [by_job[j] for j in shard.unit_ids if j in by_job]
            if not shard_units:
                continue
            covered.update(u.job_id for u in shard_units)
            arrays.append((shard.shard_id, shard.node_id, shard_units))
        uncovered = [u for u in units if u.job_id not in covered]
        if uncovered:
            arrays.append(("shard-uncovered", None, uncovered))
        # the resource-derived throttle budgets the *campaign's* concurrent
        # scratch footprint; split it across the emitted arrays so N
        # simultaneously-submitted shards cannot multiply it back up
        # (conservative when warm shards are pinned to distinct hosts).
        # Residual: SLURM cannot express a cross-array throttle, so with
        # more arrays than budget the floor of one task per array can still
        # exceed it — the runbook tells resource-tight operators to submit
        # shards in waves in that regime (docs/operating.md)
        per_shard = max(1, campaign.throttle // max(1, len(arrays)))
        for shard_id, node_id, shard_units in arrays:
            name = f"{manifest.name}_{pipeline.name}_{shard_id}"
            sf = dump_units(shard_units, out_dir / f"{name}_units.json")
            sp = write_shard_script(
                out_dir, name=name, n_units=len(shard_units),
                units_json=str(sf), manifest_json=str(manifest_file),
                data_root=manifest.root, node_id=node_id,
                throttle=per_shard, cpus=cpus, mem_gb=mem_gb,
                walltime=walltime)
            plan.shard_units_files.append(str(sf))
            plan.shard_scripts.append(str(sp))
        return plan

    script = SLURM_TEMPLATE.format(
        name=f"{manifest.name}_{pipeline.name}",
        last_idx=len(units) - 1, throttle=throttle, cpus=cpus,
        mem_gb=mem_gb, walltime=walltime,
        log_dir=str(out_dir / "logs"),
        manifest_json=str(manifest_file),
        units_json=str(units_file), data_root=manifest.root)
    sp = out_dir / f"{manifest.name}_{pipeline.name}.slurm"
    sp.write_text(script)
    plan.slurm_script = str(sp)
    return plan


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UnitResult:
    unit: WorkUnit
    status: str                  # ok | failed | skipped | speculative | blocked
    seconds: float
    attempts: int
    error: Optional[str] = None
    # data-movement accounting (mirrors the provenance stamps): input bytes
    # served from the host cache on the committing run, input bytes streamed
    # from warm peers over the blob fabric, and the scheduler's grant-time
    # estimate of the locally-available input fraction
    bytes_from_cache: int = 0
    bytes_from_peer: int = 0
    locality_score: float = 0.0


# Commit arbitration for concurrent workers racing the same output dir.
# Thread-level: the atomic tmp+rename writes already make cross-process races
# safe at the file level; this lock adds the exactly-one-ok-commit guarantee
# within a runner process (the speculation + shared-queue case).


class _DirLock:
    """Weakref-able lock holder (a bare C lock cannot be weak-referenced)."""
    __slots__ = ("lock", "__weakref__")

    def __init__(self):
        self.lock = threading.Lock()


# WeakValueDictionary bounds memory in long-lived processes without an
# eviction policy: an entry lives exactly as long as some thread holds the
# returned _DirLock, so two racers can never end up with different locks
# for the same out_dir.
_COMMIT_LOCKS: "weakref.WeakValueDictionary[str, _DirLock]" = \
    weakref.WeakValueDictionary()
_COMMIT_LOCKS_GUARD = threading.Lock()


def _commit_lock(out_dir: Path) -> _DirLock:
    key = str(out_dir)
    with _COMMIT_LOCKS_GUARD:
        holder = _COMMIT_LOCKS.get(key)
        if holder is None:
            holder = _DirLock()
            _COMMIT_LOCKS[key] = holder
        return holder


# (inputs by suffix, rel-path -> sha256, every input served from host cache,
#  input bytes off node-local disk rather than shared storage, input bytes
#  streamed from warm peers over the blob fabric, per-unit streaming-ingest
#  report — StreamReport dict aggregated over the unit's streamed fetches,
#  None when nothing streamed)
LoadedInputs = Tuple[Dict[str, np.ndarray], Dict[str, str], bool, int, int,
                     Optional[Dict]]


def load_unit_inputs(unit: WorkUnit, data_root: Path,
                     cache=None) -> LoadedInputs:
    """Verify-and-load a unit's inputs with one read per file: each array is
    hashed from the same bytes it is deserialized from (no sha256_file +
    np.load double-read). This is the prefetch stage of the executor.

    ``cache`` (a :class:`repro.dist.cache.InputCache`) serves inputs whose
    bytes are already on the host's local disk instead of re-reading shared
    storage; the returned digests are identical either way. With a peer
    fabric attached to the cache (``InputCache.attach_fabric``), a local
    miss whose manifest digest is known first streams from a warm peer —
    the unit's ``input_digests``/``input_bytes`` manifest hints are what
    make the fetch content-addressed. The third element of the result is
    True iff *every* input came from the local cache — stamped into
    provenance as ``cache_hit`` — the fourth counts the input bytes the
    cache kept off the storage link (``bytes_from_cache``), and the fifth
    the bytes that arrived over peer links (``bytes_from_peer``). The sixth
    is the unit's aggregated streaming-ingest report (digests computed
    chunk-by-chunk while the bytes moved, ``repro.core.stream``; ``None``
    when every input was served resident or streaming is disabled) —
    stamped into provenance as ``stream``."""
    data_root = Path(data_root)
    inputs: Dict[str, np.ndarray] = {}
    in_sums: Dict[str, str] = {}
    digests = unit.input_digests or {}
    sizes = unit.input_bytes or {}
    hits = 0
    hit_bytes = 0
    peer_bytes = 0
    stream_rep: Optional[stream_mod.StreamReport] = None
    streaming = cache is None and stream_mod.stream_enabled()
    for suffix, rel in unit.inputs.items():
        rep = None
        if cache is not None:
            arr, digest, origin, nbytes, info = cache.fetch_array(
                data_root / rel, digest_hint=digests.get(suffix),
                size_hint=sizes.get(suffix))
            if info is not None:
                rep = stream_mod.StreamReport.from_dict(info)
            if origin == "cache":
                hits += 1
                hit_bytes += nbytes
            elif origin == "peer":
                peer_bytes += nbytes
        elif streaming:
            arr, digest, _qa, rep = stream_mod.stream_load_npy(
                data_root / rel)
        else:
            arr, digest = sha256_load_array(data_root / rel)
        if rep is not None:
            if stream_rep is None:
                stream_rep = rep
            else:
                stream_rep.merge(rep)
        in_sums[rel] = digest
        inputs[suffix] = arr
    return (inputs, in_sums,
            bool(unit.inputs) and hits == len(unit.inputs), hit_bytes,
            peer_bytes,
            stream_rep.to_dict() if stream_rep is not None else None)


def safe_load_unit_inputs(unit: WorkUnit, data_root: Path,
                          cache=None) -> Optional[LoadedInputs]:
    """Prefetch-stage wrapper shared by both executors: a failed load returns
    ``None`` so the compute stage reloads and raises with full context."""
    try:
        return load_unit_inputs(unit, data_root, cache=cache)
    except Exception:  # noqa: BLE001 — the compute stage re-raises properly
        return None


# Output write-through (multi-stage DAGs): the committing run inserts its
# just-written outputs into the host's input cache, so a dependent unit
# scheduled on the same host (producer placement) serves stage-N outputs as
# stage-N+1 inputs off local disk. Env-disable for benchmarks that need a
# warm-up whose caches hold inputs only.
WRITE_THROUGH_ENV = "REPRO_CACHE_WRITE_THROUGH"


def _write_outputs_through(cache, out_dir: Path, out_sums: Dict[str, str]):
    """Best-effort: a cache insert must never fail a committed unit."""
    if cache is None or os.environ.get(WRITE_THROUGH_ENV, "1") == "0":
        return
    for name, digest in out_sums.items():
        try:
            path = Path(out_dir) / name
            cache.put_bytes(path.read_bytes(), digest=digest, source=path)
        except Exception:  # noqa: BLE001 — provenance already committed
            continue


def run_unit(unit: WorkUnit, pipeline: Pipeline, data_root: Path,
             attempt: int = 1,
             fault_hook: Optional[Callable[[WorkUnit, int], None]] = None,
             preloaded: Optional[LoadedInputs] = None,
             node_id: str = "", lease_epoch: int = 0,
             cache=None, locality_score: float = 0.0) -> UnitResult:
    """Execute one work unit: verify inputs, run, write outputs + provenance.

    ``preloaded`` short-circuits the input stage with already verified+loaded
    arrays from the prefetch pipeline. Output files are committed atomically
    and the ok-provenance is written under the per-out_dir commit lock with an
    ``is_complete`` re-check, so a racing duplicate commits exactly once; the
    loser returns ``skipped``. ``node_id``/``lease_epoch`` stamp the committed
    provenance when the unit runs under a cluster lease
    (:mod:`repro.dist.cluster`); ``cache`` serves the input stage from the
    host's content-addressed cache and stamps ``cache_hit`` when every input
    avoided shared storage. ``locality_score`` is the scheduler's grant-time
    estimate of the locally-available input fraction — stamped next to the
    measured ``bytes_from_cache`` so placement quality is auditable per image.
    """
    t0 = time.time()
    data_root = Path(data_root)
    out_dir = Path(unit.out_dir)
    if is_complete(out_dir, unit.pipeline_digest):
        return UnitResult(unit, "skipped", 0.0, attempt)
    try:
        if fault_hook is not None:
            fault_hook(unit, attempt)       # test hook: injected node failures
        if preloaded is not None:
            inputs, in_sums, cache_hit, hit_bytes, peer_bytes, stream = \
                preloaded
        else:
            inputs, in_sums, cache_hit, hit_bytes, peer_bytes, stream = \
                load_unit_inputs(unit, data_root, cache=cache)
        outputs = pipeline.run(inputs)
        out_sums = {}
        out_dir.mkdir(parents=True, exist_ok=True)
        for name, arr in outputs.items():
            op = out_dir / f"sub-{unit.subject}_ses-{unit.session}_{name}.npy"
            out_sums[op.name] = sha256_save_array(op, arr)
        holder = _commit_lock(out_dir)   # keep referenced while lock is held
        with holder.lock:
            if is_complete(out_dir, unit.pipeline_digest):
                return UnitResult(unit, "skipped", time.time() - t0, attempt)
            make_provenance(unit.pipeline, unit.pipeline_digest, in_sums,
                            out_sums, t0, attempt=attempt, node_id=node_id,
                            lease_epoch=lease_epoch, cache_hit=cache_hit,
                            locality_score=locality_score,
                            bytes_from_cache=hit_bytes,
                            peer_fetch=peer_bytes > 0,
                            bytes_from_peer=peer_bytes,
                            stream=stream).save(out_dir)
        _write_outputs_through(cache, out_dir, out_sums)
        return UnitResult(unit, "ok", time.time() - t0, attempt,
                          bytes_from_cache=hit_bytes,
                          bytes_from_peer=peer_bytes,
                          locality_score=locality_score)
    except Exception as e:  # noqa: BLE001 — recorded, retried by the runner
        holder = _commit_lock(out_dir)
        with holder.lock:
            if not is_complete(out_dir, unit.pipeline_digest):
                out_dir.mkdir(parents=True, exist_ok=True)
                make_provenance(unit.pipeline, unit.pipeline_digest, {}, {}, t0,
                                status="failed", error=f"{type(e).__name__}: {e}",
                                attempt=attempt, node_id=node_id,
                                lease_epoch=lease_epoch).save(out_dir)
        return UnitResult(unit, "failed", time.time() - t0, attempt,
                          error=traceback.format_exc(limit=3))


def run_unit_with_retries(
        unit: WorkUnit, pipeline: Pipeline, data_root: Path, *,
        max_retries: int = 2, backoff_s: float = 0.05,
        fault_hook: Optional[Callable[[WorkUnit, int], None]] = None,
        preloaded: Optional[LoadedInputs] = None,
        node_id: str = "", lease_epoch: int = 0, cache=None,
        locality_score: float = 0.0) -> UnitResult:
    """The executor retry stage, shared by :class:`LocalRunner` workers and
    cluster nodes: run a unit up to ``max_retries + 1`` times with exponential
    backoff. Prefetched inputs — and the host input cache — are only trusted
    on the first attempt: a retry re-verifies from storage (the failure may
    have been a torn read that the cache would otherwise replay)."""
    res = None
    for attempt in range(1, max_retries + 2):
        res = run_unit(unit, pipeline, data_root, attempt=attempt,
                       fault_hook=fault_hook,
                       preloaded=preloaded if attempt == 1 else None,
                       node_id=node_id, lease_epoch=lease_epoch,
                       cache=cache if attempt == 1 else None,
                       locality_score=locality_score)
        if res.status in ("ok", "skipped"):
            break
        if attempt <= max_retries:          # no dead sleep after the last try
            time.sleep(backoff_s * (2 ** (attempt - 1)))
    return res


class StragglerDetector:
    """Running-median straggler policy shared by the single-host and cluster
    executors: a unit is a straggler once it has run ``factor`` x the median
    of completed-ok durations (with an absolute ``min_s`` floor, and only
    after ``min_samples`` completions so the median is meaningful)."""

    def __init__(self, factor: float = 3.0, min_s: float = 0.5,
                 min_samples: int = 4):
        self.factor = factor
        self.min_s = min_s
        self.min_samples = min_samples
        self._durations: List[float] = []
        self._lock = threading.Lock()

    def observe(self, seconds: float):
        with self._lock:
            self._durations.append(seconds)

    def median(self) -> Optional[float]:
        with self._lock:
            if len(self._durations) < self.min_samples:
                return None
            return float(np.median(self._durations))

    def is_straggler(self, elapsed: float) -> bool:
        med = self.median()
        return (med is not None and elapsed > self.min_s
                and elapsed > self.factor * med)


def dedupe_results(primaries: List[UnitResult],
                   speculative: List[Tuple[int, UnitResult]]) -> List[UnitResult]:
    """Fold speculative duplicates into the primary result list.

    Exactly one result per unit keeps a committed status; every duplicate is
    relabelled ``status="speculative"`` so ok-counts (benchmarks, reports)
    are never inflated. If the speculative twin won the commit race (the
    primary came back ``skipped``/``failed``), the unit's primary slot
    absorbs the twin's committed result."""
    primaries = list(primaries)
    extras: List[UnitResult] = []
    for idx, spec in speculative:
        prim = primaries[idx]
        if spec.status == "ok" and prim.status != "ok":
            primaries[idx] = dataclasses.replace(
                spec, attempts=max(prim.attempts, spec.attempts))
        extras.append(dataclasses.replace(spec, status="speculative"))
    return primaries + extras


class LocalRunner:
    """The paper's burst-to-local path: a pipelined parallel executor with
    retry, provenance-gated idempotency, and straggler speculation.

    Knobs:
      * ``workers``        — compute threads (1 = serial paper behaviour).
      * ``prefetch``       — extra units of input-load lookahead beyond
                             ``workers`` (the verify+load stage).
      * ``max_retries`` / ``backoff_s`` — retry failed units with
                             exponential backoff.
      * ``straggler_factor`` / ``straggler_min_s`` — speculate a duplicate
                             when a unit exceeds ``factor x running-median``
                             (and at least ``min_s`` seconds, >= 4 samples,
                             spare workers available).
    """

    def __init__(self, pipeline: Pipeline, data_root: Path, *,
                 max_retries: int = 2, backoff_s: float = 0.05,
                 straggler_factor: float = 3.0,
                 straggler_min_s: float = 0.5,
                 fault_hook: Optional[Callable[[WorkUnit, int], None]] = None,
                 workers: int = 1, prefetch: int = 2):
        self.pipeline = pipeline
        self.data_root = Path(data_root)
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.fault_hook = fault_hook
        self.workers = max(1, int(workers))
        self.prefetch = max(0, int(prefetch))

    # -- stages -------------------------------------------------------------

    def _execute(self, idx: int, unit: WorkUnit, loads: Dict[int, "object"],
                 loads_guard: threading.Lock, loader: ThreadPoolExecutor,
                 n_units: int, starts: Dict[int, float],
                 units: List[WorkUnit]) -> UnitResult:
        starts[idx] = time.time()
        # pick up (and release) this unit's prefetched inputs; top up the
        # lookahead window — popping keeps live arrays bounded by the window
        with loads_guard:
            pre_f = loads.pop(idx, None)
            nxt = idx + self.workers + self.prefetch
            if nxt < n_units and nxt not in loads:
                loads[nxt] = loader.submit(self._safe_load, units[nxt])
        pre = pre_f.result() if pre_f is not None else None
        return run_unit_with_retries(
            unit, self.pipeline, self.data_root, max_retries=self.max_retries,
            backoff_s=self.backoff_s, fault_hook=self.fault_hook, preloaded=pre)

    def _safe_load(self, unit: WorkUnit) -> Optional[LoadedInputs]:
        return safe_load_unit_inputs(unit, self.data_root)

    # -- driver -------------------------------------------------------------

    def run(self, units: List[WorkUnit]) -> List[UnitResult]:
        if not units:
            return []
        n = len(units)
        primaries: List[Optional[UnitResult]] = [None] * n
        detector = StragglerDetector(self.straggler_factor,
                                     self.straggler_min_s)
        starts: Dict[int, float] = {}
        speculated: set = set()
        spec_queue: List[int] = []
        spec_results: List[Tuple[int, UnitResult]] = []
        loads: Dict[int, "object"] = {}
        loads_guard = threading.Lock()
        next_primary = 0

        with ThreadPoolExecutor(max_workers=self.workers) as pool, \
                ThreadPoolExecutor(max_workers=max(1, min(self.workers, 2))) as loader:
            with loads_guard:
                for i in range(min(self.workers + self.prefetch, n)):
                    loads[i] = loader.submit(self._safe_load, units[i])
            # slot-based admission: at most ``workers`` tasks in the pool, so
            # a speculative twin dispatches into the NEXT free slot — ahead
            # of every waiting primary — and actually runs concurrently with
            # its straggler instead of queueing behind the whole work list
            inflight: Dict["object", Tuple[str, int]] = {}

            def dispatch():
                nonlocal next_primary
                while len(inflight) < self.workers:
                    if spec_queue:
                        i = spec_queue.pop(0)
                        f = pool.submit(run_unit, units[i], self.pipeline,
                                        self.data_root,
                                        attempt=self.max_retries + 2)
                        inflight[f] = ("spec", i)
                    elif next_primary < n:
                        i = next_primary
                        next_primary += 1
                        f = pool.submit(self._execute, i, units[i], loads,
                                        loads_guard, loader, n, starts, units)
                        inflight[f] = ("prim", i)
                    else:
                        break

            dispatch()
            # poll only when speculation is possible; with one worker there
            # is nothing to monitor, so block until a future completes
            poll = 0.05 if self.workers > 1 else None
            while inflight:
                done, _ = wait(set(inflight), timeout=poll,
                               return_when=FIRST_COMPLETED)
                for f in done:
                    kind, i = inflight.pop(f)
                    res = f.result()
                    if kind == "prim":
                        primaries[i] = res
                        if res.status == "ok":
                            detector.observe(res.seconds)
                    else:
                        spec_results.append((i, res))
                # straggler speculation: duplicate in-flight units running far
                # beyond the median (idempotent — provenance picks one winner)
                if self.workers > 1:
                    now = time.time()
                    for kind, i in list(inflight.values()):
                        if kind != "prim" or i in speculated or i not in starts:
                            continue
                        if detector.is_straggler(now - starts[i]):
                            speculated.add(i)
                            spec_queue.append(i)
                dispatch()

        return dedupe_results([r for r in primaries if r is not None],
                              spec_results)


def resource_status(root: Path) -> Dict[str, float]:
    """The paper's resource query informing when to submit (disk here; SLURM
    queue depth would come from `squeue` on a real cluster)."""
    st = os.statvfs(root)
    return {"disk_free_gb": st.f_bavail * st.f_frsize / 2**30,
            "disk_total_gb": st.f_blocks * st.f_frsize / 2**30,
            "load_1m": os.getloadavg()[0]}


# ---------------------------------------------------------------------------
# CLI used by the generated SLURM array scripts
# ---------------------------------------------------------------------------

def _main():
    import argparse
    from .pipelines import builtin_pipelines
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-one", dest="units_json")
    ap.add_argument("--unit-from", dest="unit_from")
    ap.add_argument("--index", type=int, default=0)
    ap.add_argument("--data-root", default=".")
    ap.add_argument("--scratch", default="/tmp")
    args = ap.parse_args()
    src = args.units_json or args.unit_from
    units = load_units(Path(src))
    unit = units[args.index]
    if args.unit_from:
        print(unit.job_id)
        return
    pipe = builtin_pipelines()[unit.pipeline]
    res = run_unit(unit, pipe, Path(args.data_root))
    print(f"{unit.job_id}: {res.status} ({res.seconds:.1f}s)")
    if res.status == "failed":
        raise SystemExit(1)


if __name__ == "__main__":
    _main()
