"""Cost model (paper §2.4, Table 1) — HPC vs cloud vs local economics,
extended to TPU-pod training economics for this framework's scale.

Paper constants are encoded verbatim so ``benchmarks/table1_cost.py``
reproduces the published table; ``job_cost`` generalizes to any workload.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ComputeEnv:
    name: str
    cost_per_hour: float             # one 16 GB instance (paper Table 1)
    throughput_gbps: float           # storage -> compute
    latency_ms: float
    freesurfer_minutes: float        # measured per-image pipeline time


# Paper Table 1, verbatim
PAPER_ENVS: Dict[str, ComputeEnv] = {
    "hpc": ComputeEnv("HPC (ACCRE)", 0.0096, 0.60, 0.16, 375.5),
    "cloud": ComputeEnv("Cloud (AWS t2.xlarge)", 0.1856, 0.33, 19.56, 355.2),
    "local": ComputeEnv("Local", 0.0913, 0.81, 1.64, 386.0),
}

# storage pricing (paper §2.2)
ACCRE_STORAGE_PER_TB_YEAR = 180.0
GLACIER_PER_GB_MONTH = 0.0036
SELF_HOSTED_407TB_COST = 72000.0 / 4      # amortized estimate vs ACCRE's $72k/400TB


def job_cost(env: ComputeEnv, n_jobs: int, minutes_per_job: float,
             gb_transferred_per_job: float = 1.0) -> Dict[str, float]:
    """End-to-end cost/time for a batch of pipeline jobs in one environment."""
    transfer_s = gb_transferred_per_job * 8 / env.throughput_gbps \
        + env.latency_ms / 1e3
    hours = n_jobs * (minutes_per_job * 60 + transfer_s) / 3600
    return {
        "compute_hours": hours,
        "transfer_seconds_total": n_jobs * transfer_s,
        "dollars": hours * env.cost_per_hour,
    }


def paper_table1(n_jobs: int = 6) -> Dict[str, Dict[str, float]]:
    """Reproduce Table 1's bottom row: total overhead cost to run the
    6-scan FreeSurfer experiment in each environment."""
    out = {}
    for key, env in PAPER_ENVS.items():
        c = job_cost(env, n_jobs, env.freesurfer_minutes)
        out[key] = {
            "cost_per_hr": env.cost_per_hour,
            "throughput_gbps": env.throughput_gbps,
            "latency_ms": env.latency_ms,
            "minutes_per_image": env.freesurfer_minutes,
            "total_cost": round(c["dollars"], 2),
        }
    return out


def cost_ratio_cloud_vs_hpc(n_jobs: int = 6) -> float:
    t = paper_table1(n_jobs)
    return t["cloud"]["total_cost"] / t["hpc"]["total_cost"]


# ---------------------------------------------------------------------------
# TPU-pod extension: what the paper's analysis looks like for this framework
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PodEnv:
    name: str
    chips: int
    cost_per_chip_hour: float        # on-demand public pricing ballpark
    peak_flops: float = 197e12


TPU_ENVS = {
    "v5e-pod-256": PodEnv("v5e pod (256 chips)", 256, 1.2),
    "v5e-2pods": PodEnv("v5e 2 pods (512 chips)", 512, 1.2),
}


def training_run_cost(env: PodEnv, total_model_flops: float, mfu: float
                      ) -> Dict[str, float]:
    """Dollars to land a training run at a given MFU — makes the §Perf
    hillclimb's roofline fractions legible as money, the paper's core metric."""
    seconds = total_model_flops / (env.chips * env.peak_flops * mfu)
    hours = seconds / 3600
    return {"hours": hours, "dollars": hours * env.chips * env.cost_per_chip_hour}
