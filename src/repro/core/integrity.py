"""Data integrity: checksums on every transfer (paper §2.3).

The paper checksums every storage<->compute copy and kills the job on
mismatch. We provide fletcher64 (fast, used for arrays and files) and sha256
(content addressing), a verified-copy primitive, and array checksums that the
Pallas kernel in ``kernels/checksum`` computes on-device.
"""
from __future__ import annotations

import hashlib
import shutil
from pathlib import Path
from typing import Union

import numpy as np


class IntegrityError(RuntimeError):
    """Checksum mismatch — the paper's semantics: terminate the job."""


def fletcher64(data: Union[bytes, np.ndarray]) -> int:
    """Fletcher-64 over little-endian uint32 words (zero-padded tail)."""
    if isinstance(data, np.ndarray):
        data = np.ascontiguousarray(data).tobytes()
    pad = (-len(data)) % 4
    if pad:
        data = data + b"\0" * pad
    words = np.frombuffer(data, dtype="<u4").astype(np.uint64)
    mod = np.uint64(0xFFFFFFFF)
    s1 = np.uint64(0)
    s2 = np.uint64(0)
    # block the sums so intermediate values stay in range
    B = 1 << 16
    for i in range(0, len(words), B):
        blk = words[i:i + B]
        c1 = np.cumsum(blk, dtype=np.uint64)
        s2 = (s2 + np.uint64(len(blk)) * s1 + np.sum(c1, dtype=np.uint64)) % mod
        s1 = (s1 + c1[-1]) % mod
    return int((s2 << np.uint64(32)) | s1)


def sha256_file(path: Path, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


def fletcher64_file(path: Path, chunk: int = 1 << 22) -> int:
    """Streaming fletcher64 of a file (same value as one-shot)."""
    buf = Path(path).read_bytes()
    return fletcher64(buf)


def array_checksum(arr: np.ndarray) -> int:
    return fletcher64(np.ascontiguousarray(arr))


def verified_copy(src: Path, dst: Path) -> str:
    """Copy with checksum verification on both ends (paper: any mismatch
    terminates the job with an error notification)."""
    src, dst = Path(src), Path(dst)
    before = sha256_file(src)
    dst.parent.mkdir(parents=True, exist_ok=True)
    shutil.copy2(src, dst)
    after = sha256_file(dst)
    if before != after:
        dst.unlink(missing_ok=True)
        raise IntegrityError(f"checksum mismatch copying {src} -> {dst}")
    return after
