"""Data integrity: checksums on every transfer (paper §2.3).

The paper checksums every storage<->compute copy and kills the job on
mismatch. We provide fletcher64 (fast, used for arrays and files) and sha256
(content addressing), a verified-copy primitive, and array checksums that the
Pallas kernel in ``kernels/checksum`` computes on-device.

Single-pass semantics (the data-plane hot path): every primitive here reads
each byte exactly once.

* :func:`verified_copy` streams src -> dst in one pass, hashing the bytes as
  they move, fsyncs, and commits with an atomic rename — so bytes-hashed per
  byte-moved is 1, not the 3 of the naive hash(src)/copy/hash(dst) dance.
  ``paranoid=True`` adds one extra read of the *destination* to defend
  against a lying disk (2 passes total, still never re-reading the source).
* :func:`fletcher64_file` is genuinely chunked (constant memory) and returns
  the identical value to one-shot :func:`fletcher64` for any chunk size.
* :func:`sha256_load_array` / :func:`sha256_save_array` hash arrays while
  loading/saving them so the workflow engine never does the
  ``sha256_file`` + ``np.load`` double-read.
"""
from __future__ import annotations

import hashlib
import io
import os
import threading
from contextlib import contextmanager
from pathlib import Path
from typing import Tuple, Union

import numpy as np


class IntegrityError(RuntimeError):
    """Checksum mismatch — the paper's semantics: terminate the job."""


# ---------------------------------------------------------------------------
# fletcher64
# ---------------------------------------------------------------------------

_MOD = np.uint64(0xFFFFFFFF)
_BLK = 1 << 16          # block the sums so intermediates stay in uint64 range


def _fletcher_update(words: np.ndarray, s1: np.uint64, s2: np.uint64
                     ) -> Tuple[np.uint64, np.uint64]:
    """Fold a word block into running (s1, s2); associative with streaming."""
    for i in range(0, len(words), _BLK):
        blk = words[i:i + _BLK]
        c1 = np.cumsum(blk, dtype=np.uint64)
        s2 = (s2 + np.uint64(len(blk)) * s1 + np.sum(c1, dtype=np.uint64)) % _MOD
        s1 = (s1 + c1[-1]) % _MOD
    return s1, s2


def fletcher64(data: Union[bytes, np.ndarray]) -> int:
    """Fletcher-64 over little-endian uint32 words (zero-padded tail)."""
    if isinstance(data, np.ndarray):
        data = np.ascontiguousarray(data).tobytes()
    pad = (-len(data)) % 4
    if pad:
        data = data + b"\0" * pad
    words = np.frombuffer(data, dtype="<u4").astype(np.uint64)
    s1 = np.uint64(0)
    s2 = np.uint64(0)
    if len(words):
        s1, s2 = _fletcher_update(words, s1, s2)
    return int((s2 << np.uint64(32)) | s1)


def fletcher64_file(path: Path, chunk: int = 1 << 22) -> int:
    """Streaming fletcher64 of a file: constant memory, one read pass, and
    the identical value to ``fletcher64(path.read_bytes())``."""
    s1 = np.uint64(0)
    s2 = np.uint64(0)
    tail = b""
    with open(path, "rb") as f:
        while True:
            buf = f.read(chunk)
            if not buf:
                break
            buf = tail + buf
            cut = len(buf) - (len(buf) % 4)
            tail = buf[cut:]
            if cut:
                words = np.frombuffer(buf[:cut], dtype="<u4").astype(np.uint64)
                s1, s2 = _fletcher_update(words, s1, s2)
    if tail:                      # zero-pad the final partial word
        words = np.frombuffer(tail + b"\0" * ((-len(tail)) % 4),
                              dtype="<u4").astype(np.uint64)
        s1, s2 = _fletcher_update(words, s1, s2)
    return int((s2 << np.uint64(32)) | s1)


def sha256_file(path: Path, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


def array_checksum(arr: np.ndarray) -> int:
    return fletcher64(np.ascontiguousarray(arr))


# ---------------------------------------------------------------------------
# single-pass array I/O (hash while moving the bytes)
# ---------------------------------------------------------------------------

@contextmanager
def atomic_commit(path: Path, *, fsync: bool = True):
    """Write-then-rename commit protocol, shared by every writer here.

    Yields ``(file_handle, tmp_path)`` for an exclusive tmp file; on clean
    exit fsyncs and atomically renames onto ``path`` (a concurrent reader
    never sees a torn file; racing writers each commit whole-file, last
    rename wins). On exception the tmp file is removed and ``path`` is
    untouched."""
    path = Path(path)
    tmp = path.with_name(f".{path.name}.tmp-{os.getpid()}-{threading.get_ident()}")
    try:
        with open(tmp, "wb") as f:
            yield f, tmp
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        tmp.unlink(missing_ok=True)


def atomic_write_bytes(path: Path, data: bytes, *, fsync: bool = True):
    """Commit ``data`` to ``path`` via :func:`atomic_commit`."""
    with atomic_commit(path, fsync=fsync) as (f, _):
        f.write(data)


def sha256_load_array(path: Path) -> Tuple[np.ndarray, str]:
    """Load a .npy file and its sha256 with ONE read of the file."""
    data = Path(path).read_bytes()
    digest = hashlib.sha256(data).hexdigest()
    arr = np.load(io.BytesIO(data), allow_pickle=False)
    return arr, digest


def sha256_save_array(path: Path, arr: np.ndarray) -> str:
    """Serialize ``arr`` to ``path`` (atomic tmp+rename) and return the
    sha256 of the written bytes — hashed in memory, never re-read."""
    buf = io.BytesIO()
    np.save(buf, arr)
    data = buf.getvalue()
    digest = hashlib.sha256(data).hexdigest()
    atomic_write_bytes(path, data)
    return digest


# ---------------------------------------------------------------------------
# verified copy
# ---------------------------------------------------------------------------

def verified_copy(src: Path, dst: Path, *, paranoid: bool = False,
                  chunk: int = 1 << 20) -> str:
    """Copy with checksum capture in a single streaming pass.

    Reads the source exactly once, hashing each chunk as it is written to a
    temp file; fsyncs and atomically renames onto ``dst`` (a concurrent
    reader never sees a torn file, and racing copies commit whole-file).
    ``paranoid=True`` re-reads the destination once and raises
    :class:`IntegrityError` on mismatch (paper semantics: any mismatch
    terminates the job with an error notification)."""
    src, dst = Path(src), Path(dst)
    dst.parent.mkdir(parents=True, exist_ok=True)
    h = hashlib.sha256()
    with atomic_commit(dst) as (fout, tmp):
        with open(src, "rb") as fin:
            while True:
                b = fin.read(chunk)
                if not b:
                    break
                h.update(b)
                fout.write(b)
        digest = h.hexdigest()
        if paranoid:
            fout.flush()
            after = sha256_file(tmp)
            if after != digest:
                raise IntegrityError(
                    f"checksum mismatch copying {src} -> {dst}: "
                    f"wrote {digest}, read back {after}")
    return digest
