"""Streaming chunked ingest: verify bytes *while* they move (paper §2.3).

The paper's headline number is storage↔compute transfer throughput
(0.60 Gb/s lab network vs 0.33 Gb/s cloud), yet load-then-verify ingestion
pays for every byte twice on exactly that axis: once to move it, once more
(on the host, after the transfer finishes) to hash and QA it. Following
Kulkarni et al., *Resource-Efficient Streaming of Large-Scale Medical Image
Datasets* (PAPERS.md), this module chunks the storage→host→device path so
the three verification stages all overlap the transfer itself:

  storage ──chunk──▶ host ──┬─▶ incremental sha256           (integrity)
           (prefetch        ├─▶ fused QA+checksum fold       (device QA)
            thread)         │     kernels/checksum
                            └─▶ host→device chunk staging    (DMA rides the
                                                              fold dispatch)

* **Prefetch overlap** — a reader thread pulls chunk *n+1* off storage
  while chunk *n* is hashed and folded, so the link and the host never wait
  on each other (bounded lookahead: one chunk in flight).
* **Incremental sha256** — the digest provenance records is finished the
  moment the last chunk lands; there is no post-transfer hashing pass.
* **Chunked device QA** — :class:`~repro.kernels.checksum
  .QAChecksumAccumulator` folds each chunk through the fused Pallas
  QA+checksum kernel (s1/s2 transfer checksum + min/max/sum/finite_count
  carried across launches), bit-exact with the one-shot ``qa_stats`` the
  resident path runs. Each fold stages its chunk host→device and dispatches
  asynchronously; only the final verdict read blocks.
* **Honest fallbacks** — non-npy bytes, unsupported dtypes, Fortran-order
  payloads, or a truncated stream degrade to hash-only (``qa=None``); the
  sha256 is always computed and always identical to the resident path's.

Per-stage wall times land in a :class:`StreamReport` — ``overlap_s`` is the
time the pipeline saved versus running the stages back-to-back — which the
callers stamp into provenance (``stream``) and ``InputCache.stats()``.

Runbook knobs (docs/operating.md): ``REPRO_STREAM_CHUNK_MB`` sizes the
chunk (default 4 MiB), ``REPRO_STREAM_INGEST=0`` disables streaming
everywhere and restores the load-then-verify sequence.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import queue
import threading
import time
from pathlib import Path
from typing import Iterable, Iterator, List, Optional, Tuple

import numpy as np

STREAM_ENV = "REPRO_STREAM_INGEST"
CHUNK_MB_ENV = "REPRO_STREAM_CHUNK_MB"
DEFAULT_CHUNK_BYTES = 4 << 20
MIN_CHUNK_BYTES = 64 << 10


def stream_enabled() -> bool:
    """Streaming is the default data plane; ``REPRO_STREAM_INGEST=0`` is
    the kill switch back to load-then-verify."""
    return os.environ.get(STREAM_ENV, "1").lower() not in ("0", "", "false")


def stream_chunk_bytes() -> int:
    """Chunk size from ``REPRO_STREAM_CHUNK_MB`` (floored to 64 KiB so the
    per-chunk dispatch overhead cannot swamp the overlap win)."""
    mb = os.environ.get(CHUNK_MB_ENV)
    if not mb:
        return DEFAULT_CHUNK_BYTES
    try:
        return max(int(float(mb) * (1 << 20)), MIN_CHUNK_BYTES)
    except ValueError:
        return DEFAULT_CHUNK_BYTES


@dataclasses.dataclass
class StreamReport:
    """Per-stage wall time of one streamed transfer. ``read_s`` is time on
    the storage (or peer) link, ``hash_s`` host sha256 time, ``device_s``
    chunk staging + QA fold dispatch (plus the final verdict sync);
    ``wall_s`` is end-to-end. Because the stages run overlapped,
    ``overlap_s = read_s + hash_s + device_s - wall_s`` is the time the
    pipeline saved versus running them sequentially (clamped at 0)."""
    nbytes: int = 0
    chunks: int = 0
    chunk_bytes: int = 0
    read_s: float = 0.0
    hash_s: float = 0.0
    device_s: float = 0.0
    wall_s: float = 0.0
    device_qa: bool = False
    files: int = 1

    @property
    def overlap_s(self) -> float:
        return max(0.0, self.read_s + self.hash_s + self.device_s
                   - self.wall_s)

    def to_dict(self) -> dict:
        return {"nbytes": self.nbytes, "chunks": self.chunks,
                "chunk_bytes": self.chunk_bytes, "files": self.files,
                "read_s": round(self.read_s, 6),
                "hash_s": round(self.hash_s, 6),
                "device_s": round(self.device_s, 6),
                "wall_s": round(self.wall_s, 6),
                "overlap_s": round(self.overlap_s, 6),
                "device_qa": self.device_qa}

    @classmethod
    def from_dict(cls, d: dict) -> "StreamReport":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def merge(self, other: "StreamReport"):
        """Fold another transfer's report in (per-unit aggregation across a
        unit's input files)."""
        self.nbytes += other.nbytes
        self.chunks += other.chunks
        self.chunk_bytes = max(self.chunk_bytes, other.chunk_bytes)
        self.read_s += other.read_s
        self.hash_s += other.hash_s
        self.device_s += other.device_s
        self.wall_s += other.wall_s
        self.device_qa = self.device_qa or other.device_qa
        self.files += other.files


# ---------------------------------------------------------------------------
# chunk sources
# ---------------------------------------------------------------------------

def file_chunks(path: Path, chunk_bytes: int) -> Iterator[bytes]:
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk_bytes)
            if not b:
                return
            yield b


def bytes_chunks(data: bytes, chunk_bytes: int) -> Iterator[bytes]:
    view = memoryview(data)
    for off in range(0, len(data), chunk_bytes):
        yield bytes(view[off:off + chunk_bytes])
    if not data:
        return


class _Prefetcher:
    """One-chunk-lookahead reader: a daemon thread drains the source
    iterator into a depth-2 queue, timing each pull — chunk *n+1* moves off
    the link while the consumer hashes and folds chunk *n*. Source
    exceptions re-raise at the consumer (a failed read must fail the load,
    not truncate it silently)."""

    _DONE = object()

    def __init__(self, source: Iterable[bytes]):
        self.read_s = 0.0
        self._q: "queue.Queue[object]" = queue.Queue(maxsize=2)
        self._thread = threading.Thread(
            target=self._pump, args=(iter(source),), daemon=True,
            name="stream-prefetch")
        self._thread.start()

    def _pump(self, it: Iterator[bytes]):
        try:
            while True:
                t0 = time.perf_counter()
                try:
                    chunk = next(it)
                except StopIteration:
                    break
                self.read_s += time.perf_counter() - t0
                self._q.put(chunk)
            self._q.put(self._DONE)
        except BaseException as e:  # noqa: BLE001 — handed to the consumer
            self._q.put(e)

    def __iter__(self) -> Iterator[bytes]:
        while True:
            got = self._q.get()
            if got is self._DONE:
                return
            if isinstance(got, BaseException):
                raise got
            yield got  # type: ignore[misc]


# ---------------------------------------------------------------------------
# npy header sniffing (for in-flight device QA over the payload)
# ---------------------------------------------------------------------------

def _try_parse_npy_header(buf: bytes
                          ) -> Optional[Tuple[np.dtype, tuple, bool, int]]:
    """``(dtype, shape, fortran_order, payload_offset)`` once ``buf`` holds
    the complete npy header; ``None`` while more bytes are needed. Raises
    ``ValueError`` for bytes that are not an npy file at all."""
    if len(buf) < 10:
        if not b"\x93NUMPY".startswith(buf[:6]):
            raise ValueError("not an npy stream")
        return None
    if buf[:6] != b"\x93NUMPY":
        raise ValueError("not an npy stream")
    major = buf[6]
    if major == 1:
        hlen = int.from_bytes(buf[8:10], "little")
        off = 10 + hlen
    else:
        if len(buf) < 12:
            return None
        hlen = int.from_bytes(buf[8:12], "little")
        off = 12 + hlen
    if len(buf) < off:
        return None
    fp = io.BytesIO(buf[:off])
    version = np.lib.format.read_magic(fp)
    shape, fortran, dtype = np.lib.format._read_array_header(fp, version)
    return np.dtype(dtype), tuple(shape), bool(fortran), off


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

def stream_chunks(chunks: Iterable[bytes], *, npy_qa: bool = False,
                  chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                  qa_backend: str = "auto", interpret=None, prefetch=None,
                  ) -> Tuple[bytes, str, Optional[object], StreamReport]:
    """Drive one transfer through the overlap pipeline.

    Returns ``(data, sha256_hex, qa_stats_or_None, report)``. ``data`` is
    the fully assembled byte string (callers still need the bytes — to
    ``np.load``, to insert into the blob cache, to write to disk); the
    win is that hashing, QA, and device staging happened *during* the
    transfer instead of after it. With ``npy_qa`` the npy header is sniffed
    off the first chunks and the payload folded through
    :class:`~repro.kernels.checksum.QAChecksumAccumulator`; anything the
    accumulator cannot fold bit-exactly (non-npy bytes, unsupported dtype,
    Fortran order, truncation) degrades to ``qa=None`` — never an error and
    never a wrong verdict. ``prefetch`` (a :class:`_Prefetcher`) lets
    callers that already own the read thread contribute its link time."""
    t_wall = time.perf_counter()
    h = hashlib.sha256()
    parts: List[bytes] = []
    rep = StreamReport(chunk_bytes=chunk_bytes, device_qa=False)
    acc = None
    qa_dead = not npy_qa
    head = b""                     # buffered prefix until the header parses
    payload_fed = 0                # payload bytes already folded
    payload_off = 0
    n_payload = 0
    for chunk in chunks:
        parts.append(chunk)
        rep.chunks += 1
        rep.nbytes += len(chunk)
        t0 = time.perf_counter()
        h.update(chunk)
        rep.hash_s += time.perf_counter() - t0
        if qa_dead:
            continue
        if acc is None:
            head += chunk
            try:
                parsed = _try_parse_npy_header(head)
            except ValueError:
                qa_dead = True
                head = b""
                continue
            if parsed is None:
                continue
            dtype, shape, fortran, payload_off = parsed
            n_vals = int(np.prod(shape, dtype=np.int64)) if shape else 1
            if fortran or dtype.hasobject:
                qa_dead = True
                head = b""
                continue
            try:
                from ..kernels.checksum import QAChecksumAccumulator
                acc = QAChecksumAccumulator(n_vals, dtype,
                                            backend=qa_backend,
                                            interpret=interpret)
            except ValueError:         # dtype the fold can't do bit-exactly
                qa_dead = True
                head = b""
                continue
            n_payload = n_vals * dtype.itemsize
            chunk = head[payload_off:]
            head = b""
        try:
            take = chunk[:n_payload - payload_fed]
            if take:
                acc.update(take)
                payload_fed += len(take)
        except ValueError:             # overrun vs the declared shape
            qa_dead = True
            acc = None
    qa = None
    if acc is not None:
        try:
            qa = acc.finalize()
            rep.device_s += acc.device_seconds
            rep.device_qa = True
        except ValueError:             # truncated vs the declared shape
            qa = None
    if prefetch is not None:
        rep.read_s += prefetch.read_s
    rep.wall_s = time.perf_counter() - t_wall
    return b"".join(parts), h.hexdigest(), qa, rep


def stream_file(path: Path, *, chunk_bytes: Optional[int] = None,
                npy_qa: bool = False, qa_backend: str = "auto",
                interpret=None
                ) -> Tuple[bytes, str, Optional[object], StreamReport]:
    """Stream one file off storage through the overlap pipeline — the
    drop-in for ``read_bytes()`` + ``sha256(data)`` (+ one-shot QA). The
    digest is byte-identical to the resident path's."""
    cb = chunk_bytes or stream_chunk_bytes()
    pf = _Prefetcher(file_chunks(Path(path), cb))
    return stream_chunks(pf, npy_qa=npy_qa, chunk_bytes=cb,
                         qa_backend=qa_backend, interpret=interpret,
                         prefetch=pf)


def stream_load_npy(path: Path, *, chunk_bytes: Optional[int] = None,
                    device_qa: bool = False, qa_backend: str = "auto",
                    interpret=None
                    ) -> Tuple[np.ndarray, str, Optional[object],
                               StreamReport]:
    """Verify-and-load an .npy with the digest (and optionally the fused
    QA+checksum verdict) computed in-flight: the streaming twin of
    :func:`repro.core.integrity.sha256_load_array` — same
    ``(array, digest)`` contract, no post-transfer hashing pass."""
    data, digest, qa, rep = stream_file(path, chunk_bytes=chunk_bytes,
                                        npy_qa=device_qa,
                                        qa_backend=qa_backend,
                                        interpret=interpret)
    arr = np.load(io.BytesIO(data), allow_pickle=False)
    return arr, digest, qa, rep


def stream_verify_bytes(data: bytes, *, chunk_bytes: Optional[int] = None,
                        npy_qa: bool = True, qa_backend: str = "auto",
                        interpret=None
                        ) -> Tuple[str, Optional[object], StreamReport]:
    """Chunk an in-memory buffer through the pipeline (the ingest path:
    the serialized volume is already on the host, but sha256, the QA fold,
    and device staging still run per-chunk — on an accelerator the fold
    dispatch overlaps the next chunk's hashing). Returns
    ``(sha256_hex, qa_stats_or_None, report)``."""
    cb = chunk_bytes or stream_chunk_bytes()
    _, digest, qa, rep = stream_chunks(bytes_chunks(data, cb), npy_qa=npy_qa,
                                       chunk_bytes=cb, qa_backend=qa_backend,
                                       interpret=interpret)
    return digest, qa, rep
