"""Data acquisition & organization (paper §2.1).

The paper receives DICOM/NIFTI from providers, converts DICOM→NIfTI with
dcm2niix (producing JSON sidecars), filters scans by protocol / resolution /
matrix dimensions plus a fast visual QA, and lays files out as BIDS.

Here the scanner hand-off is a directory of raw dumps: ``<id>.raw.npz``
(voxel array + acquisition metadata — our DICOM stand-in). Ingestion:

  1. convert: raw.npz → .npy volume + .json sidecar (dcm2niix analogue),
     carrying acquisition metadata through; corrupted dumps are quarantined
     with a reason (the paper asks providers for complete versions).
  2. filter: protocol allow-list, resolution / matrix-dimension bounds.
  3. fast QA: intensity sanity (finite, non-constant, SNR proxy); with
     ``device_qa`` the finite/constant/mean passes and the transfer checksum
     fuse into ONE Pallas kernel launch per volume (kernels/checksum). With
     streaming on (the default, ``repro.core.stream``) the serialized volume
     is chunked through the fold + an incremental sha256, so the integrity
     digest and the QA verdict land together — no second host-side pass —
     and the recorded checksum is bit-identical to the one-shot kernel's.
  4. organize: BIDS tree ``sub-*/ses-*/<modality>/...`` + manifest scan.
     Accepted volumes and the ingestion report commit via atomic
     tmp+fsync+rename (a crash mid-ingest never leaves a torn file).

Everything is recorded in an ingestion report (the paper's curation trail).
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

from . import stream as stream_mod
from .integrity import atomic_write_bytes
from .manifest import DatasetManifest

PROTOCOL_MODALITY = {"T1w": "anat", "T2w": "anat", "dwi": "dwi", "bold": "func"}


@dataclasses.dataclass
class IngestRule:
    allowed_protocols: Tuple[str, ...] = ("T1w", "dwi")
    min_resolution_mm: float = 0.5
    max_resolution_mm: float = 3.0
    min_matrix: int = 8
    min_snr: float = 1.0


@dataclasses.dataclass
class IngestRecord:
    source: str
    status: str                  # ok | corrupted | filtered | failed_qa
    reason: str = ""
    dest: str = ""
    checksum: str = ""           # fused-QA device checksum (device_qa mode)
    sha256: str = ""             # content digest of the committed .npy bytes


def write_raw_dump(path: Path, vol: np.ndarray, *, subject: str, session: str,
                   protocol: str, resolution_mm: float = 1.0):
    """Scanner-side helper (tests/examples): one raw dump per acquisition."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, vol=vol, meta=json.dumps({
        "subject": subject, "session": session, "protocol": protocol,
        "resolution_mm": resolution_mm, "matrix": list(vol.shape)}))


def _convert(raw: Path) -> Tuple[Optional[np.ndarray], Optional[dict], str]:
    """dcm2niix analogue: raw dump → (volume, sidecar) or a rejection reason."""
    try:
        with np.load(raw, allow_pickle=False) as z:
            vol = z["vol"]
            meta = json.loads(str(z["meta"]))
    except Exception as e:  # noqa: BLE001 — corrupted provider data
        return None, None, f"corrupted: {type(e).__name__}"
    for key in ("subject", "session", "protocol", "resolution_mm"):
        if key not in meta:
            return None, None, f"missing metadata: {key}"
    return vol, meta, ""


def _bg_corner(vol: np.ndarray) -> np.ndarray:
    """Corner octant (air) used as the SNR-proxy background region."""
    c = tuple(slice(0, max(s // 4, 1)) for s in vol.shape[:3])
    return vol[c]


def _fast_qa(vol: np.ndarray, rule: IngestRule) -> str:
    # float32 throughout — the dtype the fused kernel reduces in and the
    # dtype ingest stores. Reducing in the volume's native dtype diverged
    # from the device verdict on float16 input (std/mean overflow to inf at
    # modest intensities), accepting scans on one path and rejecting the
    # same bytes on the other.
    vol = np.asarray(vol, dtype=np.float32)
    if not np.all(np.isfinite(vol)):
        return "non-finite voxels"
    if float(vol.std()) == 0.0:
        return "constant image"
    # SNR proxy: foreground mean over background std (corner octant = air)
    bg = _bg_corner(vol)
    snr = float(np.abs(vol.mean()) / (bg.std() + 1e-6))
    if snr < rule.min_snr:
        return f"low SNR proxy ({snr:.2f})"
    return ""


def _qa_verdict(st, vol: np.ndarray, rule: IngestRule) -> str:
    """The fused-kernel QA decision, shared by the one-shot and streamed
    paths: ``st`` is a ``QAStats`` (from ``qa_stats`` or the chunk
    accumulator — bit-identical either way), ``vol`` the float32 volume
    (only its corner octant is touched, for the SNR background std)."""
    if st.finite_count < vol.size:
        return "non-finite voxels"
    if st.vmin == st.vmax:
        return "constant image"
    mean = st.vsum / max(vol.size, 1)
    bg = _bg_corner(vol)
    snr = float(abs(mean) / (bg.std() + 1e-6))
    if snr < rule.min_snr:
        return f"low SNR proxy ({snr:.2f})"
    return ""


def _fast_qa_fused(vol: np.ndarray, rule: IngestRule) -> Tuple[str, str]:
    """QA + transfer checksum in ONE device pass (kernels/checksum).

    Returns ``(reason, checksum_hex)``. Semantically equivalent to
    :func:`_fast_qa` — the full-volume finite / constant / mean passes come
    from the fused kernel's (min, max, sum, finite_count); only the SNR
    background std still touches the tiny corner octant (1/64 of voxels) on
    the host. The checksum rides along for free and is recorded so the BIDS
    transfer can be verified without another read; it is computed over the
    float32 view — the exact dtype :func:`ingest_directory` stores — so a
    later device-side pass over the saved array reproduces it."""
    from ..kernels.checksum import qa_stats
    vol = np.ascontiguousarray(vol, dtype=np.float32)
    st = qa_stats(vol)
    return _qa_verdict(st, vol, rule), f"{st.checksum:016x}"


def _fast_qa_streamed(vol: np.ndarray, rule: IngestRule
                      ) -> Tuple[str, str, str, bytes,
                                 stream_mod.StreamReport]:
    """The streaming twin of :func:`_fast_qa_fused`: serialize the float32
    volume once, then chunk those bytes through the incremental sha256 and
    the chunk-accumulating fused kernel fold (``repro.core.stream``), so the
    content digest of the exact bytes about to be committed and the QA
    verdict land together — no load-then-verify second pass, and on an
    accelerator each chunk's fold dispatch overlaps the next chunk's
    hashing. Returns ``(reason, checksum_hex, sha256_hex, npy_bytes,
    report)``; the checksum is bit-identical to the one-shot kernel's
    (same blocks, same fold order) and ``npy_bytes`` is what
    :func:`ingest_directory` commits, so digest == sha256 of the file."""
    vol = np.ascontiguousarray(vol, dtype=np.float32)
    buf = io.BytesIO()
    np.save(buf, vol)
    data = buf.getvalue()
    digest, st, rep = stream_mod.stream_verify_bytes(data)
    if st is None:       # cannot happen for a C-order float32 .npy; be safe
        reason, checksum = _fast_qa_fused(vol, rule)
    else:
        reason, checksum = _qa_verdict(st, vol, rule), f"{st.checksum:016x}"
    return reason, checksum, digest, data, rep


def ingest_directory(raw_dir: Path, bids_root: Path, dataset: str,
                     rule: Optional[IngestRule] = None,
                     device_qa: Optional[bool] = None
                     ) -> Tuple[DatasetManifest, List[IngestRecord]]:
    """Run the paper's §2.1 pipeline over a directory of raw dumps.

    ``device_qa=True`` routes the fast-QA stage through the fused Pallas
    QA+checksum kernel — one device pass per volume instead of ~5 numpy
    passes — and records the transfer checksum on each accepted scan.
    Defaults to the ``REPRO_DEVICE_QA`` env var (off). With streaming on
    (the default; ``REPRO_STREAM_INGEST=0`` disables) the device-QA path
    chunks the serialized volume through the fold + an incremental sha256
    (``repro.core.stream``), committing exactly the verified bytes and
    recording their content digest on each record."""
    # construct the default per call: a shared mutable default instance
    # would leak one caller's rule edits into every later call
    rule = IngestRule() if rule is None else rule
    if device_qa is None:
        device_qa = os.environ.get("REPRO_DEVICE_QA", "0").lower() \
            not in ("0", "", "false")
    streaming = stream_mod.stream_enabled()
    raw_dir, bids_root = Path(raw_dir), Path(bids_root)
    records: List[IngestRecord] = []
    stream_rep: Optional[stream_mod.StreamReport] = None
    for raw in sorted(raw_dir.glob("*.npz")):
        vol, meta, err = _convert(raw)
        if err:
            records.append(IngestRecord(raw.name, "corrupted", err))
            continue
        proto = meta["protocol"]
        if proto not in rule.allowed_protocols:
            records.append(IngestRecord(raw.name, "filtered",
                                        f"protocol {proto} not in allow-list"))
            continue
        res = float(meta["resolution_mm"])
        if not (rule.min_resolution_mm <= res <= rule.max_resolution_mm):
            records.append(IngestRecord(raw.name, "filtered",
                                        f"resolution {res}mm out of bounds"))
            continue
        if min(vol.shape[:3]) < rule.min_matrix:
            records.append(IngestRecord(raw.name, "filtered",
                                        f"matrix {vol.shape} too small"))
            continue
        payload: Optional[bytes] = None
        digest = ""
        if device_qa and streaming:
            qa, checksum, digest, payload, rep = _fast_qa_streamed(vol, rule)
            if stream_rep is None:
                stream_rep = rep
            else:
                stream_rep.merge(rep)
        elif device_qa:
            qa, checksum = _fast_qa_fused(vol, rule)
        else:
            qa, checksum = _fast_qa(vol, rule), ""
        if qa:
            records.append(IngestRecord(raw.name, "failed_qa", qa,
                                        checksum=checksum))
            continue
        # BIDS placement + JSON sidecar (dcm2niix behaviour)
        sub, ses = meta["subject"], meta["session"]
        modality = PROTOCOL_MODALITY.get(proto, "anat")
        base = bids_root / dataset / f"sub-{sub}" / f"ses-{ses}" / modality
        base.mkdir(parents=True, exist_ok=True)
        stem = f"sub-{sub}_ses-{ses}_{proto}"
        if payload is None:
            buf = io.BytesIO()
            np.save(buf, np.ascontiguousarray(vol, dtype=np.float32))
            payload = buf.getvalue()
            digest = hashlib.sha256(payload).hexdigest()
        # commit the exact bytes the QA/digest pass saw, atomically
        atomic_write_bytes(base / f"{stem}.npy", payload)
        atomic_write_bytes(base / f"{stem}.json",
                           json.dumps(meta, indent=1).encode(), fsync=False)
        records.append(IngestRecord(raw.name, "ok",
                                    dest=str(base / f"{stem}.npy"),
                                    checksum=checksum, sha256=digest))
    manifest = DatasetManifest.scan(bids_root / dataset, name=dataset)
    report = {
        "dataset": dataset,
        "counts": {s: sum(r.status == s for r in records)
                   for s in ("ok", "corrupted", "filtered", "failed_qa")},
        "records": [dataclasses.asdict(r) for r in records],
    }
    if stream_rep is not None:
        report["stream"] = stream_rep.to_dict()
    rp = bids_root / dataset / "ingestion_report.json"
    rp.parent.mkdir(parents=True, exist_ok=True)
    # tmp + fsync + rename (journal discipline): a crash mid-write must
    # never leave a torn curation trail next to committed volumes
    atomic_write_bytes(rp, json.dumps(report, indent=1).encode())
    _fsync_dir(rp.parent)
    return manifest, records


def _fsync_dir(path: Path):
    """fsync a directory so a just-renamed report survives power loss
    (same discipline as ``repro.dist.journal.write_units``); best-effort on
    filesystems that reject directory fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)
