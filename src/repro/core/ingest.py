"""Data acquisition & organization (paper §2.1).

The paper receives DICOM/NIFTI from providers, converts DICOM→NIfTI with
dcm2niix (producing JSON sidecars), filters scans by protocol / resolution /
matrix dimensions plus a fast visual QA, and lays files out as BIDS.

Here the scanner hand-off is a directory of raw dumps: ``<id>.raw.npz``
(voxel array + acquisition metadata — our DICOM stand-in). Ingestion:

  1. convert: raw.npz → .npy volume + .json sidecar (dcm2niix analogue),
     carrying acquisition metadata through; corrupted dumps are quarantined
     with a reason (the paper asks providers for complete versions).
  2. filter: protocol allow-list, resolution / matrix-dimension bounds.
  3. fast QA: intensity sanity (finite, non-constant, SNR proxy); with
     ``device_qa`` the finite/constant/mean passes and the transfer checksum
     fuse into ONE Pallas kernel launch per volume (kernels/checksum).
  4. organize: BIDS tree ``sub-*/ses-*/<modality>/...`` + manifest scan.

Everything is recorded in an ingestion report (the paper's curation trail).
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from .manifest import DatasetManifest

PROTOCOL_MODALITY = {"T1w": "anat", "T2w": "anat", "dwi": "dwi", "bold": "func"}


@dataclasses.dataclass
class IngestRule:
    allowed_protocols: Tuple[str, ...] = ("T1w", "dwi")
    min_resolution_mm: float = 0.5
    max_resolution_mm: float = 3.0
    min_matrix: int = 8
    min_snr: float = 1.0


@dataclasses.dataclass
class IngestRecord:
    source: str
    status: str                  # ok | corrupted | filtered | failed_qa
    reason: str = ""
    dest: str = ""
    checksum: str = ""           # fused-QA device checksum (device_qa mode)


def write_raw_dump(path: Path, vol: np.ndarray, *, subject: str, session: str,
                   protocol: str, resolution_mm: float = 1.0):
    """Scanner-side helper (tests/examples): one raw dump per acquisition."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, vol=vol, meta=json.dumps({
        "subject": subject, "session": session, "protocol": protocol,
        "resolution_mm": resolution_mm, "matrix": list(vol.shape)}))


def _convert(raw: Path) -> Tuple[Optional[np.ndarray], Optional[dict], str]:
    """dcm2niix analogue: raw dump → (volume, sidecar) or a rejection reason."""
    try:
        with np.load(raw, allow_pickle=False) as z:
            vol = z["vol"]
            meta = json.loads(str(z["meta"]))
    except Exception as e:  # noqa: BLE001 — corrupted provider data
        return None, None, f"corrupted: {type(e).__name__}"
    for key in ("subject", "session", "protocol", "resolution_mm"):
        if key not in meta:
            return None, None, f"missing metadata: {key}"
    return vol, meta, ""


def _bg_corner(vol: np.ndarray) -> np.ndarray:
    """Corner octant (air) used as the SNR-proxy background region."""
    c = tuple(slice(0, max(s // 4, 1)) for s in vol.shape[:3])
    return vol[c]


def _fast_qa(vol: np.ndarray, rule: IngestRule) -> str:
    if not np.all(np.isfinite(vol)):
        return "non-finite voxels"
    if float(vol.std()) == 0.0:
        return "constant image"
    # SNR proxy: foreground mean over background std (corner octant = air)
    bg = _bg_corner(vol)
    snr = float(np.abs(vol.mean()) / (bg.std() + 1e-6))
    if snr < rule.min_snr:
        return f"low SNR proxy ({snr:.2f})"
    return ""


def _fast_qa_fused(vol: np.ndarray, rule: IngestRule) -> Tuple[str, str]:
    """QA + transfer checksum in ONE device pass (kernels/checksum).

    Returns ``(reason, checksum_hex)``. Semantically equivalent to
    :func:`_fast_qa` — the full-volume finite / constant / mean passes come
    from the fused kernel's (min, max, sum, finite_count); only the SNR
    background std still touches the tiny corner octant (1/64 of voxels) on
    the host. The checksum rides along for free and is recorded so the BIDS
    transfer can be verified without another read; it is computed over the
    float32 view — the exact dtype :func:`ingest_directory` stores — so a
    later device-side pass over the saved array reproduces it."""
    from ..kernels.checksum import qa_stats
    vol = np.ascontiguousarray(vol, dtype=np.float32)
    st = qa_stats(vol)
    checksum = f"{st.checksum:016x}"
    if st.finite_count < vol.size:
        return "non-finite voxels", checksum
    if st.vmin == st.vmax:
        return "constant image", checksum
    mean = st.vsum / max(vol.size, 1)
    bg = _bg_corner(vol)
    snr = float(abs(mean) / (bg.std() + 1e-6))
    if snr < rule.min_snr:
        return f"low SNR proxy ({snr:.2f})", checksum
    return "", checksum


def ingest_directory(raw_dir: Path, bids_root: Path, dataset: str,
                     rule: IngestRule = IngestRule(),
                     device_qa: Optional[bool] = None
                     ) -> Tuple[DatasetManifest, List[IngestRecord]]:
    """Run the paper's §2.1 pipeline over a directory of raw dumps.

    ``device_qa=True`` routes the fast-QA stage through the fused Pallas
    QA+checksum kernel — one device pass per volume instead of ~5 numpy
    passes — and records the transfer checksum on each accepted scan.
    Defaults to the ``REPRO_DEVICE_QA`` env var (off)."""
    if device_qa is None:
        device_qa = os.environ.get("REPRO_DEVICE_QA", "0").lower() \
            not in ("0", "", "false")
    raw_dir, bids_root = Path(raw_dir), Path(bids_root)
    records: List[IngestRecord] = []
    for raw in sorted(raw_dir.glob("*.npz")):
        vol, meta, err = _convert(raw)
        if err:
            records.append(IngestRecord(raw.name, "corrupted", err))
            continue
        proto = meta["protocol"]
        if proto not in rule.allowed_protocols:
            records.append(IngestRecord(raw.name, "filtered",
                                        f"protocol {proto} not in allow-list"))
            continue
        res = float(meta["resolution_mm"])
        if not (rule.min_resolution_mm <= res <= rule.max_resolution_mm):
            records.append(IngestRecord(raw.name, "filtered",
                                        f"resolution {res}mm out of bounds"))
            continue
        if min(vol.shape[:3]) < rule.min_matrix:
            records.append(IngestRecord(raw.name, "filtered",
                                        f"matrix {vol.shape} too small"))
            continue
        if device_qa:
            qa, checksum = _fast_qa_fused(vol, rule)
        else:
            qa, checksum = _fast_qa(vol, rule), ""
        if qa:
            records.append(IngestRecord(raw.name, "failed_qa", qa,
                                        checksum=checksum))
            continue
        # BIDS placement + JSON sidecar (dcm2niix behaviour)
        sub, ses = meta["subject"], meta["session"]
        modality = PROTOCOL_MODALITY.get(proto, "anat")
        base = bids_root / dataset / f"sub-{sub}" / f"ses-{ses}" / modality
        base.mkdir(parents=True, exist_ok=True)
        stem = f"sub-{sub}_ses-{ses}_{proto}"
        np.save(base / f"{stem}.npy", vol.astype(np.float32))
        (base / f"{stem}.json").write_text(json.dumps(meta, indent=1))
        records.append(IngestRecord(raw.name, "ok",
                                    dest=str(base / f"{stem}.npy"),
                                    checksum=checksum))
    manifest = DatasetManifest.scan(bids_root / dataset, name=dataset)
    report = {
        "dataset": dataset,
        "counts": {s: sum(r.status == s for r in records)
                   for s in ("ok", "corrupted", "filtered", "failed_qa")},
        "records": [dataclasses.asdict(r) for r in records],
    }
    rp = bids_root / dataset / "ingestion_report.json"
    rp.parent.mkdir(parents=True, exist_ok=True)
    rp.write_text(json.dumps(report, indent=1))
    return manifest, records
