"""Tiered storage (paper §2.2, Fig. 3).

Three tiers with the paper's cost/bandwidth characteristics:
  * HOT    — near-line RAID server (407 TB, high bandwidth, low latency)
  * SECURE — GDPR-compliant server (266 TB), surfaced into the general
             namespace via symlinks for authorized users only
  * COLD   — Glacier-style archive ($0.0036/GB-month), nightly backup target

Every put/get is checksummed (IntegrityError on mismatch). Transfers are
accounted (bytes, simulated seconds from tier bandwidth) so the cost model
and benchmarks can reproduce the paper's Table 1 without real networks.
"""
from __future__ import annotations

import dataclasses
import os
import time
from pathlib import Path
from typing import Dict, Optional

from .integrity import IntegrityError, sha256_file, verified_copy


@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    bandwidth_gbps: float          # Gb/s, paper Table 1
    latency_ms: float
    cost_per_tb_year: float


# paper-derived characteristics (HPC storage column + Glacier pricing)
TIERS: Dict[str, TierSpec] = {
    "hot": TierSpec("hot", 0.60, 0.16, 180.0 / 4),   # self-hosted RAID vs ACCRE $180
    "secure": TierSpec("secure", 0.60, 0.16, 180.0 / 4),
    "cold": TierSpec("cold", 0.25, 4000.0, 0.0036 * 1000 * 12),
}


@dataclasses.dataclass
class TransferLog:
    n_transfers: int = 0
    bytes_moved: int = 0
    simulated_seconds: float = 0.0
    wall_seconds: float = 0.0

    def record(self, nbytes: int, tier: TierSpec, wall: float):
        self.n_transfers += 1
        self.bytes_moved += nbytes
        self.simulated_seconds += tier.latency_ms / 1e3 + \
            nbytes * 8 / (tier.bandwidth_gbps * 1e9)
        self.wall_seconds += wall


class TieredStore:
    """Filesystem-backed tiered object store with checksummed transfers.

    Every transfer is a single-pass ``verified_copy`` (bytes hashed while
    they move). ``paranoid=True`` additionally re-reads each transfer's
    destination to defend against silent media corruption (one extra read
    pass per transfer — the paper's belt-and-braces mode)."""

    def __init__(self, root: Path, authorized_secure: bool = True,
                 paranoid: bool = False):
        self.root = Path(root)
        self.authorized_secure = authorized_secure
        self.paranoid = paranoid
        self.log: Dict[str, TransferLog] = {k: TransferLog() for k in TIERS}
        for t in TIERS:
            (self.root / t).mkdir(parents=True, exist_ok=True)

    def _tier_dir(self, tier: str) -> Path:
        if tier not in TIERS:
            raise KeyError(tier)
        if tier == "secure" and not self.authorized_secure:
            raise PermissionError("not authorized for the secure (GDPR) tier")
        return self.root / tier

    def put(self, src: Path, key: str, tier: str = "hot") -> str:
        dst = self._tier_dir(tier) / key
        t0 = time.time()
        digest = verified_copy(src, dst, paranoid=self.paranoid)
        self.log[tier].record(dst.stat().st_size, TIERS[tier], time.time() - t0)
        return digest

    def get(self, key: str, dst: Path, tier: str = "hot",
            expect_sha256: Optional[str] = None) -> str:
        src = self._tier_dir(tier) / key
        t0 = time.time()
        digest = verified_copy(src, dst, paranoid=self.paranoid)
        if expect_sha256 and digest != expect_sha256:
            raise IntegrityError(f"{key}: expected {expect_sha256}, got {digest}")
        self.log[tier].record(Path(dst).stat().st_size, TIERS[tier], time.time() - t0)
        return digest

    def exists(self, key: str, tier: str = "hot") -> bool:
        return (self.root / tier / key).exists()

    def link_secure_into_general(self, key: str) -> Path:
        """The paper's symlink arrangement: secure data appears in the general
        namespace for authorized users without duplicating bytes."""
        if not self.authorized_secure:
            raise PermissionError("not authorized for the secure (GDPR) tier")
        src = self.root / "secure" / key
        dst = self.root / "hot" / key
        dst.parent.mkdir(parents=True, exist_ok=True)
        if dst.is_symlink() or dst.exists():
            dst.unlink()
        os.symlink(src, dst)
        return dst

    def archive_to_cold(self, key: str, src_tier: str = "hot") -> str:
        """Nightly Glacier-style backup (paper §2.2)."""
        return self.put(self.root / src_tier / key, key, tier="cold")

    def storage_cost_per_year(self) -> Dict[str, float]:
        out = {}
        for t in TIERS:
            nbytes = sum(p.stat().st_size for p in (self.root / t).rglob("*")
                         if p.is_file() and not p.is_symlink())
            out[t] = nbytes / 1e12 * TIERS[t].cost_per_tb_year
        return out
