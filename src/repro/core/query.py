"""Automated work query (paper §2.3): given a dataset manifest and a pipeline,
return exactly the sessions that (a) have the required inputs and (b) have no
completed, digest-matching derivative — plus a CSV of excluded sessions with
the cause (the paper's accompanying CSV)."""
from __future__ import annotations

import csv
import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Tuple

from .manifest import DatasetManifest, ImageRecord
from .pipelines import Pipeline
from .provenance import is_complete


@dataclasses.dataclass
class WorkUnit:
    dataset: str
    subject: str
    session: str
    pipeline: str
    pipeline_digest: str
    inputs: Dict[str, str]          # suffix -> path relative to dataset root
    out_dir: str                    # derivatives/<pipeline>/sub-x/ses-y
    # data-plane shape of the unit, straight from the manifest: content
    # digests and sizes per input suffix. The cluster queue scores these
    # against per-node cache summaries to place the unit where its bytes
    # already live (locality-aware scheduling, docs/cluster.md). Both default
    # empty so pre-existing units JSON (and manifests scanned with
    # checksum=False, whose digests are "") keep working — the unit is then
    # locality-blind, never broken.
    input_digests: Dict[str, str] = dataclasses.field(default_factory=dict)
    input_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)
    # Multi-stage curation DAGs: job_ids of units whose committed ok
    # provenance this unit's inputs are derived from (stage N outputs are
    # stage N+1 inputs — PyCURT's sort → label → convert → database).
    # The cluster queue grants a unit only once every parent listed here is
    # terminally ok/skipped; the campaign planner admits it to the shard
    # where the parents' outputs land (producer placement, docs/cluster.md).
    # Parents not present in the same queue/campaign count as satisfied —
    # the work query already excludes complete work, so a missing parent
    # means "done before this submission", not "unknowable".
    depends_on: List[str] = dataclasses.field(default_factory=list)

    @property
    def job_id(self) -> str:
        return f"{self.dataset}_{self.pipeline}_sub-{self.subject}_ses-{self.session}"

    @property
    def total_input_bytes(self) -> int:
        return sum(self.input_bytes.values())


@dataclasses.dataclass
class Exclusion:
    subject: str
    session: str
    reason: str


def query_available_work(manifest: DatasetManifest, pipeline: Pipeline, *,
                         leases: Optional[Mapping[str, str]] = None
                         ) -> Tuple[List[WorkUnit], List[Exclusion]]:
    """Sessions with the required inputs and no completed digest-matching
    derivative. ``leases`` (``job_id -> node_id``, e.g.
    ``WorkQueue.active_leases()``) additionally excludes sessions currently
    leased to a cluster node, so a second submitter racing a live cluster
    never double-schedules in-flight work — the exclusion CSV names the
    holding node."""
    work: List[WorkUnit] = []
    excluded: List[Exclusion] = []
    digest = pipeline.digest()
    leases = leases or {}
    for (sub, ses), recs in sorted(manifest.sessions().items()):
        by_suffix: Dict[str, ImageRecord] = {}
        for r in recs:
            by_suffix.setdefault(r.suffix, r)
        missing = [s for s in pipeline.spec.required_suffixes if s not in by_suffix]
        if missing:
            excluded.append(Exclusion(sub, ses, f"missing input(s): {','.join(missing)}"))
            continue
        out_dir = (Path(manifest.root) / "derivatives" / pipeline.name /
                   f"sub-{sub}" / f"ses-{ses}")
        if is_complete(out_dir, digest):
            excluded.append(Exclusion(sub, ses, "already processed (digest match)"))
            continue
        req = pipeline.spec.required_suffixes
        wu = WorkUnit(
            dataset=manifest.name, subject=sub, session=ses,
            pipeline=pipeline.name, pipeline_digest=digest,
            inputs={s: by_suffix[s].path for s in req},
            out_dir=str(out_dir),
            input_digests={s: by_suffix[s].sha256 for s in req
                           if by_suffix[s].sha256},
            input_bytes={s: by_suffix[s].size_bytes for s in req})
        if wu.job_id in leases:
            excluded.append(Exclusion(sub, ses,
                                      f"leased by {leases[wu.job_id]}"))
            continue
        work.append(wu)
    return work, excluded


def units_to_rows(units: List[WorkUnit]) -> List[dict]:
    """The JSON-row shape of a unit list — the one serialization every
    durable artifact shares (units JSON files, campaign shards, the
    coordinator journal's snapshot). ``depends_on`` is written only when
    non-empty: independent units keep the exact pre-DAG shape, so an old
    ``load_units`` still accepts them; a DAG unit fed to an old coordinator
    fails its ``WorkUnit(**u)`` with an unexpected-keyword ``TypeError``
    instead of silently running children before parents (version-skew
    fail-soft, docs/cluster.md)."""
    rows = []
    for u in units:
        d = dataclasses.asdict(u)
        if not d.get("depends_on"):
            d.pop("depends_on", None)
        rows.append(d)
    return rows


def units_from_rows(rows: List[dict]) -> List[WorkUnit]:
    """Inverse of :func:`units_to_rows` (missing digest fields —
    pre-locality rows — default empty: locality-blind, never broken; a
    missing ``depends_on`` key — pre-DAG rows — loads as an independent
    unit)."""
    return [WorkUnit(**u) for u in rows]


def dump_units(units: List[WorkUnit], path: Path) -> Path:
    """Serialize a unit list to the units-JSON artifact every execution path
    shares (SLURM array tasks, ``repro.dist.rpc serve``, campaign shards).
    Full-fidelity: the data-plane fields (``input_digests``/``input_bytes``)
    travel too, so a queue built from the file schedules locality-aware."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(units_to_rows(units), indent=1))
    return path


def load_units(path: Path) -> List[WorkUnit]:
    """Reload a :func:`dump_units` artifact into :class:`WorkUnit` objects
    identical to the originals."""
    return units_from_rows(json.loads(Path(path).read_text()))


def write_exclusion_csv(excluded: List[Exclusion], path: Path):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["subject", "session", "reason"])
        for e in excluded:
            w.writerow([e.subject, e.session, e.reason])
