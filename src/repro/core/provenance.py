"""Provenance records (paper §2.3): every derivative ships with a config file
recording when it ran, who ran it, the exact inputs (with checksums), and the
pipeline's content digest — file-level reproducibility years later."""
from __future__ import annotations

import dataclasses
import getpass
import json
import platform
import time
from pathlib import Path
from typing import Dict, List, Optional

PROVENANCE_NAME = "provenance.json"


@dataclasses.dataclass
class Provenance:
    pipeline: str
    pipeline_digest: str           # content hash of config+code ("container digest")
    user: str
    started_at: float
    finished_at: float
    inputs: Dict[str, str]         # path -> sha256
    outputs: Dict[str, str]
    status: str                    # ok | failed
    host: str = ""
    error: Optional[str] = None
    attempt: int = 1
    # multi-node execution (repro.dist.cluster): which node committed this
    # record and under which lease epoch. Epoch 0 = single-host execution;
    # a requeued unit's new lease bumps the epoch, so records tell apart a
    # first-run commit from a post-node-death re-run years later.
    node_id: str = ""
    lease_epoch: int = 0
    # True iff every input array was served from the host's content-addressed
    # input cache (repro.dist.cache) instead of shared storage. The input
    # checksums recorded above are identical either way (a cache hit re-hashes
    # the local bytes), so this flag is pure data-plane provenance: it lets a
    # reader audit which commits never touched the storage link.
    cache_hit: bool = False
    # Data-movement provenance for locality-aware scheduling: the fraction of
    # this unit's input bytes the coordinator *estimated* were already local
    # when it granted the lease (the placement score, from the node's digest
    # summary), and the input bytes that *actually* came off node-local disk.
    # Comparing the two audits the scheduler: a high score with low
    # bytes_from_cache means a stale summary or Bloom false positive.
    locality_score: float = 0.0
    bytes_from_cache: int = 0
    # Peer-fabric provenance (repro.dist.blobserve): True iff at least one
    # input blob was streamed from another host's cache instead of shared
    # storage, and how many bytes came over peer links. Peer bytes are
    # sha256-re-verified on arrival against the manifest digest, so the
    # recorded input checksums are identical across cache/peer/storage
    # origins — like cache_hit, this is pure data-plane provenance.
    peer_fetch: bool = False
    bytes_from_peer: int = 0
    # Streaming-ingest provenance (repro.core.stream): the per-unit
    # StreamReport dict when this commit's inputs were verified in-flight —
    # digests (and, when enabled, the fused device QA fold) computed
    # chunk-by-chunk while the bytes crossed the storage or peer link, with
    # per-stage wall times and the overlap the pipeline won. None when every
    # input was served resident or streaming was disabled; the recorded
    # input checksums are identical either way.
    stream: Optional[Dict] = None

    def save(self, out_dir: Path):
        """Atomic write (tmp + rename): a concurrent reader — or a racing
        speculative duplicate — never observes a torn provenance file."""
        from .integrity import atomic_write_bytes
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        atomic_write_bytes(out_dir / PROVENANCE_NAME,
                           json.dumps(dataclasses.asdict(self), indent=1).encode())

    @classmethod
    def load(cls, out_dir: Path) -> Optional["Provenance"]:
        p = Path(out_dir) / PROVENANCE_NAME
        if not p.exists():
            return None
        try:
            return cls(**json.loads(p.read_text()))
        except (json.JSONDecodeError, TypeError):
            return None


def make_provenance(pipeline: str, digest: str, inputs: Dict[str, str],
                    outputs: Dict[str, str], started: float, status: str = "ok",
                    error: Optional[str] = None, attempt: int = 1,
                    node_id: str = "", lease_epoch: int = 0,
                    cache_hit: bool = False, locality_score: float = 0.0,
                    bytes_from_cache: int = 0, peer_fetch: bool = False,
                    bytes_from_peer: int = 0,
                    stream: Optional[Dict] = None) -> Provenance:
    return Provenance(
        pipeline=pipeline, pipeline_digest=digest,
        user=getpass.getuser(), host=platform.node(),
        started_at=started, finished_at=time.time(),
        inputs=inputs, outputs=outputs, status=status, error=error,
        attempt=attempt, node_id=node_id, lease_epoch=lease_epoch,
        cache_hit=cache_hit, locality_score=locality_score,
        bytes_from_cache=bytes_from_cache, peer_fetch=peer_fetch,
        bytes_from_peer=bytes_from_peer, stream=stream)


def is_complete(out_dir: Path, digest: Optional[str] = None) -> bool:
    """A derivative counts as done iff its provenance says ok — and, when a
    digest is given, was produced by the same pipeline version (a changed
    pipeline re-runs everything, the paper's reproducibility contract)."""
    prov = Provenance.load(out_dir)
    if prov is None or prov.status != "ok":
        return False
    return digest is None or prov.pipeline_digest == digest
