"""Campaign planner: locality-aware admission at ``generate_jobs`` scale.

PR 4 made placement cache-aware at *grant* time, inside one live
:class:`~repro.dist.queue.WorkQueue`. The paper's actual entry point is
batch admission — the automated query turned into a job array — and that
array was placement-blind: every SLURM task landed wherever the scheduler
had room, then pulled its inputs across the storage link. This module moves
the same scoring to *admission* time (brainlife.io's job-to-data routing at
the batch-system layer), and makes the resulting plan a deterministic,
replayable artifact (Clinica's campaign-level reproducibility argument):

* **Cohorts in** — N ``(manifest, pipeline)`` cohorts, each reduced by
  :func:`~repro.core.query.query_available_work` to admitted units +
  exclusions (:func:`cohort_from_query`), or handed in pre-queried.
* **Summaries in** — per-host cache :class:`~repro.dist.cache.DigestSummary`
  snapshots, pulled from a live coordinator
  (:func:`summaries_from_queue` over ``repro.dist.rpc``) or loaded from a
  serialized summaries file for offline HPC planning
  (:func:`repro.dist.cache.load_summary_file` /
  :func:`~repro.dist.cache.summaries_from_cache_dirs`).
* **Plan out** — a :class:`CampaignPlan`: every admitted unit bucketed into
  exactly one shard, warm shards pinned to the node holding their bytes,
  cold units (no warm host anywhere) in an untargeted shard. Scoring is the
  **same function the queue uses at grant time**
  (:func:`repro.dist.placement.unit_local_bytes`), so admission and grant
  ranking cannot drift. Admission throttling is derived from
  :func:`~repro.core.workflow.resource_status` (the paper's query-before-
  submit discipline).

The plan serializes to a canonical ``campaign.json`` — sorted keys, no
timestamps, stamped with a sha256 over its *inputs* — so replanning from
identical inputs is byte-identical and an auditor can tell exactly which
data/summary state produced a submitted campaign. Both execution paths
consume it: :func:`~repro.core.workflow.generate_jobs` writes one SLURM
array script per shard (``campaign=``/``summaries=``), and
``WorkQueue``/``ClusterRunner`` accept ``plan=`` to seed their backlog
partitions so a cluster starts warm instead of rediscovering locality.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import json
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from ..dist.cache import DigestSummary
from ..dist.placement import best_node, unit_local_bytes
from .manifest import DatasetManifest
from .pipelines import Pipeline
from .query import Exclusion, WorkUnit, query_available_work

CAMPAIGN_VERSION = 1
DEFAULT_THROTTLE = 100


@dataclasses.dataclass
class Cohort:
    """One (dataset, pipeline) slice of a campaign: the admitted units plus
    the exclusions the query produced (the planner re-checks the exclusion
    list, so an excluded session can never be admitted by construction)."""
    dataset: str
    pipeline: str
    pipeline_digest: str
    units: List[WorkUnit]
    excluded: List[Exclusion] = dataclasses.field(default_factory=list)


def cohort_from_query(manifest: DatasetManifest, pipeline: Pipeline,
                      *, leases=None) -> Cohort:
    """The paper's automated query, packaged as a campaign cohort."""
    units, excluded = query_available_work(manifest, pipeline, leases=leases)
    return Cohort(manifest.name, pipeline.name, pipeline.digest(),
                  units, excluded)


@dataclasses.dataclass
class Shard:
    """One admission bucket = one SLURM job array = one seeded node deque.
    ``node_id=None`` marks the cold shard (no warm host for these units)."""
    shard_id: str
    node_id: Optional[str]
    unit_ids: List[str]                 # job_ids, admission order
    est_local_bytes: int                # Σ scorer estimate on the target
    est_total_bytes: int                # Σ total input bytes


@dataclasses.dataclass
class CampaignPlan:
    """The deterministic, replayable admission artifact.

    ``inputs_hash`` is a sha256 over the canonicalized planner inputs
    (cohort units + exclusions, summary wires, knobs, resource status), so
    two plans agree byte-for-byte iff they were computed from the same
    world-state — the campaign-level reproducibility check."""
    version: int
    inputs_hash: str
    cohorts: List[dict]                 # per-cohort admission accounting
    nodes: List[str]                    # summary-backed node ids, sorted
    shards: List[Shard]
    throttle: int                       # resource-derived admission throttle
    excluded: List[dict]                # every excluded session, with reason
    resource: dict = dataclasses.field(default_factory=dict)

    # -- introspection -------------------------------------------------------

    def assigned_unit_ids(self) -> List[str]:
        """Every assigned job_id, in shard order (each exactly once)."""
        return [jid for s in self.shards for jid in s.unit_ids]

    def est_local_fraction(self) -> float:
        """Planner's estimate of the input-byte fraction served node-local."""
        total = sum(s.est_total_bytes for s in self.shards)
        local = sum(s.est_local_bytes for s in self.shards)
        return local / total if total else 0.0

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> str:
        """Canonical encoding: sorted keys, fixed indent, trailing newline —
        byte-identical across replans from identical inputs."""
        return json.dumps(dataclasses.asdict(self), sort_keys=True,
                          indent=1) + "\n"

    def save(self, path: Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def from_dict(cls, d: dict, source: str = "campaign plan"
                  ) -> "CampaignPlan":
        """Reconstruct from the parsed ``campaign.json`` shape. The version
        check lives here so every intake path (file, pre-parsed dict)
        rejects a future plan identically instead of misreading it."""
        if d.get("version") != CAMPAIGN_VERSION:
            raise ValueError(
                f"{source}: campaign version {d.get('version')!r}, "
                f"this build speaks {CAMPAIGN_VERSION}")
        d = dict(d)
        d["shards"] = [Shard(**s) if isinstance(s, dict) else s
                       for s in d.get("shards", [])]
        return cls(**d)

    @classmethod
    def load(cls, path: Path) -> "CampaignPlan":
        return cls.from_dict(json.loads(Path(path).read_text()), str(path))


def as_plan(obj) -> CampaignPlan:
    """Coerce whatever plan shape the caller holds — a live
    :class:`CampaignPlan`, a ``campaign.json`` path, or its parsed dict —
    into a :class:`CampaignPlan` (the replay path: resubmitting an audited
    campaign without re-planning)."""
    if isinstance(obj, CampaignPlan):
        return obj
    if isinstance(obj, (str, Path)):
        return CampaignPlan.load(obj)
    if isinstance(obj, dict):
        return CampaignPlan.from_dict(obj)
    raise TypeError(f"cannot interpret {type(obj).__name__} as a campaign "
                    "plan (expected CampaignPlan, path, or parsed dict)")


# ---------------------------------------------------------------------------
# summary intake: live coordinator, serialized file, or in-memory objects
# ---------------------------------------------------------------------------

def summaries_from_queue(queue_or_addr) -> Dict[str, dict]:
    """Per-node summary wires from a live coordinator: an in-process
    :class:`~repro.dist.queue.WorkQueue`, an open
    :class:`~repro.dist.rpc.QueueClient`, or a ``"host:port"`` string (a
    one-shot client is dialed and closed)."""
    if isinstance(queue_or_addr, str):
        from ..dist.rpc import QueueClient, parse_addr
        client = QueueClient(parse_addr(queue_or_addr))
        try:
            return client.summaries_snapshot()
        finally:
            client.close()
    return queue_or_addr.summaries_snapshot()


def _normalize_summaries(summaries) -> Dict[str, DigestSummary]:
    """Decode whatever summary shape the caller holds — live
    :class:`DigestSummary` objects, ``summaries_snapshot`` wires, raw
    ``to_wire`` payloads, or a summaries-file path — into per-node filters.
    Undecodable wires (version skew, garbage) drop that node to blind,
    mirroring the coordinator's fail-soft."""
    if summaries is None:
        return {}
    if isinstance(summaries, (str, Path)):
        from ..dist.cache import load_summary_file
        summaries = load_summary_file(summaries)
    out: Dict[str, DigestSummary] = {}
    for node_id, s in summaries.items():
        if isinstance(s, DigestSummary):
            out[str(node_id)] = s
            continue
        wire = s.get("full", s) if isinstance(s, dict) else s
        decoded = DigestSummary.from_wire(wire)
        if decoded is not None:
            out[str(node_id)] = decoded
    return out


# ---------------------------------------------------------------------------
# admission throttling: the resource query gating how hard we submit
# ---------------------------------------------------------------------------

def admission_throttle(status: Optional[Mapping[str, float]],
                       max_unit_bytes: int,
                       requested: int = DEFAULT_THROTTLE) -> int:
    """Cap the SLURM array throttle (``%N``) so concurrent tasks' scratch
    footprint stays inside the submit host's free disk. Each in-flight task
    holds roughly its inputs plus outputs (~2x inputs); keeping the
    concurrent total under half of free disk leaves headroom for everything
    else on the filesystem. Deterministic in its inputs; degenerate status
    (no free-disk reading, zero-byte units) keeps the requested throttle."""
    requested = max(1, int(requested))
    if not status or max_unit_bytes <= 0:
        return requested
    free = float(status.get("disk_free_gb", 0.0)) * 2**30
    if free <= 0:
        return requested
    cap = int(free // (4 * max_unit_bytes))
    return max(1, min(requested, cap))


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

class _PlannedView:
    """Duck-typed summary union the scorer reads during DAG admission: the
    node's *real* cache summary plus the digests the plan predicts will land
    there (outputs of parents already assigned to the node, which write
    through into the host cache the moment they commit). Implements exactly
    the surface :func:`~repro.dist.placement.unit_local_bytes` probes
    (``len`` + ``in``), so producer placement flows through the same shared
    scorer as every other placement decision — rankings cannot drift."""
    __slots__ = ("real", "planned")

    def __init__(self, real: DigestSummary, planned: set):
        self.real = real
        self.planned = planned

    def __contains__(self, digest) -> bool:
        return digest in self.planned or digest in self.real

    def __len__(self) -> int:
        return len(self.real) + len(self.planned)


def plan_campaign(cohorts: Sequence[Cohort], summaries=None, *,
                  throttle: int = DEFAULT_THROTTLE,
                  status: Optional[Mapping[str, float]] = None,
                  max_shard_units: Optional[int] = None) -> CampaignPlan:
    """Bucket N cohorts' admitted units into per-node shards by the shared
    placement score.

    Deterministic: units are admitted in cohort order then query order and
    assigned in dependency (topological) order — stable by admission order,
    so a dependency-free campaign assigns in exactly the admission walk —
    with nodes ranked by ``(-local_bytes, assigned_bytes, node_id)``.
    Replanning from identical inputs yields a byte-identical plan.
    Guarantees (property-tested): every admitted unit lands in exactly one
    shard; a session the cohort excluded is never assigned; a unit admitted
    by several cohorts (overlapping manifests) is assigned once, under its
    first admission.

    **Producer placement** (multi-stage DAGs): a parent's placement *is*
    the next stage's locality — its outputs write through into the host
    cache where it runs — so when a unit is assigned to a node, the input
    digests its ``depends_on`` children declare are folded into that node's
    planned warm set, and the children (assigned later: topological order)
    score those predicted bytes through the same scorer as real summary
    bytes. Children then shard to the node where their parents' outputs
    will land. A ``depends_on`` cycle among admitted units raises
    ``ValueError``; edges to job_ids outside the campaign count as
    satisfied and score nothing.

    ``max_shard_units`` splits a node's bucket into multiple arrays (site
    ``MaxArraySize`` limits); ``status`` (a
    :func:`~repro.core.workflow.resource_status` dict) tightens the
    admission throttle. With no usable summaries every unit is cold and the
    plan degrades to one untargeted shard — blind admission, exactly what
    ``generate_jobs`` emitted before this module existed."""
    decoded = _normalize_summaries(summaries)
    nodes = sorted(decoded)
    status = dict(status or {})

    # pass 1 — admission: cohort order, exclusion re-check, first-cohort
    # dedup. Placement waits for pass 2 so parents are placed before the
    # children that score against their predicted outputs.
    admitted_units: List[WorkUnit] = []
    seen: set = set()
    cohort_rows: List[dict] = []
    excluded_rows: List[dict] = []
    max_unit_bytes = 0
    for cohort in cohorts:
        excl_keys = {(e.subject, e.session) for e in cohort.excluded}
        admitted = 0
        for e in cohort.excluded:
            excluded_rows.append({
                "dataset": cohort.dataset, "pipeline": cohort.pipeline,
                "subject": e.subject, "session": e.session,
                "reason": e.reason})
        for u in cohort.units:
            if (u.subject, u.session) in excl_keys or u.job_id in seen:
                continue
            seen.add(u.job_id)
            admitted += 1
            max_unit_bytes = max(max_unit_bytes, u.total_input_bytes)
            admitted_units.append(u)
        cohort_rows.append({
            "dataset": cohort.dataset, "pipeline": cohort.pipeline,
            "pipeline_digest": cohort.pipeline_digest,
            "admitted": admitted, "excluded": len(cohort.excluded)})

    # DAG edges among admitted units + predicted outputs per parent: a
    # child's declared input digests are, by definition of depends_on, bytes
    # its parents' commits will produce
    by_job = {u.job_id: k for k, u in enumerate(admitted_units)}
    children: Dict[int, List[int]] = {}
    indeg: Dict[int, int] = {}
    produced: Dict[int, set] = {}
    for k, u in enumerate(admitted_units):
        ps = {by_job[str(d)] for d in getattr(u, "depends_on", None) or ()
              if str(d) in by_job}
        if not ps:
            continue
        indeg[k] = len(ps)
        child_digests = set((u.input_digests or {}).values())
        for p in ps:
            children.setdefault(p, []).append(k)
            if child_digests:
                produced.setdefault(p, set()).update(child_digests)

    # pass 2 — assignment in topological order, stable by admission index
    # (a heap of ready units), so a dependency-free campaign walks exactly
    # the admission order the old single-pass planner did
    heap = [k for k in range(len(admitted_units)) if k not in indeg]
    heapq.heapify(heap)
    planned: Dict[str, set] = {n: set() for n in nodes}
    views = {n: _PlannedView(decoded[n], planned[n]) for n in nodes}
    assigned: Dict[str, List[WorkUnit]] = {n: [] for n in nodes}
    scores: Dict[str, int] = {}                      # job_id -> grant score
    loads: Dict[str, int] = {n: 0 for n in nodes}    # Σ bytes, tie-break
    cold: List[WorkUnit] = []
    placed = 0
    while heap:
        k = heapq.heappop(heap)
        placed += 1
        u = admitted_units[k]
        target = best_node(u, nodes, views, loads) if nodes else None
        score = (unit_local_bytes(u, views[target])
                 if target is not None else 0)
        if target is None or score <= 0:
            cold.append(u)
        else:
            assigned[target].append(u)
            scores[u.job_id] = score
            loads[target] += u.total_input_bytes
            planned[target].update(produced.get(k, ()))
        for c in children.get(k, ()):
            indeg[c] -= 1
            if indeg[c] == 0:
                del indeg[c]
                heapq.heappush(heap, c)
    if placed < len(admitted_units):
        cyc = sorted(admitted_units[k].job_id for k in indeg)
        raise ValueError(
            "depends_on cycle among admitted units: " + ", ".join(cyc))

    def chunks(units: List[WorkUnit]) -> List[List[WorkUnit]]:
        if not max_shard_units or max_shard_units < 1:
            return [units] if units else []
        return [units[i:i + max_shard_units]
                for i in range(0, len(units), max_shard_units)]

    shards: List[Shard] = []
    for node_id in nodes:
        for i, chunk in enumerate(chunks(assigned[node_id])):
            shards.append(Shard(
                shard_id=f"shard-{len(shards):03d}", node_id=node_id,
                unit_ids=[u.job_id for u in chunk],
                est_local_bytes=sum(scores[u.job_id] for u in chunk),
                est_total_bytes=sum(u.total_input_bytes for u in chunk)))
    for chunk in chunks(cold):
        shards.append(Shard(
            shard_id=f"shard-{len(shards):03d}", node_id=None,
            unit_ids=[u.job_id for u in chunk], est_local_bytes=0,
            est_total_bytes=sum(u.total_input_bytes for u in chunk)))

    return CampaignPlan(
        version=CAMPAIGN_VERSION,
        inputs_hash=_inputs_hash(cohorts, decoded, throttle, status,
                                 max_shard_units),
        cohorts=cohort_rows, nodes=nodes, shards=shards,
        throttle=admission_throttle(status, max_unit_bytes, throttle),
        excluded=excluded_rows, resource=status)


def _inputs_hash(cohorts: Sequence[Cohort],
                 decoded: Mapping[str, DigestSummary], throttle: int,
                 status: Mapping[str, float],
                 max_shard_units: Optional[int]) -> str:
    """sha256 over the canonicalized planner inputs — the stamp that makes
    two byte-identical plans mean 'planned from the same world-state'."""
    payload = {
        "version": CAMPAIGN_VERSION,
        "cohorts": [{
            "dataset": c.dataset, "pipeline": c.pipeline,
            "pipeline_digest": c.pipeline_digest,
            "units": [dataclasses.asdict(u) for u in c.units],
            "excluded": [dataclasses.asdict(e) for e in c.excluded],
        } for c in cohorts],
        "summaries": {n: s.to_wire() for n, s in sorted(decoded.items())},
        "throttle": throttle,
        "status": {k: status[k] for k in sorted(status)},
        "max_shard_units": max_shard_units,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()
