from .pipeline import DataPipeline, ShardedTokenSource, make_lm_batches

__all__ = ["DataPipeline", "ShardedTokenSource", "make_lm_batches"]
