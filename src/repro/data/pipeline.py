"""Deterministic, resumable, integrity-checked data pipeline.

The paper's data plane applied to training: data lives as checksummed shard
files in a manifest; the loader's *query* is "which (epoch, step) batches has
this run not consumed" — exactly-once, restart-safe. A background prefetch
thread double-buffers host->device transfers (compute never waits on I/O),
and every shard read is checksum-verified (corrupted storage fails loudly,
as in the paper's transfer protocol).
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
from pathlib import Path
from typing import Dict, Iterator, List, Optional

import numpy as np

from ..core.integrity import IntegrityError, fletcher64


@dataclasses.dataclass
class ShardInfo:
    path: str
    n_tokens: int
    fletcher64: int


class ShardedTokenSource:
    """Token shards on disk with a manifest; deterministic global order."""

    MANIFEST = "shards.json"

    def __init__(self, root: Path):
        self.root = Path(root)
        m = json.loads((self.root / self.MANIFEST).read_text())
        self.shards = [ShardInfo(**s) for s in m["shards"]]
        self.vocab_size = m["vocab_size"]

    @classmethod
    def synthesize(cls, root: Path, *, n_shards: int = 4, tokens_per_shard: int = 65536,
                   vocab_size: int = 512, seed: int = 0) -> "ShardedTokenSource":
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        rng = np.random.default_rng(seed)
        shards = []
        for i in range(n_shards):
            toks = rng.integers(0, vocab_size, tokens_per_shard, dtype=np.int32)
            p = root / f"shard_{i:05d}.npy"
            np.save(p, toks)
            shards.append(ShardInfo(path=p.name, n_tokens=int(toks.size),
                                    fletcher64=fletcher64(toks)))
        (root / cls.MANIFEST).write_text(json.dumps(
            {"vocab_size": vocab_size,
             "shards": [dataclasses.asdict(s) for s in shards]}, indent=1))
        return cls(root)

    def load_shard(self, idx: int) -> np.ndarray:
        info = self.shards[idx]
        arr = np.load(self.root / info.path)
        if fletcher64(arr) != info.fletcher64:
            raise IntegrityError(f"shard {info.path} corrupted")
        return arr


class DataPipeline:
    """Deterministic batches of (tokens, targets); resumable from any step."""

    def __init__(self, source: ShardedTokenSource, *, batch: int, seq_len: int,
                 seed: int = 0, prefetch: int = 2,
                 dp_rank: int = 0, dp_size: int = 1):
        self.source = source
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.dp_rank, self.dp_size = dp_rank, dp_size
        self.prefetch = prefetch
        total = sum(s.n_tokens for s in source.shards)
        self.steps_per_epoch = max(total // (batch * (seq_len + 1)), 1)
        self._tokens: Optional[np.ndarray] = None

    def _all_tokens(self) -> np.ndarray:
        if self._tokens is None:
            self._tokens = np.concatenate(
                [self.source.load_shard(i) for i in range(len(self.source.shards))])
        return self._tokens

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step) — restartable & reproducible."""
        epoch = step // self.steps_per_epoch
        idx = step % self.steps_per_epoch
        rng = np.random.default_rng((self.seed, epoch))
        order = rng.permutation(self.steps_per_epoch)
        toks = self._all_tokens()
        span = self.batch * (self.seq + 1)
        start = int(order[idx]) * span
        window = toks[start:start + span]
        if window.size < span:
            window = np.pad(window, (0, span - window.size))
        window = window.reshape(self.batch, self.seq + 1)
        # DP slice for this host
        per = self.batch // self.dp_size
        window = window[self.dp_rank * per:(self.dp_rank + 1) * per]
        return {"tokens": window[:, :-1].astype(np.int32),
                "targets": window[:, 1:].astype(np.int32)}

    def iter_from(self, start_step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator starting at ``start_step`` (resume point)."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(s), timeout=0.1)
                    s += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_lm_batches(cfg, batch: int, seq: int, n: int, seed: int = 0
                    ) -> List[Dict[str, np.ndarray]]:
    """Quick synthetic batches for tests/benchmarks (no disk)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        toks = rng.integers(0, cfg.vocab_size, (batch, seq + 1), dtype=np.int32)
        out.append({"tokens": toks[:, :-1], "targets": toks[:, 1:]})
    return out
