"""moonshot-v1-16b-a3b (Moonlight) [moe] — 64 experts, top-6, softmax-then-topk.
[hf:moonshotai/Moonlight-16B-A3B]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    d_head=128,
    rope_theta=50_000.0,
    mlp="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, capacity_factor=1.25),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
