"""Model/arch configuration system.

Every assigned architecture is a ``ModelConfig``. Configs are immutable
dataclasses; their canonical JSON serialization is hashed to produce the
"container digest" used for provenance (the paper's Singularity-image
content-address, adapted — see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # layers where MoE replaces the dense MLP; "all" or every Nth
    every: int = 1            # 1 = every layer is MoE
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) configuration."""
    d_state: int = 64
    d_head: int = 64
    expand: int = 2
    chunk: int = 256
    d_conv: int = 4


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64      # rank of the data-dependent decay LoRA
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec archs (whisper). Frontend is a stub: inputs are
    precomputed frame embeddings (B, enc_seq, d_model)."""
    n_layers: int = 12
    enc_seq: int = 1500


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """Vision frontend stub: precomputed patch embeddings (B, n_patches, d_model)."""
    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None     # SWA window (h2o-danube)
    mlp: str = "swiglu"                      # swiglu | gelu
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    vlm: Optional[VLMConfig] = None
    # hybrid (zamba2): shared attention block applied every `shared_every` layers
    shared_attn_every: int = 0
    max_seq: int = 524_288
    # fused qkv / w13 column layout is interleaved in `tp_fuse` blocks so the
    # post-matmul split aligns with TP shard boundaries (no resharding
    # collectives — EXPERIMENTS.md §Perf P2). 16 = production 'model' axis;
    # archs using the 2D-TP mesh (8-way attention TP) set 8.
    tp_fuse: int = 16
    # sharding policy the launcher should pick for this arch:
    #   tp (Megatron TP+FSDP) | fsdp (pure DP, small archs) | tp2d (see mesh.py)
    preferred_policy: str = "tp"
    # gradient-accumulation microbatches for train_4k (deep models: shrinks
    # the remat-saved activation stack; §Perf G3)
    accum_steps: int = 1
    source: str = ""                         # provenance: where the config came from

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # ----- derived properties ------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm" and self.rwkv is not None or (
            self.family == "ssm" and self.ssm is not None)

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k is runnable (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        H, KV, dh = self.n_heads, self.n_kv_heads, self.d_head
        emb = V * D
        head = 0 if self.tie_embeddings else D * V
        per_layer = 0
        if self.rwkv is not None:
            # r,k,v,g,o (5 DxD) + decay lora + channel-mix (2 proj w/ F)
            per_layer = 5 * D * D + 2 * self.rwkv.decay_lora * D + D * F + F * D
        elif self.ssm is not None and self.family == "ssm":
            di = self.ssm.expand * D
            per_layer = D * (2 * di + 2 * self.ssm.d_state) + di * D + di
        else:
            attn = D * H * dh + 2 * D * KV * dh + H * dh * D
            if self.moe is not None:
                Fm = self.moe.d_ff_expert
                moe_mlp = self.moe.n_experts * (3 * D * Fm) + D * self.moe.n_experts
                n_moe = len([i for i in range(L) if i % self.moe.every == self.moe.every - 1]) \
                    if self.moe.every > 1 else L
                n_dense = L - n_moe
                per_layer = attn + (n_moe * moe_mlp + n_dense * 3 * D * F) / max(L, 1)
            else:
                k = 3 if self.mlp == "swiglu" else 2
                per_layer = attn + k * D * F
        if self.family == "hybrid" and self.ssm is not None:
            di = self.ssm.expand * D
            per_layer = D * (2 * di + 2 * self.ssm.d_state) + di * D + di
            # one shared attention+MLP block
            shared = D * H * dh + 2 * D * KV * dh + H * dh * D + 3 * D * F
            return int(emb + head + L * per_layer + shared)
        total = emb + head + L * per_layer
        if self.encoder is not None:
            enc_layer = D * H * dh * 2 + H * dh * D * 2 + 2 * D * F  # self-attn + gelu mlp
            # decoder cross-attn adds ~1 attn block per decoder layer
            total += self.encoder.n_layers * enc_layer + L * (D * H * dh + 2 * D * KV * dh + H * dh * D)
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params()
        D, L = self.d_model, self.n_layers
        Fm = self.moe.d_ff_expert
        full = self.n_params()
        all_experts = L * self.moe.n_experts * 3 * D * Fm
        active = L * self.moe.top_k * 3 * D * Fm
        return int(full - all_experts + active)

    def canonical_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)

    def digest(self) -> str:
        """Content address of this config — the 'Singularity image digest'."""
        return hashlib.sha256(self.canonical_json().encode()).hexdigest()[:16]

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test sized version of the same family."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.shared_attn_every == 0 else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=256,
            vocab_size=512,
            d_head=32,
            max_seq=512,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64)
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(self.ssm, d_state=16, d_head=32, chunk=64)
        if self.rwkv is not None:
            small["rwkv"] = dataclasses.replace(self.rwkv, head_size=32, decay_lora=8, chunk=32)
        if self.encoder is not None:
            small["encoder"] = dataclasses.replace(self.encoder, n_layers=2, enc_seq=64)
        if self.vlm is not None:
            small["vlm"] = dataclasses.replace(self.vlm, n_patches=16)
        if self.shared_attn_every:
            small["shared_attn_every"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and the reason if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode requires sub-quadratic attention (DESIGN.md §5)"
    return True, ""
