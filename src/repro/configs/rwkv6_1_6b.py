"""rwkv6-1.6b (Finch) [ssm] — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # d_model / head_size(64)
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    d_head=64,
    rwkv=RWKVConfig(head_size=64, decay_lora=64, chunk=128),
    preferred_policy="fsdp",
    source="arXiv:2404.05892",
)
