"""zamba2-1.2b [hybrid] — Mamba2 backbone + one shared attention+MLP block applied
every 6th layer (weights shared across invocations). [arXiv:2411.15242]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    d_head=64,
    ssm=SSMConfig(d_state=64, d_head=64, expand=2, chunk=256),
    shared_attn_every=6,
    preferred_policy="fsdp",
    source="arXiv:2411.15242",
)
