"""llama3.2-1b [dense] — small llama3, GQA kv=8, tied embeddings. [hf:meta-llama/Llama-3.2-1B]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    d_head=64,
    rope_theta=500_000.0,
    mlp="swiglu",
    tie_embeddings=True,
    preferred_policy="fsdp",
    source="hf:meta-llama/Llama-3.2-1B",
)
