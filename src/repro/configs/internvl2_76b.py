"""internvl2-76b [vlm] — InternLM2 backbone; InternViT frontend stubbed
(precomputed patch embeddings). [arXiv:2404.16821]"""
from .base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    d_head=128,
    rope_theta=1_000_000.0,
    mlp="swiglu",
    vlm=VLMConfig(n_patches=256),
    source="arXiv:2404.16821",
)
