"""llama4-scout-17b-a16e [moe] — 16 routed experts, top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    d_head=128,
    rope_theta=500_000.0,
    mlp="swiglu",
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, capacity_factor=1.25),
    # H=40 cannot carry a 16-way TP axis; 2D TP (attention 8-way, EP 16-way)
    # with qkv fusion interleaved at 8 — EXPERIMENTS.md §Perf L1-L4
    tp_fuse=8,
    preferred_policy="tp2d",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
