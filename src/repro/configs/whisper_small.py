"""whisper-small [audio] — enc-dec; conv/mel frontend stubbed (precomputed frame
embeddings). GELU MLP, MHA (kv=12). [arXiv:2212.04356]"""
from .base import ModelConfig, EncoderConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,             # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    d_head=64,
    mlp="gelu",
    encoder=EncoderConfig(n_layers=12, enc_seq=1500),
    preferred_policy="fsdp",
    source="arXiv:2212.04356",
)
