"""paper-unest — the paper's own workload family: a UNesT-like hierarchical
transformer used by the brain-segmentation pipeline (Yu et al. 2023, cited by the
paper as one of its 16 processing pipelines). Modeled as a compact dense
transformer backbone used by ``core/pipelines.py:SegmentationPipeline``."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paper-unest",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=4096,          # voxel-patch codebook
    d_head=64,
    mlp="gelu",
    source="arXiv:2209.14378 (UNesT); paper §2.1",
)
