"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published config;
``get_config(arch_id).reduced()`` is the CPU smoke-test size.
"""
from __future__ import annotations

from .base import (ModelConfig, MoEConfig, SSMConfig, RWKVConfig, EncoderConfig,
                   VLMConfig, ShapeConfig, SHAPES, SHAPE_BY_NAME, cell_is_runnable)

from . import glm4_9b, llama3_2_1b, granite_34b, h2o_danube_1_8b, rwkv6_1_6b
from . import whisper_small, internvl2_76b, llama4_scout_17b_a16e
from . import moonshot_v1_16b_a3b, zamba2_1_2b, paper_unest

_REGISTRY = {}
for _m in (glm4_9b, llama3_2_1b, granite_34b, h2o_danube_1_8b, rwkv6_1_6b,
           whisper_small, internvl2_76b, llama4_scout_17b_a16e,
           moonshot_v1_16b_a3b, zamba2_1_2b, paper_unest):
    _REGISTRY[_m.CONFIG.name] = _m.CONFIG

ARCH_IDS = tuple(k for k in _REGISTRY if k != "paper-unest")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs():
    return sorted(_REGISTRY)


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "RWKVConfig", "EncoderConfig",
           "VLMConfig", "ShapeConfig", "SHAPES", "SHAPE_BY_NAME", "cell_is_runnable",
           "get_config", "list_archs", "ARCH_IDS"]
