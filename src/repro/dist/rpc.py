"""Networked WorkQueue transport: the RPC boundary from ``docs/cluster.md``
made real.

The in-process :class:`~repro.dist.queue.WorkQueue` was designed as a single
lock-guarded object with a small JSON-serializable method surface; this
module wraps it in a socket server and gives workers a drop-in client:

* :class:`QueueServer` — owns the one real ``WorkQueue`` and serves it over
  TCP. Wire format is **JSON lines**: one request object per line
  (``{"id": n, "method": "...", "params": {...}}``), one response per line
  (``{"id": n, "ok": true, "result": ...}`` or ``{"id": n, "ok": false,
  "error": "..."}``), UTF-8, ``\\n``-framed. Hot paths may instead use
  **length-prefixed binary frames** (``0x00`` magic byte + 4-byte big-endian
  payload length + the same JSON payload): the server answers in whichever
  framing the request arrived in and tags every JSON-lines response with
  ``"bin": 1``, which is how a new client discovers it may upgrade — an old
  server never sees a binary frame, an old client never notices the tag.
  Both sides cap frames at ``MAX_FRAME_BYTES`` and reject oversize with a
  protocol error (a corrupt or hostile peer must not balloon memory). One
  thread per connection; a dropped connection kills only that worker's
  session — its leases die with its heartbeats and are reaped like any
  crashed node.
* :class:`QueueClient` — implements the exact ``WorkQueue`` method surface
  (``next_unit`` / ``complete`` / ``heartbeat`` / ``speculate`` / ``reap`` /
  ``renew`` / ``register`` / introspection, plus the batched
  ``next_units`` / ``complete_batch`` / ``renew_batch`` that fold N hot-path
  ops into one round trip and shed to per-op calls against a pre-batch
  coordinator) over one persistent connection, so
  :class:`~repro.dist.cluster.Node` and ``ClusterRunner`` run unchanged
  against either the in-process queue or a remote one.

Only already-JSON data crosses the wire: ``WorkUnit`` and ``Lease`` are flat
dataclasses, and results travel as the ``meta`` payload of ``complete``.
Array bytes never do — nodes read inputs from shared storage (through the
per-host :mod:`repro.dist.cache`) and commit outputs there directly, so the
coordinator link stays control-plane-thin (the paper's 0.60 Gb/s
storage->compute path is not funneled through one TCP socket).

CLI (see ``docs/operating.md`` for the full runbook)::

    # coordinator host: serve a unit list
    python -m repro.dist.rpc serve --units units.json --addr 0.0.0.0:7077

    # each worker host: join and drain (REPRO_QUEUE_ADDR also works)
    python -m repro.dist.rpc work --addr coord:7077 --pipeline bias_correct \\
        --data-root /shared/dataset --node-id $(hostname)
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import socket
import socketserver
import threading
import time
import uuid
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

from ..core.query import WorkUnit
from .queue import Lease, WorkQueue

QUEUE_ADDR_ENV = "REPRO_QUEUE_ADDR"

# Hard ceiling on one request/response frame, both framings, both sides.
# The control plane moves leases and digest summaries — a few KB; 8 MiB is
# two orders of headroom. Anything larger is a corrupt length prefix, a
# desynchronized stream, or a hostile peer, and the old unbounded readline
# would have buffered it all before failing.
MAX_FRAME_BYTES = 8 << 20

# First byte of a length-prefixed binary frame. JSON-lines requests always
# start with "{", so one peeked byte disambiguates the framings per request.
_FRAME_MAGIC = b"\x00"

# Per-attempt connect timeout while redialing (reconnect path only; the
# constructor's first dial keeps the caller's full timeout_s). Redials run
# under the client's transport lock, so one attempt must stay well under
# both the reconnect window and the coordinator's lease TTL — see
# QueueClient._connect_locked.
REDIAL_CONNECT_TIMEOUT_S = 1.5

# The queue surface a client may invoke. getattr-dispatch is gated on this
# allowlist so a malformed request can name only protocol methods, nothing
# else on the object.
_METHODS = frozenset({
    "next_unit", "next_units", "complete", "complete_batch", "mark_started",
    "heartbeat", "mark_dead",
    "reap", "speculate", "renew", "renew_batch", "register", "running",
    "finished",
    "pending", "alive_nodes", "done_status", "queue_depths", "active_leases",
    "results_snapshot", "stats_snapshot", "primary_log", "put_summary",
    "summaries_snapshot", "locate_blobs",
})


def parse_addr(addr: str) -> Tuple[str, int]:
    """``"host:port"`` -> ``(host, port)``; bare ``":port"`` binds all ifaces."""
    host, _, port = addr.rpartition(":")
    return host or "0.0.0.0", int(port)


def addr_from_env() -> Optional[Tuple[str, int]]:
    raw = os.environ.get(QUEUE_ADDR_ENV)
    return parse_addr(raw) if raw else None


# ---------------------------------------------------------------------------
# wire encoding: only two non-scalar types cross the boundary
# ---------------------------------------------------------------------------

def _encode(obj: Any) -> Any:
    """Make a queue-method return value JSON-safe. The queue already returns
    plain data except for ``WorkUnit``/``Lease`` dataclasses and the
    ``(unit, lease)`` grant tuple."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, Lease):
        return {"__lease__": dataclasses.asdict(obj)}
    if isinstance(obj, WorkUnit):
        # depends_on travels as a *sibling* of the __unit__ payload: an old
        # peer's decoder builds WorkUnit(**obj["__unit__"]) and never looks
        # at siblings, so version skew sheds the edge set instead of raising.
        # That is safe by construction — the queue only grants ready units,
        # so an old worker can hold a DAG child only after its parents
        # committed. New decoders restore the field below.
        d = dataclasses.asdict(obj)
        deps = d.pop("depends_on", None)
        out: Dict[str, Any] = {"__unit__": d}
        if deps:
            out["__deps__"] = list(deps)
        return out
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    raise TypeError(f"cannot encode {type(obj).__name__} for the wire")


def _decode(obj: Any) -> Any:
    if isinstance(obj, dict):
        if "__lease__" in obj:
            return Lease(**obj["__lease__"])
        if "__unit__" in obj:
            fields = dict(obj["__unit__"])
            deps = obj.get("__deps__")
            if deps:
                fields["depends_on"] = [str(x) for x in deps]
            return WorkUnit(**fields)
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class _Handler(socketserver.StreamRequestHandler):
    def setup(self):
        super().setup()
        with self.server.conn_lock:                     # type: ignore[attr-defined]
            self.server.conns.add(self.connection)      # type: ignore[attr-defined]
            self.server.handler_threads.add(            # type: ignore[attr-defined]
                threading.current_thread())

    def finish(self):
        with self.server.conn_lock:                     # type: ignore[attr-defined]
            self.server.conns.discard(self.connection)  # type: ignore[attr-defined]
            self.server.handler_threads.discard(        # type: ignore[attr-defined]
                threading.current_thread())
        super().finish()

    def _reply(self, resp: dict, *, binary: bool):
        # every response is stamped with the server's incarnation id so a
        # reconnecting client can tell "same coordinator, transient blip"
        # from "new incarnation, re-register and re-push state". ~20 bytes;
        # old clients ignore the key (same posture as the "bin" tag).
        inc = getattr(self.server, "incarnation", None)
        if inc:
            resp["inc"] = inc
        data = json.dumps(resp).encode()
        if binary:
            self.wfile.write(_FRAME_MAGIC
                             + len(data).to_bytes(4, "big") + data)
        else:
            self.wfile.write(data + b"\n")
        self.wfile.flush()

    def handle(self):
        queue: WorkQueue = self.server.queue            # type: ignore[attr-defined]
        while True:
            head = self.rfile.read(1)
            if not head:
                return                                   # client hung up
            binary = head == _FRAME_MAGIC
            if binary:
                hdr = self.rfile.read(4)
                if len(hdr) < 4:
                    return                               # EOF mid-header
                n = int.from_bytes(hdr, "big")
                if n > MAX_FRAME_BYTES:
                    # a length prefix past the cap means the stream cannot
                    # be resynchronized: report and hang up (the client's
                    # ConnectionError path — the reaper's failure mode)
                    try:
                        self._reply({"id": None, "ok": False,
                                     "error": f"ProtocolError: {n}-byte "
                                              f"frame exceeds cap "
                                              f"{MAX_FRAME_BYTES}"},
                                    binary=True)
                    except OSError:
                        pass
                    return
                payload = self.rfile.read(n)
                if len(payload) < n:
                    return                               # EOF mid-frame
            else:
                rest = self.rfile.readline(MAX_FRAME_BYTES)
                payload = head + rest
                if not payload.endswith(b"\n"):
                    if len(payload) > MAX_FRAME_BYTES:
                        # oversize line: the rest of it is still in the
                        # socket — never try to resync past it
                        try:
                            self._reply(
                                {"id": None, "ok": False,
                                 "error": f"ProtocolError: line exceeds "
                                          f"frame cap {MAX_FRAME_BYTES}"},
                                binary=False)
                        except OSError:
                            pass
                    return                               # oversize or EOF
            req = None
            try:
                req = json.loads(payload)
                if not isinstance(req, dict):
                    raise ValueError("request must be a JSON object")
                method = req.get("method")
                if method not in _METHODS:
                    raise ValueError(f"unknown method {method!r}")
                params = req.get("params") or {}
                result = getattr(queue, method)(**params)
                resp = {"id": req.get("id"), "ok": True,
                        "result": _encode(result)}
            except Exception as e:  # noqa: BLE001 — reported to the caller
                resp = {"id": req.get("id") if isinstance(req, dict) else None,
                        "ok": False, "error": f"{type(e).__name__}: {e}"}
            if not binary:
                # advertise binary-framing support on every JSON-lines
                # response; a new client upgrades after its first call, an
                # old client ignores the extra key
                resp["bin"] = 1
            try:
                self._reply(resp, binary=binary)
            except OSError:
                return                                   # connection dropped


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.conn_lock = threading.Lock()
        self.conns: set = set()
        self.handler_threads: set = set()
        # fresh per server object: two QueueServers on the same port (a
        # restart) necessarily present different ids
        self.incarnation = uuid.uuid4().hex[:12]


class QueueServer:
    """Serve one :class:`WorkQueue` over TCP JSON-lines.

    The server owns nothing but the socket: the queue's semantics (leases,
    reaping, commit arbitration) are untouched, and the coordinator process
    keeps calling the queue object directly while remote workers go through
    the wire. ``port=0`` picks a free port; read it back from
    :attr:`address` after :meth:`start`."""

    def __init__(self, queue: WorkQueue, host: str = "127.0.0.1",
                 port: int = 0, *, drain_s: float = 5.0):
        self.queue = queue
        self.drain_s = float(drain_s)
        self._srv = _Server((host, port), _Handler)
        self._srv.queue = queue                          # type: ignore[attr-defined]
        self._thread = threading.Thread(
            target=self._srv.serve_forever, name="queue-server", daemon=True)
        self._stop_lock = threading.Lock()
        self._stopped = False

    @property
    def address(self) -> Tuple[str, int]:
        return self._srv.server_address[:2]

    @property
    def addr_str(self) -> str:
        host, port = self.address
        return f"{host}:{port}"

    @property
    def incarnation(self) -> str:
        """This server object's identity on the wire (stamped into every
        response). A restarted coordinator necessarily presents a new one."""
        return self._srv.incarnation

    def start(self) -> "QueueServer":
        self._thread.start()
        return self

    def stop(self):
        """Graceful, idempotent shutdown: stop accepting, half-close every
        live connection (``SHUT_RD`` — no new requests arrive, but a reply
        already being computed still reaches its worker), join the handler
        threads up to ``drain_s``, then force-close stragglers. Safe to call
        twice (or concurrently with :meth:`crash`): the second call is a
        no-op, so tests and operators can stop/restart freely without racing
        half-written replies."""
        with self._stop_lock:
            if self._stopped:
                return
            self._stopped = True
        self._srv.shutdown()
        with self._srv.conn_lock:
            conns = list(self._srv.conns)
            threads = list(self._srv.handler_threads)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RD)
            except OSError:
                pass
        deadline = time.monotonic() + self.drain_s
        for t in threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
        # anything still running after the drain budget is wedged mid-call:
        # cut it off rather than hang the operator
        with self._srv.conn_lock:
            conns = list(self._srv.conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        self._srv.server_close()

    def crash(self):
        """Simulated coordinator death: immediately sever every connection
        mid-whatever-it-was-doing — no drain, no goodbye frames. Idempotent
        like :meth:`stop`. The restart harness uses this to exercise the
        journal-recovery path against torn replies and half-served grants."""
        with self._stop_lock:
            if self._stopped:
                return
            self._stopped = True
        self._srv.shutdown()
        with self._srv.conn_lock:
            conns = list(self._srv.conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        self._srv.server_close()

    def __enter__(self) -> "QueueServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------

class _FatalStream(ConnectionError):
    """The server refused the stream itself (an id-less error reply, e.g.
    an oversize frame): deterministic, so retrying the same bytes would
    fail the same way forever. Never redialed."""


class QueueClient:
    """``WorkQueue``-shaped proxy over one persistent JSON-lines connection.

    Thread-safe: a node's worker, loader, and heartbeat threads share the
    client; one lock serializes request/response pairs on the socket (calls
    are sub-millisecond control-plane messages, never data transfers).

    **Reconnect** (default on): a transport error drops the socket and the
    call redials with capped exponential backoff + jitter for up to
    ``reconnect_window_s``, then replays the request — safe because the
    queue surface is idempotent, epoch-guarded, or lease-TTL-backstopped
    (a duplicate ``complete`` lands in the dup log, a duplicate
    ``register`` refreshes a heartbeat, a stale ``renew`` is rejected; a
    grant whose *reply* was lost — the one non-idempotent case, since the
    replayed call draws a fresh lease — is reclaimed by the coordinator's
    per-lease expiry: nobody ever renews a lease the node never received,
    so ``reap()`` requeues it after one TTL). Each redial renegotiates
    binary framing from scratch and re-registers the node with its last
    summary. Every server response carries an incarnation id; when it
    changes (the coordinator restarted), registered restart hooks fire so
    the node can re-push its full cache summary and blob address to the new
    incarnation. ``reconnect=False`` restores the pre-reconnect contract:
    any transport error permanently poisons the client and raises
    :class:`ConnectionError` — to the node loop that is indistinguishable
    from its own crash, which is exactly the failure semantics the reaper
    expects (silence -> lease requeue). With reconnect on the same terminal
    semantics apply once the window is exhausted."""

    def __init__(self, addr: Tuple[str, int], *, timeout_s: float = 30.0,
                 binary: bool = True, reconnect: bool = True,
                 reconnect_window_s: float = 20.0, backoff_s: float = 0.05,
                 backoff_max_s: float = 1.0):
        self.addr = addr
        self.timeout_s = float(timeout_s)
        self._lock = threading.Lock()
        self._id = 0
        self._poisoned = False
        self._reconnect = bool(reconnect)
        self._reconnect_window_s = float(reconnect_window_s)
        self._backoff_s = float(backoff_s)
        self._backoff_max_s = float(backoff_max_s)
        self._closing = threading.Event()
        self._incarnation: Optional[str] = None
        self._register_params: Optional[Dict[str, Any]] = None
        self._restart_hooks: list = []
        self._hooks_lock = threading.Lock()
        self._hooks_running = False
        self._pending_restart = False
        # locality version-skew fail-soft: a server that predates cache
        # digest summaries rejects the extra params with a TypeError; after
        # the first such rejection this client stops sending summaries and
        # the run proceeds locality-blind (the pre-summary behaviour)
        self._summaries_ok = True
        # same discipline for the peer fabric's blob_addr advertisement: an
        # old coordinator rejects it once, then we stop advertising (the
        # worker still serves blobs; nobody is told, nobody dials in)
        self._fabric_ok = True
        # and for the batched hot-path methods: one "unknown method" from a
        # pre-batch coordinator downgrades this client to per-op calls
        self._batched_ok = True
        # binary framing is negotiated, never assumed: the first JSON-lines
        # response from a framing-capable server carries "bin": 1, after
        # which (with binary=True) every request is length-prefixed. An old
        # server therefore never receives a frame it would misread as a
        # garbled line. binary=False pins the client to JSON-lines — the
        # old-client-new-server compatibility shape, kept testable.
        self._binary_enabled = bool(binary)
        self._binary = False
        # the first dial fails loudly (OSError), reconnect or not: "the
        # coordinator was never there" is an operator error, not a blip
        self._sock: Optional[socket.socket] = \
            socket.create_connection(addr, timeout=timeout_s)
        self._file = self._sock.makefile("rb")

    def close(self):
        self._closing.set()            # wakes any backoff sleep immediately
        with self._lock:
            self._poison()

    def add_restart_hook(self, fn: Callable[[], None]):
        """Run ``fn()`` after this client detects a coordinator restart (the
        server incarnation id changed). Fired outside the transport lock, so
        hooks may freely call client methods (re-push a summary,
        re-advertise a blob server); hook exceptions are swallowed — a
        failed re-push degrades locality, never the reconnect."""
        with self._hooks_lock:
            self._restart_hooks.append(fn)

    def _read_response(self, method: str) -> bytes:
        """One response frame in whichever framing this connection speaks.
        Caller holds the lock. Raises :class:`ConnectionError` on EOF, a
        desynchronized stream, or an oversize frame — the cap protects the
        client's memory exactly as the server's protects its. The caller
        (:meth:`_call`) decides whether that means redial or poison."""
        if self._binary:
            head = self._file.read(1)
            if not head:
                raise ConnectionError(
                    f"queue server {self.addr} closed the connection")
            if head != _FRAME_MAGIC:
                raise ConnectionError(
                    f"queue rpc {method}: expected a binary frame from "
                    f"{self.addr} — stream desynchronized")
            hdr = self._file.read(4)
            if len(hdr) < 4:
                raise ConnectionError(
                    f"queue server {self.addr} closed the connection")
            n = int.from_bytes(hdr, "big")
            if n > MAX_FRAME_BYTES:
                # deterministic local rejection, not transport weather: the
                # same reply would blow the cap on every redial — fatal
                raise _FatalStream(
                    f"queue rpc {method}: {n}-byte response frame from "
                    f"{self.addr} exceeds frame cap {MAX_FRAME_BYTES}")
            payload = self._file.read(n)
            if len(payload) < n:
                raise ConnectionError(
                    f"queue server {self.addr} closed the connection")
            return payload
        line = self._file.readline(MAX_FRAME_BYTES + 1)
        if not line:
            raise ConnectionError(
                f"queue server {self.addr} closed the connection")
        if len(line) > MAX_FRAME_BYTES and not line.endswith(b"\n"):
            raise _FatalStream(
                f"queue rpc {method}: response line from {self.addr} "
                f"exceeds frame cap {MAX_FRAME_BYTES}")
        return line

    def _drop_socket_locked(self):
        """Tear down a dead/poisoned socket without judging the client."""
        if self._sock is not None:
            try:
                self._file.close()
                self._sock.close()
            except OSError:
                pass
        self._sock = None

    def _connect_locked(self, deadline: Optional[float] = None):
        """Redial. Framing restarts at JSON-lines — the server on the other
        end may be a different (even older) build than last time, so the
        binary upgrade is renegotiated per connection, never remembered.

        The dial itself uses a short per-attempt timeout, clamped to the
        time left before ``deadline`` (the reconnect window): this method
        runs under the transport lock, and a single full-``timeout_s``
        dial into a partition would both blow through the whole reconnect
        window and serialize the node's heartbeat/renew threads behind the
        lock — a healthy node would stop heartbeating and get reaped.
        Once connected the socket reverts to the full ``timeout_s`` for
        request/response traffic."""
        timeout = min(self.timeout_s, REDIAL_CONNECT_TIMEOUT_S)
        if deadline is not None:
            timeout = min(timeout, max(0.05, deadline - time.monotonic()))
        self._sock = socket.create_connection(self.addr, timeout=timeout)
        self._sock.settimeout(self.timeout_s)
        self._file = self._sock.makefile("rb")
        self._binary = False

    def _replay_session_locked(self):
        """Re-establish session state on a fresh connection: re-send the
        last successful ``register`` (node id + full summary + blob addr)
        so the server — possibly a brand-new incarnation that has never
        heard of this node — can place and route it again before the
        retried call lands. A ``False`` result (the node was reaped) is
        left for the node loop to discover through its own calls."""
        if self._register_params is not None:
            self._roundtrip_locked("register", dict(self._register_params))

    def _roundtrip_locked(self, method: str, params: dict) -> dict:
        """One request/response exchange on the current socket. Caller
        holds the lock. Transport trouble raises plain
        :class:`ConnectionError` (retryable: the caller may redial);
        a deterministic stream rejection raises :class:`_FatalStream`."""
        self._id += 1
        req = {"id": self._id, "method": method, "params": params}
        data = json.dumps(req).encode()
        try:
            if self._binary:
                self._sock.sendall(
                    _FRAME_MAGIC + len(data).to_bytes(4, "big") + data)
            else:
                self._sock.sendall(data + b"\n")
            raw = self._read_response(method)
        except ConnectionError:
            raise
        except OSError as e:
            # includes timeout: a timed-out call may leave its reply in
            # flight — the stream is no longer aligned, so this socket is
            # done either way
            raise ConnectionError(
                f"queue rpc {method} to {self.addr}: {e}") from e
        try:
            resp = json.loads(raw)
        except json.JSONDecodeError as e:
            # truncated line at EOF (server killed mid-reply): transport
            # death, not a protocol error
            raise ConnectionError(
                f"queue rpc {method}: truncated/garbage response "
                f"from {self.addr}: {e}") from e
        if resp.get("id") != req["id"]:
            if resp.get("id") is None and not resp.get("ok", True):
                # an id-less error is the server refusing the stream itself
                # (e.g. a frame past the cap) before closing it — the same
                # bytes would be refused again, so never retry
                raise _FatalStream(
                    f"queue rpc {method}: server {self.addr} rejected "
                    f"the stream: {resp.get('error')}")
            raise ConnectionError(
                f"queue rpc {method}: response id {resp.get('id')!r} != "
                f"request id {req['id']} — stream desynchronized")
        if not self._binary and self._binary_enabled and resp.get("bin"):
            self._binary = True           # server advertised frame support
        inc = resp.get("inc")
        if inc:
            if self._incarnation is None:
                self._incarnation = inc
            elif inc != self._incarnation:
                self._incarnation = inc
                self._pending_restart = True
        return resp

    def _call(self, method: str, **params) -> Any:
        deadline = None
        delay = self._backoff_s
        while True:
            resp = None
            with self._lock:
                if self._poisoned or self._closing.is_set():
                    raise ConnectionError(
                        f"queue rpc {method}: connection to {self.addr} "
                        f"is down")
                try:
                    if self._sock is None:
                        self._connect_locked(deadline)
                        self._replay_session_locked()
                    resp = self._roundtrip_locked(method, params)
                except _FatalStream as e:
                    self._poison()
                    raise ConnectionError(str(e)) from None
                except (ConnectionError, OSError) as e:
                    self._drop_socket_locked()
                    if not self._reconnect:
                        self._poison()
                        raise ConnectionError(
                            f"queue rpc {method} to {self.addr}: {e}") from e
                    if deadline is None:
                        deadline = time.monotonic() + self._reconnect_window_s
                    if time.monotonic() >= deadline:
                        self._poison()
                        raise ConnectionError(
                            f"queue rpc {method} to {self.addr}: gave up "
                            f"after {self._reconnect_window_s:.1f}s of "
                            f"redials: {e}") from e
            if resp is not None:
                # outside the lock: hooks re-enter the client
                self._maybe_fire_restart_hooks()
                if not resp.get("ok"):
                    raise RuntimeError(
                        f"queue rpc {method}: {resp.get('error')}")
                return _decode(resp.get("result"))
            # redial backoff, outside the lock so heartbeat/worker threads
            # aren't serialized behind the sleep; jitter de-synchronizes a
            # whole cluster's workers re-dialing one reborn coordinator
            if self._closing.wait(delay * (0.5 + random.random())):
                raise ConnectionError(
                    f"queue rpc {method}: client closed while redialing")
            delay = min(delay * 2, self._backoff_max_s)

    def _maybe_fire_restart_hooks(self):
        with self._hooks_lock:
            if not self._pending_restart or self._hooks_running:
                return        # no restart seen, or a hook is mid-flight
            #                   (hooks call client methods: don't recurse)
            self._pending_restart = False
            self._hooks_running = True
            hooks = list(self._restart_hooks)
        try:
            for fn in hooks:
                try:
                    fn()
                except Exception:   # noqa: BLE001 — a failed re-push
                    pass            # degrades locality, never the session
        finally:
            with self._hooks_lock:
                self._hooks_running = False

    def _poison(self):
        """Caller holds the lock: drop the socket; every later call raises."""
        self._poisoned = True
        self._drop_socket_locked()

    def _downgrade_on_type_error(self, exc: RuntimeError) -> bool:
        """An old server reports our new summary params as a ``TypeError:
        ... unexpected keyword ...`` RPC error. Flag the downgrade (so later
        calls skip summaries entirely) and tell the caller to retry bare."""
        if "TypeError" in str(exc):
            self._summaries_ok = False
            return True
        return False

    # -- the WorkQueue surface, verbatim ------------------------------------

    def next_unit(self, node_id: str):
        got = self._call("next_unit", node_id=node_id)
        return None if got is None else (got[0], got[1])

    def next_units(self, node_id: str, max_units: int = 1):
        """Batched grants: one round trip for up to ``max_units`` leases.
        Sheds to per-op :meth:`next_unit` calls (permanently, for this
        connection) against a coordinator that predates batching."""
        if self._batched_ok:
            try:
                got = self._call("next_units", node_id=node_id,
                                 max_units=max_units)
                return [(g[0], g[1]) for g in got]
            except RuntimeError as e:
                if "unknown method" not in str(e):
                    raise
                self._batched_ok = False
        out = []
        for _ in range(max(1, int(max_units))):
            one = self.next_unit(node_id)
            if one is None:
                break
            out.append(one)
        return out

    def complete(self, idx: int, node_id: str, status: str, *,
                 speculative: bool = False, meta: Optional[dict] = None):
        self._call("complete", idx=idx, node_id=node_id, status=status,
                   speculative=speculative, meta=meta)

    def complete_batch(self, completions):
        """Batched terminal reports (list of ``{"idx", "node_id", "status"}``
        dicts plus optional ``speculative``/``meta``); sheds to per-op
        :meth:`complete` calls against a pre-batch coordinator."""
        completions = list(completions)
        if self._batched_ok:
            try:
                self._call("complete_batch", completions=completions)
                return
            except RuntimeError as e:
                if "unknown method" not in str(e):
                    raise
                self._batched_ok = False
        for c in completions:
            meta = c.get("meta")
            self.complete(int(c["idx"]), str(c["node_id"]), str(c["status"]),
                          speculative=bool(c.get("speculative", False)),
                          meta=meta if isinstance(meta, dict) else None)

    def mark_started(self, idx: int):
        self._call("mark_started", idx=idx)

    def heartbeat(self, node_id: str, summary_delta=None, blob_addr=None):
        params: Dict[str, Any] = {"node_id": node_id}
        if summary_delta is not None and self._summaries_ok:
            params["summary_delta"] = summary_delta
        if blob_addr and self._fabric_ok:
            params["blob_addr"] = blob_addr
        while True:
            try:
                self._call("heartbeat", **params)
                return
            except RuntimeError as e:
                # shed new-protocol params one generation at a time: a
                # coordinator that rejects blob_addr may still speak
                # summaries, so don't throw both away on one TypeError
                if "blob_addr" in params and "TypeError" in str(e):
                    self._fabric_ok = False
                    params.pop("blob_addr")
                    continue
                if "summary_delta" in params and self._downgrade_on_type_error(e):
                    params.pop("summary_delta")
                    continue
                raise

    def mark_dead(self, node_id: str):
        self._call("mark_dead", node_id=node_id)

    def reap(self):
        return self._call("reap")

    def speculate(self, idx: int, node_id: Optional[str] = None):
        return self._call("speculate", idx=idx, node_id=node_id)

    def renew(self, idx: int, node_id: str, epoch: int,
              summary_delta=None) -> bool:
        if summary_delta is not None and self._summaries_ok:
            try:
                return self._call("renew", idx=idx, node_id=node_id,
                                  epoch=epoch, summary_delta=summary_delta)
            except RuntimeError as e:
                if not self._downgrade_on_type_error(e):
                    raise
        return self._call("renew", idx=idx, node_id=node_id, epoch=epoch)

    def renew_batch(self, node_id: str, leases, summary_delta=None):
        """Renew every held lease (``[[idx, epoch], ...]``) in one round
        trip, the ``summary_delta`` applied once. Sheds to per-op
        :meth:`renew` calls against a pre-batch coordinator — the delta then
        piggybacks on the first per-op renew, keeping its once-per-beat
        semantics."""
        leases = [[int(i), int(e)] for i, e in leases]
        if self._batched_ok:
            params: Dict[str, Any] = {"node_id": node_id, "leases": leases}
            if summary_delta is not None and self._summaries_ok:
                params["summary_delta"] = summary_delta
            try:
                return [bool(v) for v in self._call("renew_batch", **params)]
            except RuntimeError as e:
                if "unknown method" not in str(e):
                    raise
                self._batched_ok = False
        out = []
        delta = summary_delta
        for i, ep in leases:
            out.append(self.renew(i, node_id, ep, summary_delta=delta))
            delta = None
        return out

    def register(self, node_id: str, summary=None, blob_addr=None) -> bool:
        params: Dict[str, Any] = {"node_id": node_id}
        if summary is not None and self._summaries_ok:
            params["summary"] = summary
        if blob_addr and self._fabric_ok:
            params["blob_addr"] = blob_addr
        while True:
            try:
                joined = self._call("register", **params)
                # remember the post-shedding params: every future redial
                # replays exactly this registration before anything else
                self._register_params = dict(params)
                return joined
            except RuntimeError as e:
                if "blob_addr" in params and "TypeError" in str(e):
                    self._fabric_ok = False
                    params.pop("blob_addr")
                    continue
                if "summary" in params and self._downgrade_on_type_error(e):
                    params.pop("summary")
                    continue
                raise

    def put_summary(self, node_id: str, summary) -> bool:
        """Push a full cache digest summary; False (never an error) against
        a coordinator that predates locality-aware placement."""
        if not self._summaries_ok:
            return False
        try:
            return self._call("put_summary", node_id=node_id, summary=summary)
        except RuntimeError as e:
            if "unknown method" in str(e) or "TypeError" in str(e):
                self._summaries_ok = False
                return False
            raise

    def running(self):
        return [tuple(r) for r in self._call("running")]

    def finished(self) -> bool:
        return self._call("finished")

    def pending(self) -> int:
        return self._call("pending")

    def alive_nodes(self):
        return self._call("alive_nodes")

    def done_status(self):
        return {int(k): v for k, v in self._call("done_status").items()}

    def queue_depths(self):
        return self._call("queue_depths")

    def active_leases(self):
        return self._call("active_leases")

    def results_snapshot(self):
        snap = self._call("results_snapshot")
        return {"primaries": {int(k): v
                              for k, v in snap["primaries"].items()},
                "duplicates": snap["duplicates"]}

    def primary_log(self, start: int = 0):
        return self._call("primary_log", start=start)

    def stats_snapshot(self):
        return self._call("stats_snapshot")

    def summaries_snapshot(self):
        """Per-node summary wires for admission-time campaign planning;
        ``{}`` (never an error) against a coordinator that predates it."""
        try:
            return self._call("summaries_snapshot")
        except RuntimeError as e:
            if "unknown method" in str(e):
                return {}
            raise

    def locate_blobs(self, digests, node_id=None):
        """Peer candidates for content-addressed blobs (the fabric's routing
        question); ``{}`` (never an error) against a coordinator that
        predates the peer fabric — the fetcher then reads shared storage,
        exactly the pre-fabric behaviour."""
        if not self._fabric_ok:
            return {}
        try:
            return self._call("locate_blobs", digests=list(digests),
                              node_id=node_id)
        except RuntimeError as e:
            if "unknown method" in str(e):
                self._fabric_ok = False
                return {}
            raise

    # the in-process queue exposes these as attributes; mirror them so
    # observability code works against either implementation
    @property
    def steals(self):
        return self.stats_snapshot()["steals"]

    @property
    def requeues(self):
        return self.stats_snapshot()["requeues"]


# ---------------------------------------------------------------------------
# CLI: coordinator + worker entrypoints for real multi-host runs
# ---------------------------------------------------------------------------

def _main():
    import argparse
    ap = argparse.ArgumentParser(
        description="networked WorkQueue: serve a unit list / join as worker")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sv = sub.add_parser("serve", help="run the coordinator queue server")
    sv.add_argument("--units", required=True,
                    help="units JSON from generate_jobs (…_units.json)")
    sv.add_argument("--addr", default=os.environ.get(QUEUE_ADDR_ENV,
                                                     "127.0.0.1:7077"))
    sv.add_argument("--lease-ttl", type=float, default=30.0,
                    help="seconds of heartbeat silence before a node is reaped")
    sv.add_argument("--reap-interval", type=float, default=1.0)
    sv.add_argument("--journal", default=None, metavar="DIR",
                    help="write-ahead journal directory: every queue "
                         "mutation becomes durable, and re-serving with the "
                         "same DIR recovers the previous incarnation's "
                         "state instead of starting over")
    sv.add_argument("--fsync", default="interval",
                    choices=("always", "interval", "never"),
                    help="journal durability: fsync every record, on an "
                         "interval (default), or leave it to the OS")

    wk = sub.add_parser("work", help="join the queue and drain units")
    wk.add_argument("--addr", default=os.environ.get(QUEUE_ADDR_ENV),
                    help=f"coordinator host:port (or ${QUEUE_ADDR_ENV})")
    wk.add_argument("--pipeline", required=True)
    wk.add_argument("--data-root", required=True)
    wk.add_argument("--node-id", default=None,
                    help="default: <hostname>-<pid>")
    wk.add_argument("--prefetch", type=int, default=1)
    wk.add_argument("--max-retries", type=int, default=2)
    wk.add_argument("--cache-dir", default=None,
                    help="host input cache (or $REPRO_CACHE_DIR)")
    wk.add_argument("--cache-mb", type=float, default=None,
                    help="cache budget in MiB (or $REPRO_CACHE_MAX_MB)")
    wk.add_argument("--blob-addr", default=None,
                    help="host:port to serve cached blobs to peers on "
                         "(or $REPRO_BLOB_ADDR); needs --cache-dir")
    args = ap.parse_args()

    # allocator/XLA hygiene before anything imports jax (the work path pulls
    # in the pipelines); REPRO_ENV_PROFILE=off opts out — see launch/env.py
    from ..launch.env import apply_env_profile
    apply_env_profile("coordinator" if args.cmd == "serve" else "worker")

    if args.cmd == "serve":
        from ..core.query import load_units
        units = load_units(Path(args.units))
        if args.journal:
            from .journal import Journal
            journal = Journal(args.journal, fsync=args.fsync)
            if journal.exists():
                # a previous incarnation died here: its journal, not the
                # --units file, is the authoritative state
                queue = WorkQueue.recover(journal,
                                          lease_ttl_s=args.lease_ttl)
                done = len(queue.done_status())
                print(f"recovered journal {args.journal}: "
                      f"{len(queue.units)} units, {done} already terminal",
                      flush=True)
            else:
                queue = WorkQueue(units, (), lease_ttl_s=args.lease_ttl,
                                  journal=journal)
        else:
            queue = WorkQueue(units, (), lease_ttl_s=args.lease_ttl)
        host, port = parse_addr(args.addr)
        server = QueueServer(queue, host, port).start()
        print(f"queue server on {server.addr_str}: {len(units)} units, "
              f"lease ttl {args.lease_ttl}s", flush=True)
        import time
        try:
            while not queue.finished():
                time.sleep(args.reap_interval)
                reaped = queue.reap()
                if reaped:
                    print(f"reaped units {reaped} "
                          f"(alive: {queue.alive_nodes()})", flush=True)
        finally:
            server.stop()
        status = queue.done_status()
        ok = sum(1 for s in status.values() if s == "ok")
        print(f"finished: {ok}/{len(units)} ok", flush=True)
        raise SystemExit(0 if len(status) == len(units)
                         and all(s in ("ok", "skipped")
                                 for s in status.values()) else 1)

    # work
    if not args.addr:
        ap.error(f"--addr or ${QUEUE_ADDR_ENV} is required")
    from .cluster import run_worker            # late: pulls in jax pipelines
    node_id = args.node_id or f"{socket.gethostname()}-{os.getpid()}"
    if args.cache_dir:                       # explicit flags beat the env
        os.environ["REPRO_CACHE_DIR"] = args.cache_dir
    if args.cache_mb is not None:
        os.environ["REPRO_CACHE_MAX_MB"] = str(args.cache_mb)
    if args.blob_addr:
        os.environ["REPRO_BLOB_ADDR"] = args.blob_addr
    try:
        processed = run_worker(parse_addr(args.addr), args.pipeline,
                               Path(args.data_root), node_id,
                               prefetch=args.prefetch,
                               max_retries=args.max_retries)
    except (ConnectionError, OSError) as e:
        # the coordinator is gone (job finished, or not up yet): a worker
        # host exits quietly — its silence is the signal the reaper handles
        print(f"{node_id}: queue at {args.addr} unreachable ({e})", flush=True)
        raise SystemExit(3)
    print(f"{node_id}: processed {processed} unit(s)", flush=True)


if __name__ == "__main__":
    _main()
