"""Compressed gradient collectives: int8-quantized psum with error feedback.

Cross-pod gradient reduction moves 4 bytes/param/step at fp32. Quantizing to
int8 against a globally agreed scale cuts the wire bytes 4x; the quantization
residual is carried forward per-leaf (error feedback), so the *accumulated*
reduction stays unbiased — the standard 1-bit/8-bit SGD trick."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, scale):
    """Symmetric int8 quantization of ``x`` against ``scale`` (max-abs)."""
    s = jnp.maximum(jnp.asarray(scale, jnp.float32), jnp.finfo(jnp.float32).tiny)
    q = jnp.round(x.astype(jnp.float32) / s * 127.0)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * (jnp.asarray(scale, jnp.float32) / 127.0)


def zeros_like_errors(tree):
    """Initial (zero) error-feedback state matching a gradient tree."""
    return jax.tree.map(lambda a: jnp.zeros(jnp.shape(a), jnp.float32), tree)


def compressed_psum(x, err, axis_name):
    """int8-compressed psum over ``axis_name`` with error feedback.

    Returns (psum of the dequantized value, new local error). The scale is
    pmax-agreed so every shard quantizes against the same grid; the residual
    ``x + err - dequantize(quantize(...))`` is returned for the next round.
    Must run inside shard_map/pmap (needs a bound axis name)."""
    xe = x.astype(jnp.float32) + err
    scale = jax.lax.pmax(jnp.max(jnp.abs(xe)), axis_name)
    deq = dequantize_int8(quantize_int8(xe, scale), scale)
    new_err = xe - deq
    return jax.lax.psum(deq, axis_name), new_err


def compressed_tree_psum(tree, errs, axis_name):
    """Leaf-wise :func:`compressed_psum` over a gradient pytree.

    Returns (reduced tree, new error tree) with the input structure."""
    leaves, treedef = jax.tree.flatten(tree)
    eleaves = treedef.flatten_up_to(errs)
    pairs = [compressed_psum(a, e, axis_name) for a, e in zip(leaves, eleaves)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))
