"""Peer-to-peer blob fabric: content-addressed serving between worker hosts.

The paper's transfer ceiling — 0.60 Gb/s storage->compute over the lab
network, 0.33 Gb/s from cloud storage — is a property of the *shared
storage* link, which every :class:`~repro.dist.cache.InputCache` miss
crosses. But after a warm-up pass the cluster collectively holds most hot
blobs on node-local disk, and the coordinator already knows who holds what
(the counting-Bloom :class:`~repro.dist.cache.DigestSummary`s piggybacked
on heartbeats). This module turns those N private caches into one
cluster-wide serving tier:

* :class:`BlobServer` — a lightweight per-host TCP server answering
  content-addressed ``get <sha256>`` straight out of the host's
  ``InputCache``. Framing reuses the JSON-lines discipline of
  :mod:`repro.dist.rpc` for the control half, with a length-prefixed binary
  path for the payload: request is one JSON line, response is one JSON
  header line (``{"id": n, "ok": true, "size": N}``) followed by exactly
  ``N`` raw bytes. Blob bodies never pass through ``json.dumps``. Serving
  reads are pinned (:meth:`InputCache.read_blob`) so LRU eviction cannot
  unlink a file mid-serve.
* :class:`PeerFabric` — the fetch side a cache consults on a local miss.
  It asks the coordinator for ranked peer candidates
  (``WorkQueue.locate_blobs``, answered from the summaries it already
  holds) and streams from the warmest live peer. Received bytes are
  **re-verified** against the requested sha256 before anyone trusts them.

Failure is the normal case and every mode degrades to the storage read the
caller was about to do anyway: dead peer / timeout (connection error),
Bloom false positive or stale summary (peer answers ``not found``), digest
mismatch (corrupted body or lying peer), coordinator too old to speak
``locate_blobs`` (the fabric disables itself after the first "unknown
method"). Each mode has its own counter, merged into ``InputCache.stats()``
so fallbacks are visible in ``WorkQueue.stats_snapshot()`` cluster-wide.
"""
from __future__ import annotations

import hashlib
import json
import socket
import socketserver
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from ..core.stream import stream_chunk_bytes

# Runbook knob (docs/operating.md): "host:port" this worker's blob server
# binds; the *advertised* address replaces a wildcard host with the
# machine's hostname so peers can actually reach it. Unset = no blob server
# (the worker still fetches from peers; it just never serves).
BLOB_ADDR_ENV = "REPRO_BLOB_ADDR"
# Runbook knob: set to "0" to disable peer *fetching* on a worker even when
# a cache is configured (serving is governed by BLOB_ADDR_ENV alone).
PEER_FETCH_ENV = "REPRO_PEER_FETCH"

_MAX_BLOB_BYTES = 1 << 34            # 16 GiB: sanity bound on header "size"


def parse_blob_addr(addr: str) -> Tuple[str, int]:
    """``"host:port"`` -> ``(host, port)``; bare ``":port"`` binds all
    interfaces (the advertised address then carries the hostname)."""
    host, _, port = addr.rpartition(":")
    return host or "0.0.0.0", int(port)


def advertised_addr(bound: Tuple[str, int]) -> str:
    """The address peers should dial for a server bound at ``bound``:
    wildcard hosts are unreachable from elsewhere, so advertise the
    machine's hostname instead."""
    host, port = bound
    if host in ("0.0.0.0", "::", ""):
        host = socket.gethostname()
    return f"{host}:{port}"


# ---------------------------------------------------------------------------
# server: GET <sha256> out of the host cache
# ---------------------------------------------------------------------------

class _BlobHandler(socketserver.StreamRequestHandler):
    def setup(self):
        super().setup()
        with self.server.conn_lock:                     # type: ignore[attr-defined]
            self.server.conns.add(self.connection)      # type: ignore[attr-defined]

    def finish(self):
        with self.server.conn_lock:                     # type: ignore[attr-defined]
            self.server.conns.discard(self.connection)  # type: ignore[attr-defined]
        super().finish()

    def handle(self):
        cache = self.server.cache                       # type: ignore[attr-defined]
        while True:
            line = self.rfile.readline()
            if not line:
                return                                   # client hung up
            req = None
            data: Optional[bytes] = None
            try:
                req = json.loads(line)
                if not isinstance(req, dict):
                    raise ValueError("request must be a JSON object")
                if req.get("method") != "get":
                    raise ValueError(f"unknown method {req.get('method')!r}")
                digest = req.get("digest")
                if not isinstance(digest, str) or not digest:
                    raise ValueError("get requires a digest")
                # pinned read: eviction cannot unlink the blob mid-serve.
                # None = not resident (requester's Bloom false positive or
                # stale summary): an explicit not-found, not an error — the
                # requester counts it and falls back to storage.
                data = cache.read_blob(digest)
                if data is None:
                    resp = {"id": req.get("id"), "ok": False,
                            "error": "not found"}
                else:
                    resp = {"id": req.get("id"), "ok": True,
                            "size": len(data)}
            except Exception as e:  # noqa: BLE001 — reported to the caller
                data = None
                resp = {"id": req.get("id") if isinstance(req, dict) else None,
                        "ok": False, "error": f"{type(e).__name__}: {e}"}
            try:
                self.wfile.write(json.dumps(resp).encode() + b"\n")
                if data is not None:
                    self.wfile.write(data)      # raw body, length in header
                self.wfile.flush()
            except OSError:
                return                                   # connection dropped


class _BlobTCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.conn_lock = threading.Lock()
        self.conns: set = set()


class BlobServer:
    """Serve one host's :class:`~repro.dist.cache.InputCache` blobs over
    TCP. ``port=0`` picks a free port; :attr:`addr_str` is the dialable
    bound address and :attr:`advertise` the one to publish to the
    coordinator (wildcard host replaced by the hostname)."""

    def __init__(self, cache, host: str = "127.0.0.1", port: int = 0):
        self.cache = cache
        self._srv = _BlobTCPServer((host, port), _BlobHandler)
        self._srv.cache = cache                          # type: ignore[attr-defined]
        self._thread = threading.Thread(
            target=self._srv.serve_forever, name="blob-server", daemon=True)
        self._stopped = False

    @property
    def address(self) -> Tuple[str, int]:
        return self._srv.server_address[:2]

    @property
    def addr_str(self) -> str:
        host, port = self.address
        return f"{host}:{port}"

    @property
    def advertise(self) -> str:
        return advertised_addr(self.address)

    def start(self) -> "BlobServer":
        self._thread.start()
        return self

    def stop(self):
        if self._stopped:        # idempotent: Node.kill + runner teardown
            return
        self._stopped = True
        self._srv.shutdown()
        # as in QueueServer.stop: drop live connections so a peer blocked
        # mid-transfer sees a prompt ConnectionError (and falls back to
        # storage) instead of hanging until its timeout
        with self._srv.conn_lock:
            conns = list(self._srv.conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        self._srv.server_close()

    def __enter__(self) -> "BlobServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()


# ---------------------------------------------------------------------------
# fetch side
# ---------------------------------------------------------------------------

class BlobNotFound(Exception):
    """The peer answered: it does not hold that digest (Bloom false
    positive at the coordinator, or the peer evicted it since its last
    summary delta)."""


class _BlobConn:
    """One persistent connection to a peer blob server. Requests are
    serialized by :attr:`lock` (prefetch threads share the fabric); a
    transport or framing error leaves the stream unusable, so the owner
    drops the whole connection — an explicit :class:`BlobNotFound` leaves
    it aligned (header line, no body) and reusable."""

    def __init__(self, addr: str, timeout_s: float):
        self.addr = addr
        self.lock = threading.Lock()
        host, _, port = addr.rpartition(":")
        self._sock = socket.create_connection((host, int(port)),
                                              timeout=timeout_s)
        self._file = self._sock.makefile("rb")
        self._id = 0

    def get(self, digest: str) -> Tuple[bytes, str]:
        """Request one blob body. The body is read off the socket in
        streaming-ingest-sized chunks with the sha256 folded in as each
        chunk lands (``repro.core.stream`` discipline: hashing overlaps the
        transfer, socket buffers refill while the CPU hashes), so the
        returned ``(data, sha256_hex)`` needs no post-transfer hashing
        pass. The *caller* still owns the verify-vs-requested-digest
        decision."""
        self._id += 1
        self._sock.sendall(json.dumps(
            {"id": self._id, "method": "get",
             "digest": digest}).encode() + b"\n")
        line = self._file.readline()
        if not line:
            raise ConnectionError(
                f"blob peer {self.addr} closed the connection")
        head = json.loads(line)
        if not isinstance(head, dict):
            raise ValueError(f"blob peer {self.addr}: malformed header")
        if not head.get("ok"):
            err = str(head.get("error", ""))
            if "not found" in err:
                raise BlobNotFound(f"{self.addr}: {digest} not held")
            raise ValueError(f"blob peer {self.addr}: {err}")
        size = head.get("size")
        if not isinstance(size, int) or not 0 <= size <= _MAX_BLOB_BYTES:
            raise ValueError(f"blob peer {self.addr}: bad size {size!r}")
        h = hashlib.sha256()
        parts: List[bytes] = []
        remaining = size
        chunk_bytes = stream_chunk_bytes()
        while remaining:
            piece = self._file.read(min(remaining, chunk_bytes))
            if not piece:
                raise ConnectionError(
                    f"blob peer {self.addr}: body truncated at "
                    f"{size - remaining}/{size} bytes")
            h.update(piece)
            parts.append(piece)
            remaining -= len(piece)
        return b"".join(parts), h.hexdigest()

    def close(self):
        try:
            self._file.close()
            self._sock.close()
        except OSError:
            pass


def fetch_blob(addr: str, digest: str, *, timeout_s: float = 5.0) -> bytes:
    """One-shot client: dial ``addr`` (``"host:port"``), request ``digest``,
    return the raw body. Raises :class:`BlobNotFound` on an explicit peer
    404 and ``OSError``/``ValueError`` on transport or framing trouble —
    the caller treats every one of those as "use shared storage". The body
    is returned unverified against ``digest``; :class:`PeerFabric` checks
    the in-flight hash (and reuses connections instead of paying this dial
    per blob)."""
    conn = _BlobConn(addr, timeout_s)
    try:
        return conn.get(digest)[0]
    finally:
        conn.close()


class PeerFabric:
    """The fetch policy an :class:`~repro.dist.cache.InputCache` consults on
    a local miss (:meth:`InputCache.attach_fabric`).

    ``locate`` is any callable ``digests -> {digest: [addr, ...]}`` — in
    production ``WorkQueue.locate_blobs`` via the node's queue handle
    (in-process or :class:`~repro.dist.rpc.QueueClient`), in tests a plain
    dict lookup. Candidates are tried warmest-first; the first peer whose
    bytes hash to the requested digest wins. Every failure mode increments
    its own counter (merged into ``InputCache.stats()``) and the fabric
    never raises — ``None`` means "go read shared storage".

    Version skew: a coordinator that predates ``locate_blobs`` answers
    "unknown method" once; the fabric then disables itself for the rest of
    the run instead of paying a doomed RPC per miss."""

    def __init__(self, locate: Callable[[List[str]], Dict[str, List[str]]],
                 *, self_addr: Optional[str] = None, timeout_s: float = 5.0,
                 max_peers: int = 3, quarantine_s: float = 5.0):
        self.locate = locate
        self.self_addr = self_addr
        self.timeout_s = float(timeout_s)
        self.max_peers = int(max_peers)
        # circuit breaker: a peer whose *connection* failed is skipped for
        # quarantine_s instead of paying a doomed dial (and its timeout) on
        # every subsequent miss — then retried, so a restarted peer rejoins
        self.quarantine_s = float(quarantine_s)
        self._quarantine: Dict[str, float] = {}    # addr -> retry-at (mono)
        self._lock = threading.Lock()
        self._disabled = False
        self._conns: Dict[str, _BlobConn] = {}
        self._counters = {"peer_false_positives": 0, "peer_dead": 0,
                          "peer_digest_mismatches": 0,
                          "peer_locate_failures": 0,
                          "peer_quarantine_skips": 0}

    def _bump(self, key: str):
        with self._lock:
            self._counters[key] += 1

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    # -- connection pool ----------------------------------------------------
    # dialing per blob would put a TCP handshake in front of every fetch —
    # at ~1 MiB blobs that fixed cost is what decides whether the peer link
    # beats the 0.60 Gb/s storage path. One persistent connection per peer;
    # transport errors drop it (next fetch re-dials, so a restarted peer is
    # picked back up), explicit 404s keep it.

    def _conn_for(self, addr: str) -> _BlobConn:
        with self._lock:
            conn = self._conns.get(addr)
        if conn is not None:
            return conn
        conn = _BlobConn(addr, self.timeout_s)      # dial outside the lock
        with self._lock:
            won = self._conns.setdefault(addr, conn)
        if won is not conn:
            conn.close()                             # lost the race: reuse won
        return won

    def _drop(self, addr: str, conn: _BlobConn):
        with self._lock:
            if self._conns.get(addr) is conn:
                del self._conns[addr]
        conn.close()

    # -- quarantine circuit breaker -----------------------------------------
    def _quarantine_peer(self, addr: str):
        if self.quarantine_s <= 0:
            return
        with self._lock:
            self._quarantine[addr] = time.monotonic() + self.quarantine_s

    def _quarantined(self, addr: str) -> bool:
        """True while ``addr`` is inside its quarantine window. Expiry
        clears the entry, so the next fetch re-dials (half-open probe)."""
        with self._lock:
            until = self._quarantine.get(addr)
            if until is None:
                return False
            if time.monotonic() >= until:
                del self._quarantine[addr]
                return False
            return True

    def close(self):
        """Close pooled peer connections (worker shutdown)."""
        with self._lock:
            conns, self._conns = list(self._conns.values()), {}
        for conn in conns:
            conn.close()

    def fetch(self, digest: str) -> Optional[Tuple[bytes, str]]:
        """``(verified bytes, peer addr)`` for ``digest``, or ``None`` when
        no live peer could produce bytes matching it."""
        with self._lock:
            if self._disabled:
                return None
        try:
            located = self.locate([digest]) or {}
        except (ConnectionError, OSError, RuntimeError) as e:
            if "unknown method" in str(e):
                with self._lock:         # pre-fabric coordinator: stand down
                    self._disabled = True
            else:
                self._bump("peer_locate_failures")
            return None
        for addr in list(located.get(digest) or [])[:self.max_peers]:
            if not isinstance(addr, str) or addr == self.self_addr:
                continue
            if self._quarantined(addr):
                self._bump("peer_quarantine_skips")
                continue
            conn = None
            try:
                conn = self._conn_for(addr)
                with conn.lock:
                    data, got_digest = conn.get(digest)
            except BlobNotFound:
                self._bump("peer_false_positives")
                continue
            except (OSError, ValueError):
                if conn is not None:
                    self._drop(addr, conn)     # stream state is unknown
                self._bump("peer_dead")
                self._quarantine_peer(addr)
                continue
            if got_digest != digest:
                # corrupted body or a lying peer: the in-flight hash
                # (folded chunk-by-chunk as the body streamed in) is the
                # fabric's correctness boundary — no post-transfer pass
                self._bump("peer_digest_mismatches")
                continue
            return data, addr
        return None
