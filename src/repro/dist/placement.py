"""The one placement scorer: estimated cache-local bytes of a work unit
against a host's digest summary.

Two schedulers consume this module — and deliberately nothing else scores
placement anywhere in the tree:

* **Grant time** — :class:`repro.dist.queue.WorkQueue` scores every live
  decision (grant / backlog fill / steal / speculation target / dead-node
  requeue) for one running cluster.
* **Admission time** — :mod:`repro.core.campaign` buckets whole job arrays
  by the same score before anything is submitted, so a SLURM campaign lands
  on the hosts that already hold its bytes.

Keeping both on one function is a correctness property, not a style choice:
if admission-time and grant-time scoring drift, the campaign planner seeds a
queue with partitions the queue itself would immediately score differently
and re-shuffle — locality paid for twice, delivered once. A test imports
this function from both call sites and pins them to the same object.

Scores are *estimates* (Bloom false positives, stale summaries) and only
ever shape ordering; correctness is score-blind everywhere.

``summary`` is duck-typed: anything supporting ``len(summary)`` and
``digest in summary`` works (:class:`repro.dist.cache.DigestSummary` in
production, plain sets in tests).
"""
from __future__ import annotations

from typing import (Container, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)


def unit_local_bytes(unit, summary) -> int:
    """Estimated bytes of ``unit``'s inputs already present in ``summary``
    (``Σ input_bytes[s]`` over input digests the summary holds). 0 without a
    usable summary or without manifest digests on the unit — the
    locality-blind fallback, never an error."""
    if summary is None or not len(summary):
        return 0
    digests = getattr(unit, "input_digests", None)
    if not digests:
        return 0
    sizes = unit.input_bytes
    return sum(sizes.get(s, 0) for s, d in digests.items() if d in summary)


def best_node(unit, candidates: Sequence[str], summaries: Mapping[str, object],
              load: Optional[Mapping[str, int]] = None) -> str:
    """The candidate holding the most of ``unit``'s input bytes; ties go to
    the lightest ``load`` (deque depth at grant time, assigned bytes at
    admission time), then lexicographic node id for determinism."""
    load = load or {}
    return min(candidates,
               key=lambda n: (-unit_local_bytes(unit, summaries.get(n)),
                              load.get(n, 0), n))


def best_peers(digest: str, candidates: Sequence[str],
               summaries: Mapping[str, object],
               load: Optional[Mapping[str, int]] = None,
               limit: Optional[int] = None) -> List[str]:
    """Candidates whose summary (probably) holds blob ``digest``, ranked
    warmest-first for the peer fabric: lightest ``load`` first (a busy
    node's disk and NIC are the straggler's), then lexicographic node id
    for determinism. Bloom membership is a *probably* — the fabric treats a
    peer 404 as a false positive and moves on — so ranking only ever shapes
    the order in which peers are tried, never correctness. Same scoring
    household as :func:`best_node`: the queue consumes both, and nothing
    else in the tree ranks placement."""
    load = load or {}
    holders = [n for n in candidates
               if (s := summaries.get(n)) is not None and len(s) and digest in s]
    holders.sort(key=lambda n: (load.get(n, 0), n))
    return holders[:limit] if limit is not None else holders


class WarmSetIndex:
    """Incremental inverse of :func:`unit_local_bytes`: digest → unit posting
    lists built once at admission, folded against each node's known digests
    as summaries arrive, so every placement decision reads a per-node
    ``unit → warm bytes`` dict instead of re-probing Bloom filters for up to
    hundreds of units under the queue lock.

    Three pieces of state:

    * ``_postings`` — ``digest → [(unit_idx, bytes)]`` where *bytes* is the
      summed manifest size of that unit's inputs carrying that digest.
      Immutable after construction; only *referenced* digests exist here, so
      hostile or irrelevant digests in a summary cost one dict miss and no
      memory.
    * ``_held`` — per node, a count per referenced digest (a multiset: the
      counting-Bloom summaries support repeated add/discard of one digest,
      and the index must not zero a score until the last copy drops).
    * ``_scores`` — per node, ``unit_idx → warm bytes`` holding only nonzero
      entries: the node's *warm set*. ``scores(node).items()`` is exactly
      "the units worth sorting" for a backlog fill — everything absent is
      score 0 by construction.

    ``rebuild`` (full summary push) probes every referenced digest against
    the summary, so its scores equal :func:`unit_local_bytes` probe-for-probe
    — Bloom false positives included — unless the wire carries an exact
    ``digests`` list, in which case the index is strictly *more* accurate
    than re-probing. ``add``/``discard`` (summary deltas) are O(delta ×
    posting-list length). Scores remain estimates and only shape ordering;
    correctness stays score-blind everywhere.
    """

    def __init__(self, units: Sequence[object], *,
                 skip: Container[int] = ()):
        """``skip`` excludes unit indices from the posting lists — journal
        recovery rebuilds the index over only still-placeable units, so a
        mostly-finished campaign's restarted coordinator doesn't carry (or
        score against) postings for work that already retired."""
        self._postings: Dict[str, List[Tuple[int, int]]] = {}
        for i, u in enumerate(units):
            if i in skip:
                continue
            digests = getattr(u, "input_digests", None)
            if not digests:
                continue
            sizes = getattr(u, "input_bytes", None) or {}
            per: Dict[str, int] = {}
            for s, d in digests.items():
                per[d] = per.get(d, 0) + sizes.get(s, 0)
            for d, w in per.items():
                if w > 0:
                    self._postings.setdefault(d, []).append((i, w))
        self._held: Dict[str, Dict[str, int]] = {}
        self._scores: Dict[str, Dict[int, int]] = {}

    # -- summary application ------------------------------------------------
    def rebuild(self, node: str, summary,
                digests: Optional[Iterable[str]] = None) -> None:
        """Replace ``node``'s warm set from a full summary push. With an
        exact ``digests`` list the rebuild is exact; otherwise every
        referenced digest is probed via ``d in summary`` (matching
        :func:`unit_local_bytes`, false positives and all)."""
        held: Dict[str, int] = {}
        if digests is not None:
            for d in digests:
                d = str(d)
                if d in self._postings:
                    held[d] = held.get(d, 0) + 1
        elif summary is not None and len(summary):
            for d in self._postings:
                if d in summary:
                    held[d] = 1
        scores: Dict[int, int] = {}
        for d in held:
            for i, w in self._postings[d]:
                scores[i] = scores.get(i, 0) + w
        self._held[node] = held
        self._scores[node] = scores

    def add(self, node: str, digest: str) -> None:
        """Apply one summary-delta ``add``; O(posting list)."""
        if digest not in self._postings:
            return
        held = self._held.setdefault(node, {})
        c = held.get(digest, 0)
        held[digest] = c + 1
        if c:
            return
        scores = self._scores.setdefault(node, {})
        for i, w in self._postings[digest]:
            scores[i] = scores.get(i, 0) + w

    def discard(self, node: str, digest: str) -> None:
        """Apply one summary-delta ``drop``; no-op below zero, mirroring the
        counting-Bloom discard."""
        held = self._held.get(node)
        if not held:
            return
        c = held.get(digest, 0)
        if c == 0:
            return
        if c > 1:
            held[digest] = c - 1
            return
        del held[digest]
        scores = self._scores.get(node) or {}
        for i, w in self._postings[digest]:
            left = scores.get(i, 0) - w
            if left > 0:
                scores[i] = left
            else:
                scores.pop(i, None)

    def drop_node(self, node: str) -> None:
        self._held.pop(node, None)
        self._scores.pop(node, None)

    # -- lookups ------------------------------------------------------------
    def score(self, node: str, unit_idx: int) -> int:
        """Warm bytes of one unit on one node — O(1)."""
        s = self._scores.get(node)
        return s.get(unit_idx, 0) if s else 0

    def scores(self, node: str) -> Mapping[int, int]:
        """The node's warm set (``unit_idx → bytes``, nonzero entries only).
        Callers must not mutate the returned mapping."""
        return self._scores.get(node) or {}

    def best_node(self, unit_idx: int, candidates: Sequence[str],
                  load: Optional[Mapping[str, int]] = None) -> str:
        """Index-backed :func:`best_node`: same tie-break (most warm bytes,
        then lightest load, then lexicographic node id) without touching a
        summary."""
        load = load or {}
        return min(candidates,
                   key=lambda n: (-self.score(n, unit_idx), load.get(n, 0), n))
