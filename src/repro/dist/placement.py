"""The one placement scorer: estimated cache-local bytes of a work unit
against a host's digest summary.

Two schedulers consume this module — and deliberately nothing else scores
placement anywhere in the tree:

* **Grant time** — :class:`repro.dist.queue.WorkQueue` scores every live
  decision (grant / backlog fill / steal / speculation target / dead-node
  requeue) for one running cluster.
* **Admission time** — :mod:`repro.core.campaign` buckets whole job arrays
  by the same score before anything is submitted, so a SLURM campaign lands
  on the hosts that already hold its bytes.

Keeping both on one function is a correctness property, not a style choice:
if admission-time and grant-time scoring drift, the campaign planner seeds a
queue with partitions the queue itself would immediately score differently
and re-shuffle — locality paid for twice, delivered once. A test imports
this function from both call sites and pins them to the same object.

Scores are *estimates* (Bloom false positives, stale summaries) and only
ever shape ordering; correctness is score-blind everywhere.

``summary`` is duck-typed: anything supporting ``len(summary)`` and
``digest in summary`` works (:class:`repro.dist.cache.DigestSummary` in
production, plain sets in tests).
"""
from __future__ import annotations

from typing import List, Mapping, Optional, Sequence


def unit_local_bytes(unit, summary) -> int:
    """Estimated bytes of ``unit``'s inputs already present in ``summary``
    (``Σ input_bytes[s]`` over input digests the summary holds). 0 without a
    usable summary or without manifest digests on the unit — the
    locality-blind fallback, never an error."""
    if summary is None or not len(summary):
        return 0
    digests = getattr(unit, "input_digests", None)
    if not digests:
        return 0
    sizes = unit.input_bytes
    return sum(sizes.get(s, 0) for s, d in digests.items() if d in summary)


def best_node(unit, candidates: Sequence[str], summaries: Mapping[str, object],
              load: Optional[Mapping[str, int]] = None) -> str:
    """The candidate holding the most of ``unit``'s input bytes; ties go to
    the lightest ``load`` (deque depth at grant time, assigned bytes at
    admission time), then lexicographic node id for determinism."""
    load = load or {}
    return min(candidates,
               key=lambda n: (-unit_local_bytes(unit, summaries.get(n)),
                              load.get(n, 0), n))


def best_peers(digest: str, candidates: Sequence[str],
               summaries: Mapping[str, object],
               load: Optional[Mapping[str, int]] = None,
               limit: Optional[int] = None) -> List[str]:
    """Candidates whose summary (probably) holds blob ``digest``, ranked
    warmest-first for the peer fabric: lightest ``load`` first (a busy
    node's disk and NIC are the straggler's), then lexicographic node id
    for determinism. Bloom membership is a *probably* — the fabric treats a
    peer 404 as a false positive and moves on — so ranking only ever shapes
    the order in which peers are tried, never correctness. Same scoring
    household as :func:`best_node`: the queue consumes both, and nothing
    else in the tree ranks placement."""
    load = load or {}
    holders = [n for n in candidates
               if (s := summaries.get(n)) is not None and len(s) and digest in s]
    holders.sort(key=lambda n: (load.get(n, 0), n))
    return holders[:limit] if limit is not None else holders
