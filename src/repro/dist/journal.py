"""Coordinator write-ahead journal: crash durability for the WorkQueue.

The queue's state — backlog, leases, DAG gates, result metadata — lives in
one process's memory; before this module, a coordinator restart lost a
whole campaign. A :class:`Journal` makes every mutation durable with three
files in one directory:

* ``units.json`` — the admitted unit list, written once at attach through
  the same :func:`~repro.core.query.units_to_rows` serialization every
  other units artifact uses. Units are immutable after admission, so the
  (potentially huge) list never rides a snapshot again.
* ``state.json`` — a compaction snapshot of the *mutable* state (epochs,
  terminal statuses, live leases, result metadata, node membership) plus
  the journal sequence number it covers. Written atomically
  (tmp + rename), so a crash mid-compaction leaves the previous snapshot
  intact.
* ``wal.log`` — the append-only record stream since the last snapshot.
  Each record is CRC-framed: ``u32be payload length | u32be crc32(payload)
  | JSON payload``, after an 8-byte magic header. Replay verifies every
  CRC and **truncates the torn tail** — a record cut short by the crash
  (or corrupted on disk) ends the trustworthy prefix; everything before it
  is applied, everything after is dropped and counted.

Record payloads carry a monotonically increasing sequence number ``q``.
Compaction stamps the snapshot with the last sequence it covers and then
truncates the WAL; if the process dies *between* those two steps, replay
simply skips WAL records with ``q <= snapshot.seq`` — the crash window is
idempotent by construction, no record is ever applied twice.

Fsync policy (``fsync=``): ``"always"`` fsyncs every append (an
acknowledged grant is durable, WAN-safe), ``"interval"`` fsyncs at most
every ``fsync_interval_s`` seconds (default: bounded loss of the last few
milliseconds of acknowledgements — the epoch/reap machinery absorbs a
re-granted lease, and the atomic provenance commit absorbs a re-run), and
``"never"`` leaves flushing to the OS (tests, throwaway runs).

The queue side lives in :mod:`repro.dist.queue`: ``WorkQueue(journal=...)``
appends a record inside the queue lock for every mutation, and
``WorkQueue.recover(journal)`` rebuilds a queue from snapshot + tail.

CLI::

    python -m repro.dist.journal inspect <journal-dir>

verifies every CRC (read-only — no truncation) and prints a replay
summary: record counts by type, torn/corrupt tail bytes, and the unit
statuses a recovery would start from.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

_MAGIC = b"RPROWAL1"
_HEADER = 8                      # per-record framing: u32 len + u32 crc
# one record is a lease grant or a completion report — a few hundred bytes.
# Anything past this is a corrupt length field, and replay must not trust
# the rest of the file either way.
MAX_RECORD_BYTES = 8 << 20

FSYNC_POLICIES = ("always", "interval", "never")


class JournalCorrupt(Exception):
    """The journal cannot be trusted at all (bad magic, unreadable
    snapshot/units) — as opposed to a torn tail, which replay repairs."""


class Journal:
    """One coordinator's durable mutation log (see module docstring).

    Thread-safety: :meth:`append` and :meth:`compact` are called under the
    queue lock, but :meth:`close` may race them from another thread (a
    restart harness fencing off the dead incarnation), so the file handle
    is guarded by its own lock. A closed journal silently drops appends —
    that is the fence: a zombie queue keeps mutating its in-memory state
    harmlessly, but can never corrupt the WAL the new incarnation owns.
    """

    def __init__(self, root, *, fsync: str = "interval",
                 fsync_interval_s: float = 0.05,
                 compact_every: int = 4096, now=None):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"unknown fsync policy {fsync!r} "
                             f"(want one of {FSYNC_POLICIES})")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self.fsync_interval_s = float(fsync_interval_s)
        self.compact_every = int(compact_every)
        import time as _time
        self._now = now or _time.monotonic
        self._lock = threading.Lock()
        self._closed = False
        self._wal = None                     # opened on first append/replay
        self._seq = 0
        self._since_snapshot = 0
        self._last_fsync = self._now()

    # -- paths --------------------------------------------------------------

    @property
    def units_path(self) -> Path:
        return self.root / "units.json"

    @property
    def state_path(self) -> Path:
        return self.root / "state.json"

    @property
    def wal_path(self) -> Path:
        return self.root / "wal.log"

    def exists(self) -> bool:
        """True when this directory already holds a journal to recover."""
        return self.units_path.exists()

    # -- write side ---------------------------------------------------------

    def _open_wal_locked(self):
        if self._wal is None:
            fresh = not self.wal_path.exists() \
                or self.wal_path.stat().st_size == 0
            self._wal = open(self.wal_path, "ab")
            if fresh:
                self._wal.write(_MAGIC)
                self._wal.flush()

    def _fsync_dir(self) -> None:
        """Durably record a rename in the journal directory itself — on a
        power loss an un-fsynced directory can resurface the rename with
        the *old* (or no) inode behind it. Best-effort where the platform
        can't fsync a directory handle."""
        try:
            fd = os.open(self.root, os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(fd)
        except OSError:
            pass
        finally:
            os.close(fd)

    def write_units(self, units) -> None:
        """Persist the admitted unit list (once, at attach). Atomic *and*
        durable like the snapshot: the tmp file is fsynced before the
        rename and the directory after it — a rename that survives a power
        loss while its data doesn't would leave a truncated units.json,
        and replay treats an unreadable units.json as
        :class:`JournalCorrupt` (the intact snapshot and WAL become
        unreachable with it)."""
        from ..core.query import units_to_rows
        tmp = self.units_path.with_name(self.units_path.name + ".tmp")
        with open(tmp, "w") as f:
            f.write(json.dumps(units_to_rows(list(units)), indent=1))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.units_path)
        self._fsync_dir()

    def append(self, rec: Dict[str, Any]) -> None:
        """Frame + append one mutation record; fsync per policy. Dropped
        silently after :meth:`close` (the zombie fence)."""
        with self._lock:
            if self._closed:
                return
            self._open_wal_locked()
            self._seq += 1
            rec = dict(rec, q=self._seq)
            payload = json.dumps(rec, separators=(",", ":")).encode()
            self._wal.write(len(payload).to_bytes(4, "big")
                            + zlib.crc32(payload).to_bytes(4, "big")
                            + payload)
            self._since_snapshot += 1
            if self.fsync == "always":
                self._wal.flush()
                os.fsync(self._wal.fileno())
                self._last_fsync = self._now()
            elif self.fsync == "interval":
                self._wal.flush()
                t = self._now()
                if t - self._last_fsync >= self.fsync_interval_s:
                    os.fsync(self._wal.fileno())
                    self._last_fsync = t
            else:
                self._wal.flush()

    def should_compact(self) -> bool:
        with self._lock:
            return (not self._closed
                    and self._since_snapshot >= self.compact_every)

    def compact(self, state: Dict[str, Any]) -> None:
        """Snapshot the mutable state and reset the WAL. Crash-safe in
        both windows: before the rename the old snapshot+WAL still replay;
        between rename and truncate the WAL's records are all ``q <=
        snapshot.seq`` and replay skips them."""
        with self._lock:
            if self._closed:
                return
            state = dict(state, v=1, seq=self._seq)
            tmp = self.state_path.with_name(self.state_path.name + ".tmp")
            tmp.write_text(json.dumps(state, separators=(",", ":")))
            with open(tmp, "rb") as f:
                os.fsync(f.fileno())
            os.replace(tmp, self.state_path)
            self._fsync_dir()
            if self._wal is not None:
                self._wal.close()
            self._wal = open(self.wal_path, "wb")
            self._wal.write(_MAGIC)
            self._wal.flush()
            os.fsync(self._wal.fileno())
            self._since_snapshot = 0
            self._last_fsync = self._now()

    def close(self) -> None:
        """Stop writing, permanently. Safe to call twice, safe to race
        :meth:`append` — the fence a restart harness drops on the dead
        incarnation before recovering the new one."""
        with self._lock:
            self._closed = True
            if self._wal is not None:
                try:
                    self._wal.flush()
                    os.fsync(self._wal.fileno())
                except (OSError, ValueError):
                    pass
                self._wal.close()
                self._wal = None

    # -- read side ----------------------------------------------------------

    def scan_wal(self, *, truncate: bool = False
                 ) -> Tuple[List[dict], int, Optional[str]]:
        """Read the WAL's trustworthy prefix: ``(records, torn_bytes,
        torn_reason)``. A short read, CRC mismatch, oversize length, or
        undecodable payload ends the prefix; with ``truncate=True`` the
        file is cut back to the last good record (recovery), otherwise it
        is left untouched (the read-only ``inspect`` CLI)."""
        if not self.wal_path.exists():
            return [], 0, None
        data = self.wal_path.read_bytes()
        if not data:
            return [], 0, None
        if data[:len(_MAGIC)] != _MAGIC:
            raise JournalCorrupt(
                f"{self.wal_path}: bad magic {data[:len(_MAGIC)]!r}")
        records: List[dict] = []
        off = len(_MAGIC)
        torn_reason = None
        while off < len(data):
            if off + _HEADER > len(data):
                torn_reason = "torn header"
                break
            n = int.from_bytes(data[off:off + 4], "big")
            crc = int.from_bytes(data[off + 4:off + 8], "big")
            if n > MAX_RECORD_BYTES:
                torn_reason = f"length field {n} exceeds cap"
                break
            body = data[off + _HEADER:off + _HEADER + n]
            if len(body) < n:
                torn_reason = "torn payload"
                break
            if zlib.crc32(body) != crc:
                torn_reason = "crc mismatch"
                break
            try:
                rec = json.loads(body)
                if not isinstance(rec, dict):
                    raise ValueError("record must be a JSON object")
            except ValueError:
                torn_reason = "undecodable payload"
                break
            records.append(rec)
            off += _HEADER + n
        torn = len(data) - off
        if torn and truncate:
            with open(self.wal_path, "r+b") as f:
                f.truncate(off)
        return records, torn, torn_reason

    def replay(self, *, truncate: bool = True
               ) -> Tuple[List[dict], Optional[dict], List[dict], int]:
        """Everything recovery needs: ``(unit rows, snapshot state or None,
        tail records with q > snapshot.seq, torn tail bytes)``. Leaves the
        journal positioned for appending (``seq`` continues after the last
        good record)."""
        if not self.exists():
            raise JournalCorrupt(f"{self.root}: no units.json — nothing "
                                 f"was ever journaled here")
        try:
            rows = json.loads(self.units_path.read_text())
        except ValueError as e:
            raise JournalCorrupt(f"{self.units_path}: {e}") from e
        state = None
        if self.state_path.exists():
            try:
                state = json.loads(self.state_path.read_text())
            except ValueError as e:
                raise JournalCorrupt(f"{self.state_path}: {e}") from e
        snap_seq = int(state.get("seq", 0)) if state else 0
        records, _torn, _ = self.scan_wal(truncate=truncate)
        tail = [r for r in records if int(r.get("q", 0)) > snap_seq]
        with self._lock:
            self._seq = max(snap_seq,
                            *(int(r.get("q", 0)) for r in records)) \
                if records else snap_seq
            self._since_snapshot = len(tail)
        return rows, state, tail, _torn


# ---------------------------------------------------------------------------
# CLI: read-only journal inspection for operators
# ---------------------------------------------------------------------------

def _inspect(root: Path) -> int:
    j = Journal(root)
    if not j.exists():
        print(f"{root}: not a journal (no units.json)")
        return 2
    try:
        rows, state, tail, torn = j.replay(truncate=False)
    except JournalCorrupt as e:
        print(f"CORRUPT: {e}")
        print("recovery from this journal is impossible; restart the "
              "campaign from the units file (the work query + provenance "
              "digests skip everything already committed)")
        return 1
    _, _, torn_reason = j.scan_wal(truncate=False)
    snap_seq = int(state.get("seq", 0)) if state else 0
    print(f"journal {root}")
    print(f"  units           : {len(rows)}")
    print(f"  snapshot        : "
          + (f"seq {snap_seq}" if state else "none (WAL only)"))
    print(f"  wal tail records: {len(tail)} (q > {snap_seq})")
    if torn:
        print(f"  torn tail       : {torn} byte(s) dropped ({torn_reason})")
    else:
        print("  torn tail       : none — every CRC verified")
    counts: Dict[str, int] = {}
    for r in tail:
        counts[str(r.get("t"))] = counts.get(str(r.get("t")), 0) + 1
    if counts:
        print("  tail record counts: "
              + ", ".join(f"{t}={n}" for t, n in sorted(counts.items())))
    # the unit statuses a recovery would start from: snapshot terminal
    # statuses + tail completions folded the same way replay folds them
    done: Dict[int, str] = {int(k): str(v)
                            for k, v in (state or {}).get("done", {}).items()}
    leased = {int(le[0]) for le in (state or {}).get("leases", [])}
    for r in tail:
        t = r.get("t")
        if t == "grant":
            leased.add(int(r["i"]))
        elif t == "complete":
            i = int(r["i"])
            if i not in done and r.get("st") in ("ok", "skipped", "failed"):
                done.setdefault(i, str(r["st"]))
                leased.discard(i)
    by_status: Dict[str, int] = {}
    for s in done.values():
        by_status[s] = by_status.get(s, 0) + 1
    pending = len(rows) - len(done)
    print(f"  unit statuses   : "
          + ", ".join(f"{s}={n}" for s, n in sorted(by_status.items()))
          + (", " if by_status else "")
          + f"pending={pending} (of which ~{len(leased - set(done))} "
            f"were leased at the tail)")
    return 0


def _main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="coordinator write-ahead journal tools")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ins = sub.add_parser(
        "inspect", help="verify CRCs and print a replay summary (read-only)")
    ins.add_argument("path", help="journal directory")
    args = ap.parse_args(argv)
    if args.cmd == "inspect":
        raise SystemExit(_inspect(Path(args.path)))


if __name__ == "__main__":
    _main()
