"""Distribution layer: logical-axis sharding rules, compressed collectives,
the multi-node work-stealing executor (``cluster`` + ``queue``), its socket
transport (``rpc``), and the per-host content-addressed input cache
(``cache``)."""
from .cache import DigestSummary, InputCache, cache_from_env
from .cluster import ClusterRunner, ClusterStats, Node, run_worker
from .queue import Lease, WorkQueue
from .sharding import (Rules, attn_shard_choice, constrain, constrain_residual,
                       constrain_params_gathered, current_rules, param_spec_for,
                       param_specs, shardings_for, tp_size, use_rules)

__all__ = [
    "ClusterRunner", "ClusterStats", "Node", "Lease", "WorkQueue",
    "DigestSummary", "InputCache", "cache_from_env", "QueueClient",
    "QueueServer", "run_worker",
    "Rules", "attn_shard_choice", "constrain", "constrain_residual",
    "constrain_params_gathered", "current_rules", "param_spec_for",
    "param_specs", "shardings_for", "tp_size", "use_rules",
]


def __getattr__(name):
    # rpc is loaded lazily so `python -m repro.dist.rpc` (the worker/server
    # CLI) doesn't trip runpy's found-in-sys.modules warning
    if name in ("QueueClient", "QueueServer"):
        from . import rpc
        return getattr(rpc, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
