"""Distribution layer: logical-axis sharding rules, compressed collectives,
the multi-node work-stealing executor (``cluster`` + ``queue``), its socket
transport (``rpc``), the per-host content-addressed input cache (``cache``),
the peer-to-peer blob fabric that serves those caches between hosts
(``blobserve``), and the shared placement scorer (``placement``) both the
queue and the campaign planner rank candidates with."""
from .blobserve import BlobServer, PeerFabric, fetch_blob
from .cache import (DigestSummary, InputCache, cache_from_env,
                    harvest_summary, load_summary_file, save_summary_file,
                    summaries_from_cache_dirs)
from .cluster import ClusterRunner, ClusterStats, Node, run_worker
from .placement import WarmSetIndex, best_node, best_peers, unit_local_bytes
from .queue import Lease, WorkQueue
from .sharding import (Rules, attn_shard_choice, constrain, constrain_residual,
                       constrain_params_gathered, current_rules, param_spec_for,
                       param_specs, shardings_for, tp_size, use_rules)

__all__ = [
    "ClusterRunner", "ClusterStats", "Node", "Lease", "WorkQueue",
    "DigestSummary", "InputCache", "cache_from_env", "QueueClient",
    "QueueServer", "Journal", "JournalCorrupt", "ChaosProxy",
    "BlobServer", "PeerFabric", "fetch_blob", "run_worker",
    "WarmSetIndex", "best_node", "best_peers", "unit_local_bytes",
    "harvest_summary", "load_summary_file", "save_summary_file",
    "summaries_from_cache_dirs",
    "Rules", "attn_shard_choice", "constrain", "constrain_residual",
    "constrain_params_gathered", "current_rules", "param_spec_for",
    "param_specs", "shardings_for", "tp_size", "use_rules",
]


def __getattr__(name):
    # rpc/journal are loaded lazily so `python -m repro.dist.rpc` and
    # `python -m repro.dist.journal` (the CLIs) don't trip runpy's
    # found-in-sys.modules warning
    if name in ("QueueClient", "QueueServer"):
        from . import rpc
        return getattr(rpc, name)
    if name in ("Journal", "JournalCorrupt"):
        from . import journal
        return getattr(journal, name)
    if name == "ChaosProxy":
        from .faults import ChaosProxy
        return ChaosProxy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
