"""Distribution layer: logical-axis sharding rules, compressed collectives,
and the multi-node work-stealing executor (``cluster`` + ``queue``)."""
from .cluster import ClusterRunner, ClusterStats, Node
from .queue import Lease, WorkQueue
from .sharding import (Rules, attn_shard_choice, constrain, constrain_residual,
                       constrain_params_gathered, current_rules, param_spec_for,
                       param_specs, shardings_for, tp_size, use_rules)

__all__ = [
    "ClusterRunner", "ClusterStats", "Node", "Lease", "WorkQueue",
    "Rules", "attn_shard_choice", "constrain", "constrain_residual",
    "constrain_params_gathered", "current_rules", "param_spec_for",
    "param_specs", "shardings_for", "tp_size", "use_rules",
]
