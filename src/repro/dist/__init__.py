"""Distribution layer: logical-axis sharding rules and compressed collectives."""
from .sharding import (Rules, attn_shard_choice, constrain, constrain_residual,
                       constrain_params_gathered, current_rules, param_spec_for,
                       param_specs, shardings_for, tp_size, use_rules)

__all__ = [
    "Rules", "attn_shard_choice", "constrain", "constrain_residual",
    "constrain_params_gathered", "current_rules", "param_spec_for",
    "param_specs", "shardings_for", "tp_size", "use_rules",
]
