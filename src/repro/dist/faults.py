"""Fault-injection TCP proxy: the network weather machine for chaos tests.

:class:`ChaosProxy` sits between :class:`~repro.dist.rpc.QueueClient` and a
:class:`~repro.dist.rpc.QueueServer` (or any TCP pair) and mangles traffic in
the ways real networks and dying hosts do:

* **drop** — a forwarded chunk silently vanishes (the receiver sees a
  desynchronized stream and must tear the connection down);
* **delay** — a chunk stalls for ``delay_s`` before moving on (latency
  spikes, head-of-line blocking);
* **duplicate** — a chunk is forwarded twice (the duplicated bytes corrupt
  the framing exactly like a misbehaving middlebox would);
* **truncate** — half a chunk is forwarded and then *both* sockets are torn
  down: the close-mid-frame case, what a host dying mid-``sendall`` looks
  like from the other end;
* **partition** — :meth:`partition` freezes every pump (bytes neither flow
  nor error) until the partition heals: the connection is alive but the
  network is gone, which is precisely the shape lease reaping exists for.

Faults fire per forwarded chunk from a deterministic per-pump
``random.Random`` seeded by ``seed ^ connection-index ^ direction``, so a
failing chaos run replays byte-for-byte. All probabilities default to 0 —
a fresh proxy is a transparent passthrough; tests opt into exactly the
weather they want. Counters (``stats()``) record what actually fired, so a
"chaos" run that never injected anything fails loudly instead of greenly.

The proxy is protocol-blind on purpose: it corrupts *transport*, never
*semantics*. Whether the system above survives is the queue's epoch fencing
and the client's reconnect discipline — which is what the invariant harness
asserts.
"""
from __future__ import annotations

import random
import socket
import threading
import time
from typing import Dict, Optional, Tuple

_CHUNK = 4096


class ChaosProxy:
    """A TCP proxy that injects transport faults between dial and upstream.

    ``upstream`` is the real server's ``(host, port)``. The proxy listens on
    ``(host, port=0)`` (loopback, ephemeral) — dial :attr:`address` instead
    of the upstream and every connection is pumped through the fault engine.
    Use as a context manager or call :meth:`stop` explicitly.
    """

    def __init__(self, upstream: Tuple[str, int], *, seed: int = 0,
                 drop_rate: float = 0.0, delay_rate: float = 0.0,
                 delay_s: float = 0.02, dup_rate: float = 0.0,
                 truncate_rate: float = 0.0, host: str = "127.0.0.1"):
        self.upstream = tuple(upstream)
        self.seed = int(seed)
        self.drop_rate = float(drop_rate)
        self.delay_rate = float(delay_rate)
        self.delay_s = float(delay_s)
        self.dup_rate = float(dup_rate)
        self.truncate_rate = float(truncate_rate)
        self._listener = socket.create_server((host, 0))
        self._listener.settimeout(0.2)
        self._addr = self._listener.getsockname()
        self._stopped = threading.Event()
        # set = traffic flows; cleared = partitioned (pumps freeze)
        self._open = threading.Event()
        self._open.set()
        self._lock = threading.Lock()
        self._conn_index = 0
        self._counters: Dict[str, int] = {
            "conns": 0, "chunks": 0, "dropped": 0, "delayed": 0,
            "duplicated": 0, "truncated": 0, "partition_stalls": 0,
        }
        self._threads = []
        self._accept_thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ChaosProxy":
        t = threading.Thread(target=self._accept_loop,
                             name="chaos-proxy-accept", daemon=True)
        t.start()
        self._accept_thread = t
        return self

    def stop(self) -> None:
        """Idempotent: stop accepting, tear down every live pump."""
        if self._stopped.is_set():
            return
        self._stopped.set()
        self._open.set()                 # unfreeze pumps so they can exit
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=2.0)
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)

    def __enter__(self) -> "ChaosProxy":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def address(self) -> Tuple[str, int]:
        """What clients dial instead of the upstream."""
        return self._addr

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + n

    # -- partition ----------------------------------------------------------
    def partition(self, on: bool) -> None:
        """``on=True`` freezes every pump mid-stream (no bytes, no errors —
        the network is simply *gone*); ``on=False`` heals it and buffered
        bytes resume. New connections accepted during a partition stall the
        same way, before their upstream dial."""
        if on:
            self._open.clear()
        else:
            self._open.set()

    def _await_open(self) -> bool:
        """Block while partitioned. Returns False if the proxy stopped."""
        if not self._open.is_set():
            self._bump("partition_stalls")
            while not self._open.wait(timeout=0.1):
                if self._stopped.is_set():
                    return False
        return not self._stopped.is_set()

    # -- pumps --------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stopped.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return                   # listener closed by stop()
            with self._lock:
                idx = self._conn_index
                self._conn_index += 1
                self._counters["conns"] += 1
            t = threading.Thread(target=self._serve_conn, args=(conn, idx),
                                 name=f"chaos-proxy-conn-{idx}", daemon=True)
            with self._lock:
                self._threads.append(t)
            t.start()

    def _serve_conn(self, client: socket.socket, idx: int) -> None:
        if not self._await_open():
            client.close()
            return
        try:
            upstream = socket.create_connection(self.upstream, timeout=5.0)
        except OSError:
            client.close()               # upstream down (mid-restart): RST
            return
        dead = threading.Event()         # either pump's death kills both
        pumps = [
            threading.Thread(
                target=self._pump, name=f"chaos-pump-{idx}-up", daemon=True,
                args=(client, upstream, random.Random(self.seed ^ (idx << 1)),
                      dead)),
            threading.Thread(
                target=self._pump, name=f"chaos-pump-{idx}-down", daemon=True,
                args=(upstream, client,
                      random.Random(self.seed ^ (idx << 1) ^ 1), dead)),
        ]
        for p in pumps:
            p.start()
        for p in pumps:
            p.join()
        for s in (client, upstream):
            try:
                s.close()
            except OSError:
                pass

    def _pump(self, src: socket.socket, dst: socket.socket,
              rng: random.Random, dead: threading.Event) -> None:
        try:
            while not self._stopped.is_set() and not dead.is_set():
                src.settimeout(0.2)
                try:
                    chunk = src.recv(_CHUNK)
                except socket.timeout:
                    continue
                except OSError:
                    break
                if not chunk:
                    break
                if not self._await_open():
                    break
                self._bump("chunks")
                r = rng.random()
                if r < self.truncate_rate:
                    # forward half, then hard-close both ends: the
                    # close-mid-frame fault a dying host produces
                    self._bump("truncated")
                    try:
                        dst.sendall(chunk[: max(1, len(chunk) // 2)])
                    except OSError:
                        pass
                    break
                if r < self.truncate_rate + self.drop_rate:
                    self._bump("dropped")
                    continue             # the chunk never happened
                if r < self.truncate_rate + self.drop_rate + self.delay_rate:
                    self._bump("delayed")
                    time.sleep(self.delay_s)
                try:
                    dst.sendall(chunk)
                    if rng.random() < self.dup_rate:
                        self._bump("duplicated")
                        dst.sendall(chunk)
                except OSError:
                    break
        finally:
            dead.set()
            for s in (src, dst):
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
