"""Sharding rules: logical activation axes -> mesh axes, param placement.

The model code never names mesh axes directly. It constrains activations with
*logical* names ("batch", "act_model", "vocab", "cache_seq", ...) which an
active :class:`Rules` instance resolves against the current mesh; outside a
``use_rules`` context every constraint is a no-op, so the same model runs
unsharded on a laptop and sharded on the production mesh.

Three families of rules (``kind``):
  * ``train``   — batch over the data axes, sequence-parallel residuals,
                  Megatron TP over 'model' (+ 'model2' for tp2d meshes).
  * ``prefill`` / ``decode`` — batch over data axes, KV cache sequence-
                  sharded over 'model'.
  * ``long``    — a single long-context sequence: batch replicated, the cache
                  sequence dim sharded over EVERY mesh axis.

Param placement (``param_specs``) is FSDP-style: matmul weights shard their
first core dim over 'data' and their last over 'model'; embeddings are
vocab-sharded over 'model'; norms/biases replicate. Every assignment is
divisibility-guarded — an axis that does not divide the dim is dropped, never
erroring (whisper's 51865-row vocab on a 16-way axis, mamba's width-4 convs).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXES = ("model", "model2")

_ACTIVE = threading.local()


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _scalar(axes: Tuple[str, ...]):
    """() -> None, (a,) -> a, (a, b) -> (a, b): the PartitionSpec convention."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


class Rules:
    """Logical-axis -> mesh-axis map for one (mesh, kind, policy) cell.

    ``map`` is a plain dict (inspectable in tests); ``spec(*names)`` resolves
    a sequence of logical names (or None) into a PartitionSpec.
    """

    def __init__(self, mesh, kind: str = "train", policy: str = "tp",
                 global_batch: Optional[int] = None):
        self.mesh = mesh
        self.kind = kind
        self.policy = policy
        self.global_batch = global_batch
        sizes = _mesh_sizes(mesh)
        data_axes = tuple(a for a in mesh.axis_names if a not in MODEL_AXES)
        model_axes = tuple(a for a in mesh.axis_names if a in MODEL_AXES)
        batch = _scalar(data_axes)
        if global_batch is not None and data_axes:
            n = 1
            for a in data_axes:
                n *= sizes[a]
            if global_batch % n:
                batch = None                      # not divisible: replicate
        model = _scalar(model_axes)
        self.map = {
            "batch": batch,
            "act_model": model,                   # TP axis for activations
            "vocab": model,                       # vocab-parallel head
            "embed": _scalar(data_axes),          # d_model of the lm head
            "cache_seq": model,                   # KV cache sequence dim
            "res_seq": model,                     # sequence-parallel residual
        }
        if kind == "long":
            # one enormous sequence: every chip holds a sequence slice
            self.map["batch"] = None
            self.map["cache_seq"] = _scalar(tuple(mesh.axis_names))

    def spec(self, *names) -> P:
        return P(*[self.map.get(n) if n is not None else None for n in names])


@contextmanager
def use_rules(rules: Rules):
    """Activate ``rules`` for constrain()/tp_size() in this thread."""
    prev = getattr(_ACTIVE, "rules", None)
    _ACTIVE.rules = rules
    try:
        yield rules
    finally:
        _ACTIVE.rules = prev


def current_rules() -> Optional[Rules]:
    return getattr(_ACTIVE, "rules", None)


def tp_size() -> int:
    """Product of the model (TP) axes of the active mesh; 1 outside rules."""
    r = current_rules()
    if r is None:
        return 1
    sizes = _mesh_sizes(r.mesh)
    n = 1
    for a in r.mesh.axis_names:
        if a in MODEL_AXES:
            n *= sizes[a]
    return n


def _axis_n(sizes: dict, ax) -> int:
    if ax is None:
        return 1
    axs = (ax,) if isinstance(ax, str) else ax
    n = 1
    for a in axs:
        n *= sizes.get(a, 1)
    return n


def _guard(spec: P, shape, mesh) -> P:
    """Drop every axis assignment that does not divide its dim."""
    sizes = _mesh_sizes(mesh)
    padded = tuple(spec) + (None,) * (len(shape) - len(spec))
    return P(*[ax if (ax is not None and dim % _axis_n(sizes, ax) == 0) else None
               for dim, ax in zip(shape, padded)])


def constrain(x, *names):
    """with_sharding_constraint under the active rules; identity without."""
    r = current_rules()
    if r is None:
        return x
    spec = _guard(r.spec(*names), x.shape, r.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def constrain_residual(x):
    """Residual stream (B, S, D): batch + sequence-parallel over TP axis."""
    return constrain(x, "batch", "res_seq", None)


def constrain_params_gathered(params):
    """Constrain a (bf16 cast copy of the) param tree TP-only: the FSDP
    ('data') axes are dropped so the all-gather hoists out of microbatch
    scans instead of re-running per microbatch (§Perf G3b)."""
    r = current_rules()
    if r is None:
        return params
    specs = param_specs(params, r.mesh)

    def drop_data(spec: P) -> P:
        out = []
        for ax in spec:
            if ax is None or isinstance(ax, str):
                out.append(ax if ax in MODEL_AXES else None)
            else:
                out.append(_scalar(tuple(a for a in ax if a in MODEL_AXES)))
        return P(*out)

    def apply(w, spec):
        if getattr(w, "ndim", 0) < 1:
            return w
        s = _guard(drop_data(spec), w.shape, r.mesh)
        return jax.lax.with_sharding_constraint(w, NamedSharding(r.mesh, s))

    return jax.tree.map(apply, params, specs)


def attn_shard_choice(KV: int, G: int, q_len: int) -> Optional[str]:
    """Which attention dim should carry the TP axis for a (KV, G) head split.

    Returns None when GSPMD can factor tp = a*b with a | KV and b | G — manual
    constraints would only cause involuntary resharding then. Otherwise pick
    the first dim the TP size divides: query positions ("q"), kv heads
    ("kv"), or the GQA group dim ("g"); None if nothing fits (replicate)."""
    tp = tp_size()
    if tp <= 1:
        return None
    if any(tp % a == 0 and KV % a == 0 and G % (tp // a) == 0
           for a in range(1, tp + 1)):
        return None
    if q_len % tp == 0:
        return "q"
    if KV % tp == 0:
        return "kv"
    if G % tp == 0:
        return "g"
    return None


# ---------------------------------------------------------------------------
# parameter placement
# ---------------------------------------------------------------------------

def param_spec_for(path: str, ndim: int, stacked: bool, shape=None,
                   mesh=None) -> P:
    """PartitionSpec for one param.

    ``stacked`` marks scanned per-layer params whose leading dim is the layer
    dim (always replicated). Embedding tables ("embed" in the path) are
    vocab-sharded over 'model' with d_model over 'data'; other >=2D core
    weights shard (first core dim -> 'data', last -> 'model'); <=1D cores
    (norms, biases) replicate. With ``shape``+``mesh`` the assignment is
    divisibility-guarded."""
    core = ndim - 1 if stacked else ndim
    if core <= 1:
        spec = P(*([None] * ndim))
    else:
        if "embed" in path:
            axes = ["model"] + [None] * (core - 2) + ["data"]
        else:
            axes = ["data"] + [None] * (core - 2) + ["model"]
        if stacked:
            axes = [None] + axes
        spec = P(*axes)
    if shape is not None and mesh is not None:
        spec = _guard(spec, shape, mesh)
    return spec


def param_specs(params, mesh):
    """PartitionSpec tree matching ``params`` (divisibility-guarded)."""
    def name_of(entry) -> str:
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "idx"):
            return str(entry.idx)
        return str(entry)

    def spec_for(path, leaf):
        parts = [name_of(p) for p in path]
        pstr = "/".join(parts)
        stacked = "layers" in parts
        return param_spec_for(pstr, leaf.ndim, stacked, tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings_for(mesh, specs):
    """NamedSharding tree from a PartitionSpec tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
