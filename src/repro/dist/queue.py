"""Shared work-unit queue with per-node leases, tail-stealing, and heartbeat
reaping — the control plane of the multi-node executor (``repro.dist.cluster``).

Protocol (see ``docs/cluster.md`` for the failure model):

* **Partition** — units are dealt round-robin into one deque per node, so an
  N-node cluster starts with balanced locality and zero coordination.
* **Lease** — ``next_unit(node)`` pops the node's own deque head and grants a
  :class:`Lease` carrying a per-unit **epoch** (bumped on every grant). The
  epoch is stamped into the committed provenance, so a record tells apart a
  first-run commit from a post-requeue re-run.
* **Steal** — an idle node steals the *tail half* of the longest peer deque
  (tails preserve the victim's head locality and any prefetch it has issued
  for imminent units). Stealing moves only unleased entries; in-flight work
  is never stolen, only speculated or reaped.
* **Heartbeat + reap** — nodes heartbeat on a timer decoupled from compute
  (a long unit must not look like a dead node). ``reap()`` declares nodes
  whose heartbeat is older than ``lease_ttl_s`` dead, requeues their leased
  units (epoch++) and redistributes their queued entries to the
  least-loaded alive nodes. A reaped "zombie" that later finishes anyway is
  harmless: the provenance commit arbitration admits exactly one ok record.
  ``reap()`` also expires *individual* leases that went a full TTL without
  a renewal on a node that itself still heartbeats: holders renew every
  in-hand lease each heartbeat, so an unrenewed lease on a live node means
  the grant reply never reached the node (a connection dropped mid-reply
  and the client replayed into a fresh grant, or a coordinator crash after
  journaling the grant) — without per-lease expiry such an orphan would
  stay leased forever and the campaign would never finish.
* **Speculate** — ``speculate(idx, node)`` grants a *twin* lease on a
  different node for a straggling unit; twins race the primary through the
  same idempotent commit, and duplicates surface as ``status="speculative"``.
* **Renew** — ``renew(idx, node, epoch)`` is a lease-scoped heartbeat for
  WAN-scale TTLs: it refreshes the holder's liveness *and* confirms the
  lease is still authoritative. A renewal naming a stale epoch (the unit was
  reaped and re-granted), a retired unit, or a dead node is rejected — the
  holder learns it lost the lease instead of heartbeating into the void.
* **Register** — ``register(node)`` joins a node after construction (the
  network-transport case: worker hosts dial in whenever they boot). A queue
  may start with zero nodes; units wait in a backlog that the first
  registrant drains and later registrants steal from.
* **Locality** — nodes push compact digest summaries of their host input
  cache (:class:`~repro.dist.cache.DigestSummary`; full on
  ``register``/``put_summary``, deltas piggybacked on ``heartbeat``/
  ``renew``). Every placement decision — grant, backlog fill, steal,
  speculation target, dead-node redistribution — scores candidate units by
  **estimated cache-local bytes** (``Σ input_bytes[s]`` over input digests
  the node's summary holds) and prefers keeping bytes where they already
  live. Scores come from an incremental **warm-set index**
  (:class:`~repro.dist.placement.WarmSetIndex`): digest→unit posting lists
  built once at admission and folded per-node as summaries and deltas
  arrive, so bulk decisions read precomputed ``unit → warm bytes`` dicts
  instead of re-probing Bloom filters under the lock — backlog fills and
  steals stay scored at 10⁵–10⁶-unit backlogs (the old
  ``LOCALITY_BULK_SCAN_CAP`` blind fallback is gone). Scoring is purely
  advisory: a stale or missing summary degrades to the locality-blind
  behaviour of PR 2/3, never to a wrong schedule. See the placement-policy
  section of ``docs/cluster.md``.
* **Batching** — ``next_units`` / ``complete_batch`` / ``renew_batch``
  wrap N grants/completions/renewals in one lock acquisition (and, over
  rpc, one round trip). Same semantics as N per-op calls; old
  coordinators simply don't export them and new clients shed to per-op.
* **DAG gating** — units carrying ``depends_on`` edges (multi-stage
  curation pipelines) are **parked**: they sit in no deque and no backlog
  until every in-queue parent has retired ``ok``/``skipped`` — i.e. holds
  a committed ok provenance record — at which point the child is released
  exactly once, to its planned home node (or the backlog). Because release
  happens only at retirement, the edge set is epoch-safe for free: a
  reaped parent hasn't retired, so its children stay parked until the
  re-run's commit; a zombie or twin duplicate can't release twice because
  a unit retires exactly once. A terminally ``failed`` parent cascades:
  every transitive descendant lands in a terminal ``blocked`` state —
  counted done, surfaced in ``stats_snapshot()``, never granted.
  Dependency cycles are rejected at construction (``ValueError``); parents
  not present in the queue count as satisfied (the work query already
  excludes complete work).

Everything is guarded by one lock — the queue is the single shared-state
object, and the whole method surface is JSON-serializable by design:
``repro.dist.rpc`` wraps it in a socket server (each call becomes one
JSON-lines RPC to the coordinator) without touching nodes. ``complete``
optionally carries a result ``meta`` payload so a coordinator can fold in
results from worker processes it never shared memory with
(:meth:`WorkQueue.results_snapshot`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from pathlib import PurePath
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from ..core.query import WorkUnit, units_from_rows
from .cache import SUMMARY_WIRE_VERSION, DigestSummary
# best_node / unit_local_bytes are re-exported here on purpose even though
# grants now read the WarmSetIndex: the shared-scorer contract (campaign
# admission and queue grants rank identically) is pinned by identity tests
# against this module's attributes, and the index rebuild reproduces exactly
# their semantics.
from .placement import WarmSetIndex, best_node, best_peers, unit_local_bytes

# grant-time scoring looks this deep into a node's own deque for a
# higher-affinity unit; bounded so a grant disturbs at most a head window of
# the deque ordering even on six-figure unit lists
LOCALITY_SCAN_WINDOW = 16

# locate_blobs answers at most this many digests per call and ranks at most
# this many peers per digest — both bound lock time against a hostile or
# confused client, and three candidates already cover dead-peer + false-
# positive retry without fanning a thundering herd at one warm host
LOCATE_DIGEST_CAP = 256
LOCATE_PEERS_PER_DIGEST = 3


@dataclasses.dataclass(frozen=True)
class Lease:
    """One node's exclusive (or, for twins, speculative) claim on a unit.

    ``local_bytes`` is the coordinator's estimate, at grant time, of how many
    of the unit's input bytes were already in the holder's cache — stamped
    into provenance as ``locality_score`` (normalized) so placement quality
    is auditable after the fact."""
    unit_idx: int
    node_id: str
    epoch: int
    granted_at: float
    speculative: bool = False
    local_bytes: int = 0


class WorkQueue:
    """In-process coordinator state: per-node deques + leases + heartbeats.

    Thread-safe; every public method takes the single internal lock. ``now``
    is injectable for deterministic tests.
    """

    def __init__(self, units: Sequence[WorkUnit],
                 node_ids: Sequence[str] = (), *,
                 lease_ttl_s: float = 2.0, now=time.time,
                 locality: bool = True, partition: str = "round_robin",
                 plan=None, journal=None):
        if plan is not None:
            partition = "plan"
        if partition not in ("round_robin", "backlog", "plan"):
            raise ValueError(f"unknown partition {partition!r}")
        if partition == "plan" and plan is None:
            raise ValueError('partition="plan" needs a plan')
        self.units = list(units)
        self.lease_ttl_s = float(lease_ttl_s)
        self.locality = bool(locality)
        self._now = now
        self._lock = threading.Lock()
        self._queues: Dict[str, Deque[int]] = {n: deque() for n in node_ids}
        # with no nodes yet (network transport: workers register later) the
        # units wait in a backlog; otherwise round-robin partition as before.
        # partition="backlog" keeps even a node-listed queue unpartitioned so
        # the (locality-scored) backlog fill decides initial placement once
        # nodes have pushed their cache summaries. A ``plan``
        # (:class:`repro.core.campaign.CampaignPlan`, or its loaded-JSON
        # shape) seeds each node's deque from its admission-time shard, so
        # the queue starts already warm instead of rediscovering locality.
        #
        # The backlog deque is consumed lazily: warm (scored) fills delete
        # membership from ``_backlog_seq`` and leave a stale deque entry
        # behind for the FIFO pop to skip, so no fill ever rebuilds the
        # deque. ``_backlog_seq`` doubles as the admission-order key (front
        # appends count down, back appends count up), which is what scored
        # fills use to break warmth ties in FIFO order.
        self._backlog: Deque[int] = deque()
        self._backlog_seq: Dict[int, int] = {}
        self._backlog_front = 0
        self._backlog_back = 1
        # DAG state, built before dealing so _admit can park non-ready units.
        # _parents holds each child's *unsatisfied* parent idxs (entries
        # drain as parents retire ok); _children the forward edges; _parked
        # maps a waiting child to its planned home node (None = backlog), so
        # release lands it exactly where the partition/plan wanted it.
        # Edges naming job_ids outside this queue are satisfied by
        # definition: the work query excludes already-complete work, so an
        # absent parent means "done before this submission".
        self._by_job: Dict[str, int] = {}
        for i, u in enumerate(self.units):
            self._by_job.setdefault(u.job_id, i)
        self._parents: Dict[int, set] = {}
        self._children: Dict[int, List[int]] = {}
        for i, u in enumerate(self.units):
            deps = getattr(u, "depends_on", None) or ()
            ps = {self._by_job[str(d)] for d in deps
                  if str(d) in self._by_job}
            if ps:
                self._parents[i] = ps
                for p in sorted(ps):
                    self._children.setdefault(p, []).append(i)
        self._check_acyclic()
        self._parked: Dict[int, Optional[str]] = {}
        if plan is not None:
            self._seed_from_plan(plan)
        elif node_ids and partition == "round_robin":
            for i in range(len(self.units)):
                self._admit(i, node_ids[i % len(node_ids)])
        else:
            for i in range(len(self.units)):
                self._admit(i, None)
        self._epochs: Dict[int, int] = {i: 0 for i in range(len(self.units))}
        self._leases: Dict[int, Lease] = {}          # primary lease per unit
        self._spec: Dict[int, Lease] = {}            # at most one twin per unit
        self._spec_queues: Dict[str, Deque[int]] = {n: deque() for n in node_ids}
        self._started: Dict[int, float] = {}         # compute began (post-prefetch)
        self._done: Dict[int, str] = {}              # unit idx -> terminal status
        self._failed_pending: Dict[int, str] = {}    # primary failed, twin racing
        self._heartbeats: Dict[str, float] = {n: now() for n in node_ids}
        self._dead: set = set()
        self.steals: Dict[str, int] = {n: 0 for n in node_ids}
        self.requeues: List[int] = []                # reaped unit idxs (log)
        self.renew_rejections: int = 0               # stale-lease renew count
        # locality state: per-node cache digest summaries (pushed by nodes)
        # plus the cache stats that piggyback on the same wire, the
        # incremental warm-set index every placement decision reads, and the
        # placement counters operators read from stats_snapshot(). The
        # summaries stay authoritative for blob location (locate_blobs
        # probes arbitrary digests); the index only covers digests this
        # queue's units reference.
        self._summaries: Dict[str, DigestSummary] = {}
        self._warm = WarmSetIndex(self.units)
        self._cache_stats: Dict[str, Dict[str, int]] = {}
        # peer-fabric state: blob-server addresses nodes advertised on
        # register/heartbeat (absence = "don't route peers at me"), plus
        # routing counters for stats_snapshot()
        self._blob_addrs: Dict[str, str] = {}
        self.fabric_stats: Dict[str, int] = {
            "locates": 0,             # locate_blobs calls answered
            "located_digests": 0,     # digests answered with >=1 peer
            "unlocated_digests": 0,   # digests no live peer (probably) holds
        }
        self._steal_rr = 0                           # round-robin tie cursor
        self.locality_stats: Dict[str, int] = {
            "scored_grants": 0,       # grants where affinity picked the unit
            "blind_grants": 0,        # grants with no usable summary/score
            "local_bytes_granted": 0,  # Σ estimated cache-local bytes granted
            "input_bytes_granted": 0,  # Σ total input bytes granted
            "steals_scored": 0,       # steals shaped by affinity scoring
            "steals_blind": 0,        # plain tail-half steals
            "stolen_local_bytes": 0,  # Σ thief-local bytes of stolen units
            "summary_rejected": 0,    # summary wires we couldn't decode
        }
        # result metadata carried by complete(meta=...): the retiring
        # completion per unit, plus every duplicate report (twin losers,
        # zombies) — what a coordinator folds into its result list for units
        # finished by worker processes it never shared memory with
        self._primary_meta: Dict[int, dict] = {}
        self._primary_log: List[dict] = []           # same entries, in order
        self._pending_meta: Dict[int, dict] = {}     # deferred primary failure
        self._dup_meta: List[dict] = []
        # durability (docs/cluster.md): with a Journal attached, every
        # mutation that changes what a restarted coordinator must know —
        # grants, completions, renewals, node joins, deaths — appends one
        # record under the lock it already holds, and compaction snapshots
        # the mutable state whenever the WAL grows past the journal's
        # threshold. _replaying gates the append sites while recover()
        # re-drives the same code paths from the log.
        self._journal = None
        self._replaying = False
        if journal is not None:
            journal.write_units(self.units)
            with self._lock:
                self._journal = journal
                journal.compact(self._snapshot_state_locked())

    def _seed_from_plan(self, plan):
        """Deal units into per-node deques per an admission-time campaign
        plan. Duck-typed: ``plan.shards`` (or ``plan["shards"]``) of
        ``{node_id, unit_ids}`` records, so both a live
        :class:`~repro.core.campaign.CampaignPlan` and its parsed
        ``campaign.json`` work. Fail-soft by construction: shard entries
        naming unknown units are ignored, shards targeting unknown/absent
        nodes fall to the backlog (the locality-scored fill re-places them),
        and units the plan never mentions are backlogged too — a stale or
        partial plan degrades to PR 3 behaviour, never to lost work."""
        if isinstance(plan, (str, PurePath)):
            # a campaign.json path is explicit intent, not a stale artifact:
            # load it (version-checked) rather than duck-typing it to an
            # attribute-less string and silently backlogging everything
            from ..core.campaign import CampaignPlan
            plan = CampaignPlan.load(plan)
        shards = plan.get("shards", []) if isinstance(plan, dict) \
            else getattr(plan, "shards", [])
        by_job = {u.job_id: i for i, u in enumerate(self.units)}
        seeded: set = set()
        for shard in shards:
            if isinstance(shard, dict):
                node_id, unit_ids = shard.get("node_id"), shard.get("unit_ids")
            else:
                node_id = getattr(shard, "node_id", None)
                unit_ids = getattr(shard, "unit_ids", None)
            home = node_id if node_id in self._queues else None
            for jid in unit_ids or []:
                i = by_job.get(jid)
                if i is None or i in seeded:
                    continue
                seeded.add(i)
                self._admit(i, home)
        for i in range(len(self.units)):
            if i not in seeded:
                self._admit(i, None)

    # -- DAG gating ----------------------------------------------------------
    # Callers hold the lock (or run from __init__ before the queue is
    # shared). Correctness hinges on two facts: a unit retires exactly once
    # (every terminal transition funnels through _retire), and a parked unit
    # is in no deque/backlog, so nothing — grants, steals, backlog fills,
    # speculation, dead-node redistribution — can hand it out early.

    def _check_acyclic(self):
        """Kahn's algorithm over the in-queue edges; raises ``ValueError``
        naming the cyclic units. Cycles (including self-dependencies) would
        otherwise deadlock the queue as permanently-parked work."""
        remaining = {i: set(ps) for i, ps in self._parents.items()}
        ready = [i for i in range(len(self.units)) if i not in remaining]
        while ready:
            nxt: List[int] = []
            for p in ready:
                for c in self._children.get(p, ()):
                    ps = remaining.get(c)
                    if ps is not None:
                        ps.discard(p)
                        if not ps:
                            del remaining[c]
                            nxt.append(c)
            ready = nxt
        if remaining:
            cyc = sorted(self.units[i].job_id for i in remaining)
            raise ValueError(
                "depends_on cycle among work units: " + ", ".join(cyc))

    def _admit(self, idx: int, node_id: Optional[str]):
        """Deal ``idx`` to its home: parked (remembering the planned home
        for release) while any parent is unsatisfied, else straight onto the
        node's deque — or the backlog when ``node_id`` is None."""
        if self._parents.get(idx):
            self._parked[idx] = node_id
        elif node_id is None:
            self._backlog_append(idx)
        else:
            self._queues[node_id].append(idx)

    def _retire(self, idx: int, status: str):
        """The single point where a unit becomes terminal. On ``ok``/
        ``skipped`` — the unit's provenance commit is durable — satisfy its
        out-edges and release each child that just became ready, exactly
        once: to its planned home if that node is still alive, else the
        backlog. On ``failed`` (retries exhausted), cascade: every
        transitive descendant is necessarily still parked (a child releases
        only when *all* parents committed ok), so each lands terminally
        ``blocked`` without ever having been granted."""
        self._done[idx] = status
        if status in ("ok", "skipped"):
            for c in self._children.get(idx, ()):
                ps = self._parents.get(c)
                if ps is None:
                    continue
                ps.discard(idx)
                if ps or c in self._done:
                    continue
                home = self._parked.pop(c, None)
                if home is not None and home in self._queues \
                        and home not in self._dead:
                    self._queues[home].append(c)
                else:
                    self._backlog_append(c)
        elif status == "failed":
            stack = list(self._children.get(idx, ()))
            while stack:
                c = stack.pop()
                if c in self._done:
                    continue
                self._done[c] = "blocked"
                self._parked.pop(c, None)
                stack.extend(self._children.get(c, ()))

    def _retire_meta(self, idx: int, entry: dict):
        """Record the completion that retired ``idx``: keyed for the final
        fold, appended to the ordered log for incremental polling. Each unit
        retires exactly once, so the log never rewrites an entry."""
        self._primary_meta[idx] = entry
        self._primary_log.append(entry)

    # -- backlog bookkeeping -------------------------------------------------
    # Callers hold the lock. Membership and ordering live in _backlog_seq;
    # the deque exists only to give the FIFO pop its order without scans.

    def _backlog_append(self, idx: int):
        self._backlog.append(idx)
        self._backlog_seq[idx] = self._backlog_back
        self._backlog_back += 1

    def _backlog_appendleft(self, idx: int):
        self._backlog.appendleft(idx)
        self._backlog_front -= 1
        self._backlog_seq[idx] = self._backlog_front

    def _backlog_pop_fifo(self) -> Optional[int]:
        """Oldest live backlog entry, skipping entries a warm fill already
        took (stale deque copies) and units retired while parked."""
        while self._backlog:
            idx = self._backlog.popleft()
            if self._backlog_seq.pop(idx, None) is None:
                continue
            if idx not in self._done:
                return idx
        return None

    # -- durability (write-ahead journal) ------------------------------------
    # Callers hold the lock. The journal sees exactly the mutations a
    # restart must reconstruct; placement state (deques, backlog order,
    # summaries, the warm-set index) is deliberately NOT journaled —
    # recovery rebuilds it from scratch and reconnecting workers re-push
    # their summaries, so the log stays small and placement stays advisory.

    def _journal_append(self, rec: dict):
        j = self._journal
        if j is None or self._replaying:
            return
        j.append(rec)

    def _journal_maybe_compact(self):
        """Compaction runs only at public-method boundaries, never inside
        :meth:`_journal_append`: an append can precede its mutation (a
        death record lands before the leases are torn down), and a snapshot
        taken mid-mutation would claim the record's seq without containing
        its effect — replay would then skip the record and lose the event."""
        j = self._journal
        if j is not None and not self._replaying and j.should_compact():
            j.compact(self._snapshot_state_locked())

    def _snapshot_state_locked(self) -> dict:
        """The mutable-state snapshot compaction writes: everything a
        recovery needs that isn't the (immutable) unit list. JSON object
        keys must be strings, so int-keyed maps are stringified here and
        re-intified in :meth:`recover`."""
        leases = [[l.unit_idx, l.node_id, l.epoch,
                   1 if l.speculative else 0, l.local_bytes]
                  for l in list(self._leases.values())
                  + list(self._spec.values())]
        return {
            "nodes": list(self._queues),
            "dead": sorted(self._dead),
            "blob_addrs": dict(self._blob_addrs),
            "epochs": {str(i): e for i, e in self._epochs.items() if e},
            "done": {str(i): s for i, s in self._done.items()},
            "leases": leases,
            "failed_pending": {str(i): s
                               for i, s in self._failed_pending.items()},
            "pending_meta": {str(i): dict(m)
                             for i, m in self._pending_meta.items()},
            "primary_log": [dict(m) for m in self._primary_log],
            "dup_meta": [dict(m) for m in self._dup_meta],
            "requeues": list(self.requeues),
            "steals": dict(self.steals),
            "renew_rejections": self.renew_rejections,
        }

    def _apply_record(self, rec: dict):
        """Re-drive one WAL record during recovery (``_replaying`` is set,
        nothing is re-journaled). Completion and death records go through
        the real code paths so retirement/DAG-release/dup arbitration
        replay exactly as they ran; grants apply minimally (epoch + lease)
        because the normalization pass at the end of :meth:`recover`
        rebuilds all placement state anyway. Unknown record types are
        skipped — an old coordinator replaying a newer journal degrades to
        ignoring what it can't parse rather than crashing."""
        t = rec.get("t")
        try:
            if t == "register":
                n = str(rec["n"])
                if n in self._dead:
                    return
                if n not in self._queues:
                    self._queues[n] = deque()
                    self._spec_queues[n] = deque()
                    self.steals.setdefault(n, 0)
                    self._heartbeats[n] = self._now()
                b = rec.get("b")
                if b:
                    self._blob_addrs[n] = str(b)
            elif t == "grant":
                i, n, e = int(rec["i"]), str(rec["n"]), int(rec["e"])
                if i in self._done or e <= self._epochs.get(i, 0) \
                        or n not in self._queues or n in self._dead:
                    return
                self._epochs[i] = e
                spec = bool(rec.get("s"))
                lease = Lease(i, n, e, self._now(), speculative=spec,
                              local_bytes=int(rec.get("lb", 0)))
                (self._spec if spec else self._leases)[i] = lease
            elif t == "complete":
                m = rec.get("m")
                self._complete_locked(
                    int(rec["i"]), str(rec["n"]), str(rec["st"]),
                    speculative=bool(rec.get("s")),
                    meta=m if isinstance(m, dict) else None)
            elif t == "dead":
                self._declare_dead(str(rec["n"]))
            elif t == "expire":
                # re-drives the same drop/settle path; the requeue side is
                # irrelevant mid-replay (normalization rebuilds placement)
                self._expire_lease(int(rec["i"]), bool(rec.get("s")))
            elif t == "renew":
                pass    # pure liveness: recovery re-stamps every clock
        except (KeyError, TypeError, ValueError):
            pass        # a malformed-but-CRC-valid record loses one event,
            #             never the recovery

    @classmethod
    def recover(cls, journal, *, lease_ttl_s: float = 2.0, now=time.time,
                locality: bool = True) -> "WorkQueue":
        """Rebuild a queue from a dead coordinator's journal: replay
        snapshot + WAL tail (torn tail truncated), then normalize.

        What comes back durable: unit list, terminal statuses + result
        metadata, DAG gates (drained to match the done set), per-unit
        epochs, node membership incl. deaths, and in-flight leases — which
        resolve through the ordinary epoch/reap machinery: every lease
        restarts its TTL clock *now*, so a holder that reconnects and
        renews keeps its lease, and one that died with the old coordinator
        is reaped exactly like any other silent node. What is deliberately
        rebuilt fresh rather than restored: all placement state — every
        grantable unit returns to the backlog in admission order, spec
        twins re-enter their node's speculative queue, and the warm-set
        index is re-derived (summaries re-arrive as workers reconnect and
        re-push). Duplicate post-restart completions are harmless by the
        same arbitration that already absorbs zombies and twins."""
        rows, state, tail, _torn = journal.replay()
        q = cls(units_from_rows(rows), (), lease_ttl_s=lease_ttl_s,
                now=now, locality=locality)
        with q._lock:
            q._replaying = True
            st = state or {}
            for n in st.get("nodes", []):
                n = str(n)
                if n not in q._queues:
                    q._queues[n] = deque()
                    q._spec_queues[n] = deque()
                    q.steals.setdefault(n, 0)
                    q._heartbeats[n] = q._now()
            for n in st.get("dead", []):
                q._dead.add(str(n))
            for n, a in (st.get("blob_addrs") or {}).items():
                if str(n) not in q._dead:
                    q._blob_addrs[str(n)] = str(a)
            for i, e in (st.get("epochs") or {}).items():
                q._epochs[int(i)] = int(e)
            # terminal statuses, then drain the DAG gates to match: an
            # ok/skipped parent's edge is satisfied, any done unit leaves
            # the parked set (release/cascade already happened pre-crash)
            for i, s in (st.get("done") or {}).items():
                q._done[int(i)] = str(s)
            for i, s in q._done.items():
                q._parked.pop(i, None)
                if s in ("ok", "skipped"):
                    for c in q._children.get(i, ()):
                        ps = q._parents.get(c)
                        if ps is not None:
                            ps.discard(i)
            for c in [c for c, ps in q._parents.items()
                      if not ps and c not in q._done]:
                q._parked.pop(c, None)
            for le in st.get("leases", []):
                try:
                    i, n, e = int(le[0]), str(le[1]), int(le[2])
                    spec, lb = bool(le[3]), int(le[4])
                except (TypeError, ValueError, IndexError):
                    continue
                if i in q._done or n in q._dead or n not in q._queues:
                    continue
                lease = Lease(i, n, e, q._now(), speculative=spec,
                              local_bytes=lb)
                (q._spec if spec else q._leases)[i] = lease
            for i, s in (st.get("failed_pending") or {}).items():
                q._failed_pending[int(i)] = str(s)
            for i, m in (st.get("pending_meta") or {}).items():
                if isinstance(m, dict):
                    q._pending_meta[int(i)] = dict(m)
            for m in st.get("primary_log", []):
                if isinstance(m, dict) and "idx" in m:
                    q._retire_meta(int(m["idx"]), dict(m))
            q._dup_meta.extend(dict(m) for m in st.get("dup_meta", [])
                               if isinstance(m, dict))
            q.requeues.extend(int(i) for i in st.get("requeues", []))
            for n, c in (st.get("steals") or {}).items():
                q.steals[str(n)] = int(c)
            q.renew_rejections = int(st.get("renew_rejections", 0))
            for rec in tail:
                q._apply_record(rec)
            # normalization: placement state is rebuilt from scratch.
            # Mid-replay deque/backlog churn (requeues, DAG releases) left
            # stale entries; clearing and re-dealing makes "grantable ⇔
            # exactly one of backlog/lease" an invariant rather than an
            # accident of replay order.
            for n in q._queues:
                q._queues[n].clear()
                q._spec_queues[n].clear()
            q._backlog.clear()
            q._backlog_seq.clear()
            q._backlog_front, q._backlog_back = 0, 1
            for i in range(len(q.units)):
                if i in q._done or i in q._parked or i in q._leases:
                    continue
                q._backlog_append(i)
            t0 = q._now()
            for i, l in list(q._spec.items()):
                if i in q._done or l.node_id in q._dead:
                    q._spec.pop(i)
                    continue
                q._spec_queues[l.node_id].append(i)
                q._spec[i] = dataclasses.replace(l, granted_at=t0)
            for i, l in list(q._leases.items()):
                q._leases[i] = dataclasses.replace(l, granted_at=t0)
            q._started.clear()
            # one full TTL of grace for every surviving node to reconnect
            # to the new incarnation before the reaper may declare it dead
            for n in q._queues:
                if n not in q._dead:
                    q._heartbeats[n] = t0
            q._warm = WarmSetIndex(q.units, skip=q._done)
            q._replaying = False
            q._journal = journal
            journal.compact(q._snapshot_state_locked())
        return q

    # -- locality scoring ----------------------------------------------------
    # All helpers assume the caller holds the lock. Scores are *estimates*
    # (Bloom false positives, stale summaries) and only ever shape ordering —
    # correctness (exactly-one-ok, lease epochs, reaping) is score-blind.

    def _local_bytes(self, idx: int, node_id: str) -> int:
        """Estimated bytes of unit ``idx``'s inputs already in ``node_id``'s
        host cache — an O(1) warm-set index lookup. 0 without a summary
        (old client, no cache, version skew) — the locality-blind fallback.
        The index's full-push rebuild probes the same shared
        admission/grant scorer semantics
        (:func:`repro.dist.placement.unit_local_bytes`), so campaign plans
        and live grants can never rank the same unit differently."""
        if not self.locality:
            return 0
        return self._warm.score(node_id, idx)

    def _node_scores(self, node_id: str) -> bool:
        """Whether scoring can distinguish anything for this node."""
        s = self._summaries.get(node_id)
        return self.locality and s is not None and len(s) > 0

    def _best_node(self, idx: int, candidates: List[str]) -> str:
        """The candidate holding the most of ``idx``'s input bytes; ties go
        to the shallowest deque, then lexicographic for determinism — the
        index-backed twin of :func:`repro.dist.placement.best_node`."""
        if not self.locality:
            return min(candidates,
                       key=lambda n: (len(self._queues[n]), n))
        return self._warm.best_node(
            idx, candidates, {n: len(q) for n, q in self._queues.items()})

    def _apply_summary_wire(self, node_id: str, wire) -> bool:
        """Fold a summary push (full or delta) into the per-node state.
        Anything malformed or version-skewed is counted and dropped — the
        node stays schedulable, just locality-blind. Returns True iff the
        wire was understood and applied."""
        if node_id in self._dead or node_id not in self._queues:
            return False
        if not isinstance(wire, dict) or wire.get("v") != 1:
            self.locality_stats["summary_rejected"] += 1
            return False
        stats = wire.get("stats")
        if isinstance(stats, dict):
            self._cache_stats[node_id] = dict(stats)
        if "full" in wire:
            summary = DigestSummary.from_wire(wire["full"])
            if summary is None:
                self.locality_stats["summary_rejected"] += 1
                return False
            self._summaries[node_id] = summary
            if self.locality:
                # an exact digest list on the wire (new caches send one)
                # makes the rebuild exact; otherwise probe the Bloom filter
                # for every referenced digest, matching unit_local_bytes
                digests = wire.get("digests")
                self._warm.rebuild(
                    node_id, summary,
                    digests=digests if isinstance(digests, list) else None)
            return True
        summary = self._summaries.setdefault(node_id, DigestSummary())
        try:
            for d in wire.get("add") or []:
                summary.add(str(d))
                if self.locality:
                    self._warm.add(node_id, str(d))
            for d in wire.get("drop") or []:
                summary.discard(str(d))
                if self.locality:
                    self._warm.discard(node_id, str(d))
        except (TypeError, ValueError):
            self.locality_stats["summary_rejected"] += 1
            return False
        return True

    # -- leasing ------------------------------------------------------------

    def _grant(self, idx: int, node_id: str, speculative: bool,
               local_bytes: int = 0) -> Lease:
        self._epochs[idx] += 1
        lease = Lease(idx, node_id, self._epochs[idx], self._now(),
                      speculative=speculative, local_bytes=local_bytes)
        (self._spec if speculative else self._leases)[idx] = lease
        rec = {"t": "grant", "i": idx, "n": node_id, "e": lease.epoch,
               "lb": local_bytes}
        if speculative:
            rec["s"] = 1
        self._journal_append(rec)
        return lease

    def _pop_scored(self, node_id: str) -> Optional[Tuple[int, int]]:
        """Pop the next unit off ``node_id``'s deque: the highest-affinity
        unit within the head scan window when the node has a usable summary,
        the plain head otherwise (exact PR 2 behaviour). Returns
        ``(unit_idx, estimated_local_bytes)`` or ``None`` on an empty deque.
        Retired entries encountered anywhere in the window are dropped, so
        the pop — and therefore :meth:`next_unit` — never hands out a done
        unit."""
        q = self._queues[node_id]
        while True:
            while q and q[0] in self._done:
                q.popleft()
            if not q:
                return None
            if not self._node_scores(node_id):
                self.locality_stats["blind_grants"] += 1
                return q.popleft(), 0
            best_pos, best_score = None, -1
            dead: List[int] = []
            for pos in range(min(len(q), LOCALITY_SCAN_WINDOW)):
                idx = q[pos]
                if idx in self._done:
                    dead.append(pos)
                    continue
                score = self._local_bytes(idx, node_id)
                if score > best_score:     # ties keep the earliest (FIFO)
                    best_pos, best_score = pos, score
            for pos in reversed(dead):     # drop retired entries for good
                del q[pos]
            if best_pos is None:
                continue                   # window was all retired: rescan
            best_pos -= sum(1 for p in dead if p < best_pos)
            idx = q[best_pos]
            del q[best_pos]
            key = "scored_grants" if best_score > 0 else "blind_grants"
            self.locality_stats[key] += 1
            self.locality_stats["local_bytes_granted"] += max(0, best_score)
            self.locality_stats["input_bytes_granted"] += \
                self.units[idx].total_input_bytes
            return idx, max(0, best_score)

    def _next_unit_locked(self, node_id: str
                          ) -> Optional[Tuple[WorkUnit, Lease]]:
        if node_id in self._dead or node_id not in self._queues:
            return None
        sq = self._spec_queues[node_id]
        while sq:
            idx = sq.popleft()
            if idx in self._done:
                self._spec.pop(idx, None)
                continue
            lease = self._spec.get(idx)
            if lease is None:
                continue                   # twin evaporated while queued
            # delivery starts the twin's expiry clock: while the entry sat
            # in this queue the lease couldn't be lost in flight, so only
            # from here on does "unrenewed for a TTL" mean a lost grant
            lease = dataclasses.replace(lease, granted_at=self._now())
            self._spec[idx] = lease
            return self.units[idx], lease
        q = self._queues[node_id]
        if not q:
            self._fill_from_backlog(node_id)
        if not q:
            self._steal_into(node_id)
        got = self._pop_scored(node_id)   # never returns a retired unit
        if got is None:
            return None
        idx, score = got
        return self.units[idx], self._grant(idx, node_id, False,
                                            local_bytes=score)

    def next_unit(self, node_id: str) -> Optional[Tuple[WorkUnit, Lease]]:
        """Lease the next unit for ``node_id``: own speculative work first,
        then the best-affinity unit near the node's own deque head, then a
        (locality-scored) share of the registration backlog, then steal the
        lowest-affinity half of the fullest peer deque. Returns ``None``
        when no leasable work exists *right now* (the node should re-poll
        until :meth:`finished`) — including for unknown node ids, so a
        transport client that skipped :meth:`register` fails soft."""
        with self._lock:
            got = self._next_unit_locked(node_id)
            self._journal_maybe_compact()
            return got

    def next_units(self, node_id: str, max_units: int = 1
                   ) -> List[Tuple[WorkUnit, Lease]]:
        """Batched :meth:`next_unit`: up to ``max_units`` grants under one
        lock acquisition (over rpc: one round trip). Stops early when no
        leasable work exists right now; a short batch means exactly what a
        ``None`` from :meth:`next_unit` means."""
        out: List[Tuple[WorkUnit, Lease]] = []
        with self._lock:
            for _ in range(max(1, int(max_units))):
                got = self._next_unit_locked(node_id)
                if got is None:
                    break
                out.append(got)
            self._journal_maybe_compact()
        return out

    def _fill_from_backlog(self, node_id: str):
        """Move a fair share of never-partitioned units (queue built with no
        nodes or ``partition="backlog"``, or orphans reaped while no node was
        alive) onto ``node_id``'s deque — late registrants then rebalance via
        ordinary stealing. With a usable summary the share is the node's
        **top-k by cache-local bytes** (warmest first, so prefetch starts on
        the warmest work); otherwise FIFO, exactly the PR 3 behaviour.

        Cost is O(warm-set · log + k), independent of backlog depth: the
        warm candidates come straight off the node's warm-set index entry,
        so a million-unit backlog no longer forces the blind-FIFO fallback
        the old ``LOCALITY_BULK_SCAN_CAP`` imposed."""
        if not self._backlog_seq:
            return
        alive = max(1, sum(1 for n in self._queues if n not in self._dead))
        k = max(1, len(self._backlog_seq) // alive)
        q = self._queues[node_id]
        chosen: List[int] = []
        if self._node_scores(node_id):
            # intersect warm set and backlog by iterating whichever is
            # smaller — a deep backlog against a small cache scans the warm
            # set, a drained backlog against a big cache scans the backlog
            scores = self._warm.scores(node_id)
            if len(scores) <= len(self._backlog_seq):
                warm = [(idx, s) for idx, s in scores.items()
                        if idx in self._backlog_seq and idx not in self._done]
            else:
                warm = [(idx, s) for idx in self._backlog_seq
                        if (s := scores.get(idx, 0)) > 0
                        and idx not in self._done]
            # warmest first; ties in backlog (admission) order — the exact
            # ordering the old full sort produced
            warm.sort(key=lambda t: (-t[1], self._backlog_seq[t[0]]))
            for idx, _ in warm[:k]:
                del self._backlog_seq[idx]
                chosen.append(idx)
        while len(chosen) < k:
            idx = self._backlog_pop_fifo()
            if idx is None:
                break
            chosen.append(idx)
        q.extend(chosen)                    # warmest-first order

    def _steal_into(self, thief: str):
        """Steal half of the fullest peer deque. Victim ties break by a
        round-robin cursor over the tied node ids (deterministic for a fixed
        steal sequence, fair across victims — ``max`` on ``(len, node_id)``
        used to bias every tie toward the lexicographically-last node).
        With usable summaries the thief takes the entries that are
        **coldest for the victim** (preferring, among those, warmest for the
        thief); blind, it takes the tail half, preserving the victim's head
        locality and prefetch exactly as before.

        The scored selection reads both warm-set index entries — one cheap
        pass over the victim deque plus a sort of only the warm entries —
        so it stays scored at any depth (the old cap fell back to blind
        tail-half past 512 entries)."""
        lens = {n: len(q) for n, q in self._queues.items()
                if n != thief and n not in self._dead and len(q)}
        if not lens:
            return
        deepest = max(lens.values())
        tied = sorted(n for n, l in lens.items() if l == deepest)
        victim = tied[self._steal_rr % len(tied)]
        self._steal_rr += 1
        vq = self._queues[victim]
        k = max(1, len(vq) // 2)
        if self._node_scores(thief) or self._node_scores(victim):
            wv = self._warm.scores(victim) if self.locality else {}
            wt = self._warm.scores(thief) if self.locality else {}
            # selection order (matches the old full sort on
            # (victim_bytes, -thief_bytes, -pos)): victim-cold entries first
            # — thief-warm ones ahead of plain cold, tail-first within each —
            # then victim-warm entries coldest-first. Only warm entries get
            # sorted; the cold majority is consumed tail-first as-is.
            cold_thief_warm: List[Tuple[int, int, int]] = []
            cold_positions: List[int] = []
            victim_warm: List[Tuple[int, int, int, int]] = []
            for p, idx in enumerate(vq):
                v = wv.get(idx, 0)
                if v > 0:
                    victim_warm.append((v, -wt.get(idx, 0), -p, p))
                elif (t := wt.get(idx, 0)) > 0:
                    cold_thief_warm.append((-t, -p, p))
                else:
                    cold_positions.append(p)
            cold_thief_warm.sort()
            victim_warm.sort()
            sel = [e[-1] for e in cold_thief_warm]
            sel.extend(reversed(cold_positions))
            sel.extend(e[-1] for e in victim_warm)
            take = set(sel[:k])
            grabbed = [idx for p, idx in enumerate(vq) if p in take]
            self._queues[victim] = deque(idx for p, idx in enumerate(vq)
                                         if p not in take)
            self.locality_stats["steals_scored"] += 1
            self.locality_stats["stolen_local_bytes"] += \
                sum(wt.get(i, 0) for i in grabbed)
        else:
            grabbed = [vq.pop() for _ in range(k)]
            # reverse: popping the tail reversed the order; keep victim's order
            grabbed = list(reversed(grabbed))
            self.locality_stats["steals_blind"] += 1
        self._queues[thief].extend(grabbed)
        self.steals[thief] += 1

    def mark_started(self, idx: int):
        """Compute (not prefetch) began — the straggler clock starts here."""
        with self._lock:
            self._started.setdefault(idx, self._now())

    def complete(self, idx: int, node_id: str, status: str, *,
                 speculative: bool = False, meta: Optional[dict] = None):
        """Record a terminal result for a lease.

        Primary leases retire the unit on ``ok``/``skipped``; a terminal
        ``failed`` (retries exhausted — same semantics as ``LocalRunner``)
        retires it only when no speculative twin is still racing — otherwise
        retirement is deferred so the twin's ok can still save the unit. A
        twin retires the unit on ``ok``/``skipped``, and on ``failed`` only
        settles a deferred primary failure (both racers lost). Results from
        nodes already declared dead are ignored for retirement — their unit
        was requeued, and the provenance commit arbitration already made any
        late zombie write harmless — and late completions of already-done
        units are no-ops.

        ``meta`` (JSON-safe: e.g. ``{"seconds": ..., "attempts": ...,
        "error": ...}``) attaches the worker-side result to the completion so
        a coordinator that never shared memory with the worker can rebuild
        its result list: the retiring completion's meta lands in
        :meth:`results_snapshot` ``primaries``, every non-retiring report
        (twin losers, zombies, late duplicates) in ``duplicates``."""
        with self._lock:
            self._complete_locked(idx, node_id, status,
                                  speculative=speculative, meta=meta)
            self._journal_maybe_compact()

    def complete_batch(self, completions: Sequence[dict]):
        """Batched :meth:`complete`: N terminal reports under one lock
        acquisition (over rpc: one round trip). Each entry is a JSON-safe
        dict ``{"idx", "node_id", "status"}`` plus optional ``speculative``
        and ``meta`` — the same arguments, same semantics, same order as N
        per-op calls. Malformed entries are dropped (fail-soft: the worker
        retries nothing, exactly as a lost per-op duplicate report)."""
        with self._lock:
            for c in completions:
                if not isinstance(c, dict):
                    continue
                try:
                    idx = int(c["idx"])
                    node_id = str(c["node_id"])
                    status = str(c["status"])
                except (KeyError, TypeError, ValueError):
                    continue
                meta = c.get("meta")
                self._complete_locked(
                    idx, node_id, status,
                    speculative=bool(c.get("speculative", False)),
                    meta=meta if isinstance(meta, dict) else None)
            self._journal_maybe_compact()

    def _complete_locked(self, idx: int, node_id: str, status: str, *,
                         speculative: bool = False,
                         meta: Optional[dict] = None):
        # every report is journaled — retiring or not — so replay re-runs
        # the exact same arbitration (twin races, zombie dups, deferred
        # failures) the live queue ran, instead of a cleaned-up history
        rec = {"t": "complete", "i": idx, "n": node_id, "st": status}
        if speculative:
            rec["s"] = 1
        if meta is not None:
            rec["m"] = meta
        self._journal_append(rec)
        entry = None
        if meta is not None:
            entry = {"idx": idx, "node_id": node_id, "status": status,
                     "speculative": speculative, **meta}
        if node_id in self._dead:
            if entry is not None:
                self._dup_meta.append(entry)
            return
        if speculative:
            spec = self._spec.get(idx)
            if spec is not None and spec.node_id == node_id:
                self._spec.pop(idx)
            if idx in self._done:
                if entry is not None:
                    self._dup_meta.append(entry)
                return
            if status in ("ok", "skipped"):
                self._retire(idx, status)
                self._started.pop(idx, None)
                self._failed_pending.pop(idx, None)
                # the twin won: its result is the unit's result, and the
                # deferred primary failure (if any) is superseded
                self._pending_meta.pop(idx, None)
                if entry is not None:
                    self._retire_meta(idx, entry)
            elif idx in self._failed_pending:
                self._retire(idx, self._failed_pending.pop(idx))
                pend = self._pending_meta.pop(idx, None)
                if pend is not None:
                    self._retire_meta(idx, pend)
                if entry is not None:
                    self._dup_meta.append(entry)
            elif entry is not None:
                self._dup_meta.append(entry)
            return
        lease = self._leases.get(idx)
        if lease is not None and lease.node_id == node_id:
            self._leases.pop(idx)
            self._started.pop(idx, None)
        if idx in self._done:
            if entry is not None:
                self._dup_meta.append(entry)
            return
        if status == "failed" and idx in self._spec:
            self._failed_pending[idx] = status   # twin still racing
            if entry is not None:
                self._pending_meta[idx] = entry
            return
        self._retire(idx, status)
        self._failed_pending.pop(idx, None)
        self._pending_meta.pop(idx, None)
        if entry is not None:
            self._retire_meta(idx, entry)

    def renew(self, idx: int, node_id: str, epoch: int,
              summary_delta=None) -> bool:
        """Lease-scoped heartbeat for WAN-scale TTLs: refresh ``node_id``'s
        liveness *and* confirm its lease on ``idx`` (primary or twin) is still
        authoritative at ``epoch``. Returns False — without touching any
        state — when the lease is gone: the node is dead, the unit retired,
        or the unit was reaped and re-granted (epoch bumped), in which case
        the caller is now a zombie and should not expect its commit to win.
        A successful renewal refreshes the lease's ``granted_at``.

        ``summary_delta`` optionally piggybacks a cache digest-summary delta
        (same wire as :meth:`heartbeat`) so WAN workers renewing long leases
        keep their placement summaries fresh without extra round trips. It is
        applied even when the renewal itself is rejected — a zombie's cache
        contents are still real.

        ``renew_rejections`` counts only the *interesting* rejections (dead
        node, wrong holder, stale epoch) — a renew that loses an ordinary
        race with its own unit's completion is not a lost lease and stays
        out of the WAN-health signal."""
        with self._lock:
            if summary_delta is not None:
                self._apply_summary_wire(node_id, summary_delta)
            ok = self._renew_locked(idx, node_id, epoch)
            self._journal_maybe_compact()
            return ok

    def renew_batch(self, node_id: str, leases: Sequence[Sequence[int]],
                    summary_delta=None) -> List[bool]:
        """Batched :meth:`renew` for every lease a node holds: one lock
        acquisition (over rpc: one round trip) renews ``leases`` — a list of
        ``[unit_idx, epoch]`` pairs — and applies the piggybacked
        ``summary_delta`` once. Returns one verdict per pair, in order;
        malformed pairs are simply rejected (False), same fail-soft posture
        as every other wire surface."""
        with self._lock:
            if summary_delta is not None:
                self._apply_summary_wire(node_id, summary_delta)
            out: List[bool] = []
            for pair in leases:
                try:
                    idx, epoch = int(pair[0]), int(pair[1])
                except (TypeError, ValueError, IndexError):
                    out.append(False)
                    continue
                out.append(self._renew_locked(idx, node_id, epoch))
            self._journal_maybe_compact()
            return out

    def _renew_locked(self, idx: int, node_id: str, epoch: int) -> bool:
        if idx in self._done:
            return False                 # completed: routine, not counted
        if node_id in self._dead:
            self.renew_rejections += 1
            return False
        lease = self._leases.get(idx)
        if lease is None or lease.node_id != node_id or lease.epoch != epoch:
            lease = self._spec.get(idx)
        if lease is None or lease.node_id != node_id or lease.epoch != epoch:
            self.renew_rejections += 1
            return False
        self._heartbeats[node_id] = self._now()
        renewed = dataclasses.replace(lease, granted_at=self._now())
        (self._spec if lease.speculative else self._leases)[idx] = renewed
        self._journal_append({"t": "renew", "n": node_id, "i": idx,
                              "e": epoch})
        return True

    # -- speculation --------------------------------------------------------

    def speculate(self, idx: int, node_id: Optional[str] = None
                  ) -> Optional[Lease]:
        """Queue a speculative twin of ``idx`` on ``node_id`` (must differ
        from the primary lease holder; at most one twin per unit). With
        ``node_id=None`` the queue places the twin itself, on the alive node
        holding the most of the unit's input bytes (ties: shallowest deque) —
        a straggler's twin starts fastest where its inputs are already warm."""
        with self._lock:
            lease = self._leases.get(idx)
            if idx in self._done or idx in self._spec or lease is None:
                return None
            if node_id is None:
                candidates = [n for n in self._queues
                              if n not in self._dead and n != lease.node_id]
                if not candidates:
                    return None
                node_id = self._best_node(idx, candidates)
            if lease.node_id == node_id or node_id in self._dead \
                    or node_id not in self._queues:
                return None
            twin = self._grant(idx, node_id, True,
                               local_bytes=self._local_bytes(idx, node_id))
            self._spec_queues[node_id].append(idx)
            self._journal_maybe_compact()
            return twin

    def running(self) -> List[Tuple[int, float, str]]:
        """Units in compute: (idx, started_at, node) for straggler checks."""
        with self._lock:
            return [(i, t0, self._leases[i].node_id)
                    for i, t0 in self._started.items()
                    if i not in self._done and i in self._leases]

    # -- heartbeats + failure handling --------------------------------------

    def register(self, node_id: str, summary=None, blob_addr=None) -> bool:
        """Join ``node_id`` to the cluster after construction — the network-
        transport path where worker hosts dial in whenever they boot. A new
        node starts with an empty deque and picks up work from the backlog or
        by stealing. Re-registering an alive node just refreshes its
        heartbeat; a reaped node id stays dead (rejoin under a fresh id).

        ``summary`` optionally carries the host cache's full digest summary
        (``InputCache.summary_sync()`` wire), so a worker with a warm cache
        from a previous run is placed locality-aware from its very first
        grant. ``blob_addr`` optionally advertises the host's blob server
        (``host:port``) for the peer fabric; a worker that runs no blob
        server omits it and :meth:`locate_blobs` never routes peers at it.
        Old clients simply omit both — locality-blind and fabric-invisible,
        never rejected."""
        with self._lock:
            if node_id in self._dead:
                return False
            fresh = node_id not in self._queues
            if fresh:
                self._queues[node_id] = deque()
                self._spec_queues[node_id] = deque()
                self.steals.setdefault(node_id, 0)
            self._heartbeats[node_id] = self._now()
            if summary is not None:
                self._apply_summary_wire(node_id, summary)
            if blob_addr:
                addr_changed = self._blob_addrs.get(node_id) != str(blob_addr)
                self._blob_addrs[node_id] = str(blob_addr)
            else:
                addr_changed = False
            if fresh or addr_changed:
                rec = {"t": "register", "n": node_id}
                if node_id in self._blob_addrs:
                    rec["b"] = self._blob_addrs[node_id]
                self._journal_append(rec)
            self._journal_maybe_compact()
            return True

    def put_summary(self, node_id: str, summary) -> bool:
        """Replace ``node_id``'s cache digest summary (full-state push, the
        ``InputCache.summary_sync()`` wire). Nodes call it at loop start and
        whenever their delta cursor falls off the cache's op window. Unknown
        or dead nodes, and wires this coordinator version cannot decode, are
        dropped (counted in ``summary_rejected``) — locality degrades,
        scheduling never breaks. Returns True iff the summary was applied."""
        with self._lock:
            return self._apply_summary_wire(node_id, summary)

    def heartbeat(self, node_id: str, summary_delta=None, blob_addr=None):
        """Node-level liveness refresh. ``summary_delta`` optionally
        piggybacks the host cache's digest-summary delta since the node's
        last push (``InputCache.summary_delta_since()`` wire: a handful of
        added/dropped digests plus live cache counters) — the few-bytes
        message that keeps coordinator-side placement scoring current.
        ``blob_addr`` re-advertises the host's blob server, so a worker
        whose register predates the coordinator restart still becomes
        routable within one heartbeat."""
        with self._lock:
            # unknown ids are dropped (not auto-registered): a reap must never
            # see a heartbeat for a node that has no deque to clean up
            if node_id not in self._dead and node_id in self._queues:
                self._heartbeats[node_id] = self._now()
                if summary_delta is not None:
                    self._apply_summary_wire(node_id, summary_delta)
                if blob_addr and \
                        self._blob_addrs.get(node_id) != str(blob_addr):
                    self._blob_addrs[node_id] = str(blob_addr)
                    self._journal_append({"t": "register", "n": node_id,
                                          "b": str(blob_addr)})
                    self._journal_maybe_compact()

    def mark_dead(self, node_id: str):
        """Explicit fail-fast path (e.g. a node's thread crashed)."""
        with self._lock:
            self._declare_dead(node_id)
            self._journal_maybe_compact()

    def reap(self) -> List[int]:
        """Declare heartbeat-expired nodes dead; requeue their leased units
        (epoch bumps on re-grant) and redistribute their queued entries onto
        the least-loaded alive nodes. Then expire individual leases a full
        TTL past their last renewal even though their holder still
        heartbeats — the lost-grant case (see the module docstring): the
        node never learned of the lease, so nobody will ever renew,
        complete, or free it. Returns the requeued unit idxs."""
        with self._lock:
            now = self._now()
            newly_dead = [n for n, hb in self._heartbeats.items()
                          if n not in self._dead and now - hb > self.lease_ttl_s]
            requeued: List[int] = []
            for n in newly_dead:
                requeued.extend(self._declare_dead(n))
            requeued.extend(self._expire_stale_leases(now))
            self._journal_maybe_compact()
            return requeued

    def _expire_stale_leases(self, now: float) -> List[int]:
        """Caller holds the lock. Reclaim leases whose ``granted_at`` is
        older than ``lease_ttl_s`` while the holding node is alive: nodes
        renew every in-hand lease on each heartbeat (refreshing
        ``granted_at``), so staleness on a live node means the grant was
        lost in flight. Dead holders are left to :meth:`_declare_dead` —
        it already requeued (or will requeue) everything they held."""
        requeued: List[int] = []
        for idx, lease in list(self._leases.items()):
            if lease.node_id not in self._dead \
                    and now - lease.granted_at > self.lease_ttl_s:
                requeued.extend(self._expire_lease(idx, False))
        for idx, lease in list(self._spec.items()):
            if lease.node_id in self._dead \
                    or now - lease.granted_at <= self.lease_ttl_s:
                continue
            if idx in self._spec_queues.get(lease.node_id, ()):
                # still queued coordinator-side: the twin was never handed
                # out, so nothing was lost in flight — delivery (the spec
                # pop in _next_unit_locked) restarts its expiry clock
                continue
            self._expire_lease(idx, True)
        return requeued

    def _expire_lease(self, idx: int, speculative: bool) -> List[int]:
        """Caller holds the lock. Drop one stale lease and requeue its unit
        (primary) or settle a deferred primary failure (twin — mirroring
        the dead-node twin path). The epoch is deliberately *not* bumped
        here: the next grant bumps it, so a re-run outranks the lost
        lease, while a holder that merely received the grant late can
        still complete — its report retires the unit through the ordinary
        arbitration and the stale deque entry is skipped as done."""
        lease = (self._spec if speculative else self._leases).pop(idx, None)
        if lease is None:
            return []
        rec = {"t": "expire", "i": idx}
        if speculative:
            rec["s"] = 1
        self._journal_append(rec)
        if speculative:
            # an expired twin evaporates; if the primary already failed and
            # was only waiting on this twin, the unit settles as failed
            if idx in self._failed_pending and idx not in self._done:
                self._retire(idx, self._failed_pending.pop(idx))
                pend = self._pending_meta.pop(idx, None)
                if pend is not None:
                    self._retire_meta(idx, pend)
            return []
        self._started.pop(idx, None)
        if idx in self._done:
            return []
        alive = [n for n in self._queues if n not in self._dead]
        if alive:
            self._queues[self._best_node(idx, alive)].appendleft(idx)
        else:
            self._backlog_appendleft(idx)
        self.requeues.append(idx)
        return [idx]

    def _declare_dead(self, node_id: str) -> List[int]:
        if node_id in self._dead:
            return []
        self._journal_append({"t": "dead", "n": node_id})
        self._dead.add(node_id)
        alive = [n for n in self._queues if n not in self._dead]
        orphans: List[int] = []
        # leased-but-unfinished units held by the dead node
        for idx, lease in list(self._leases.items()):
            if lease.node_id == node_id and idx not in self._done:
                self._leases.pop(idx)
                self._started.pop(idx, None)
                orphans.append(idx)
        # a twin on a dead node just evaporates — the primary still runs,
        # unless the primary already failed and was waiting on this twin
        for idx, lease in list(self._spec.items()):
            if lease.node_id == node_id:
                self._spec.pop(idx)
                if idx in self._failed_pending and idx not in self._done:
                    self._retire(idx, self._failed_pending.pop(idx))
                    pend = self._pending_meta.pop(idx, None)
                    if pend is not None:
                        self._retire_meta(idx, pend)
        self._spec_queues[node_id].clear()
        self._summaries.pop(node_id, None)   # dead cache scores nothing
        self._warm.drop_node(node_id)        # and holds no warm set
        self._blob_addrs.pop(node_id, None)  # and serves no peers
        # unleased entries still sitting in its deque
        orphans.extend(i for i in self._queues[node_id] if i not in self._done)
        self._queues[node_id].clear()
        if alive:
            for idx in orphans:
                # affinity-aware requeue: a survivor that already holds the
                # orphan's bytes re-runs it off local disk; with no summary
                # coverage this degrades to least-loaded, as before
                target = self._best_node(idx, alive)
                # front of the queue: requeued work is the oldest work
                self._queues[target].appendleft(idx)
        else:
            # nobody alive to take them: park in the backlog so a later
            # register() (network transport) can still finish the job
            for idx in reversed(orphans):
                self._backlog_appendleft(idx)
        self.requeues.extend(orphans)
        return orphans

    # -- introspection ------------------------------------------------------

    def finished(self) -> bool:
        with self._lock:
            return len(self._done) == len(self.units)

    def pending(self) -> int:
        with self._lock:
            return len(self.units) - len(self._done)

    def alive_nodes(self) -> List[str]:
        with self._lock:
            return [n for n in self._queues if n not in self._dead]

    def done_status(self) -> Dict[int, str]:
        with self._lock:
            return dict(self._done)

    def queue_depths(self) -> Dict[str, int]:
        with self._lock:
            return {n: len(q) for n, q in self._queues.items()}

    def results_snapshot(self) -> Dict[str, object]:
        """Everything ``complete(meta=...)`` has recorded so far:
        ``{"primaries": {idx: entry}, "duplicates": [entry, ...]}`` where an
        entry is the JSON-safe completion record (idx, node_id, status,
        speculative, plus the caller's meta). ``primaries`` holds the
        completion that retired each unit; ``duplicates`` every non-retiring
        report. A coordinator folds these into its result list for units
        finished by nodes in other processes."""
        with self._lock:
            return {"primaries": {i: dict(m)
                                  for i, m in self._primary_meta.items()},
                    "duplicates": [dict(m) for m in self._dup_meta]}

    def primary_log(self, start: int = 0) -> List[dict]:
        """Retiring completions in retirement order, from offset ``start`` —
        the incremental feed a coordinator polls each tick (pass the count
        it has already consumed) instead of re-copying the full snapshot."""
        with self._lock:
            return [dict(m) for m in self._primary_log[start:]]

    def stats_snapshot(self) -> Dict[str, object]:
        """Control-plane counters in one JSON-safe call (the transport client
        mirrors these as properties): steals, requeues, renew rejections,
        plus the data-movement view operators previously had to grep
        provenance for — per-node cache counters (as last piggybacked on
        heartbeats: hits/misses/evictions/bytes_from_cache/bytes_from_storage)
        with a cluster-wide ``cache_totals`` roll-up, the placement
        counters (``locality``: scored vs blind grants, granted local bytes,
        steal affinity stats, rejected summary wires), and the DAG view
        (``dag``: units ready to run vs parked blocked behind unfinished
        parents vs cancelled — terminally blocked by a failed ancestor —
        plus per-stage/pipeline progress). Old rpc clients simply ignore
        the extra key."""
        with self._lock:
            totals: Dict[str, int] = {}
            for st in self._cache_stats.values():
                for k, v in st.items():
                    if isinstance(v, (int, float)):
                        totals[k] = totals.get(k, 0) + v
            hits = totals.get("hits", 0)
            lookups = hits + totals.get("misses", 0)
            # per-link byte meter: {fetcher: {peer addr: bytes}} as last
            # piggybacked on heartbeats — who pulled how much from whom
            peer_links = {n: dict(st["peer_bytes_by_addr"])
                          for n, st in self._cache_stats.items()
                          if isinstance(st.get("peer_bytes_by_addr"), dict)
                          and st["peer_bytes_by_addr"]}
            # DAG progress: blocked = parked behind unfinished parents,
            # cancelled = terminally blocked by a failed ancestor, ready =
            # everything grantable or in flight right now
            cancelled = sum(1 for s in self._done.values() if s == "blocked")
            per_stage: Dict[str, Dict[str, int]] = {}
            for i, u in enumerate(self.units):
                row = per_stage.setdefault(u.pipeline, {
                    "total": 0, "ok": 0, "failed": 0, "cancelled": 0,
                    "blocked": 0, "ready": 0})
                row["total"] += 1
                s = self._done.get(i)
                if s in ("ok", "skipped"):
                    row["ok"] += 1
                elif s == "blocked":
                    row["cancelled"] += 1
                elif s is not None:
                    row["failed"] += 1
                elif i in self._parked:
                    row["blocked"] += 1
                else:
                    row["ready"] += 1
            dag = {"ready": (len(self.units) - len(self._done)
                             - len(self._parked)),
                   "blocked": len(self._parked),
                   "cancelled": cancelled,
                   "per_stage": per_stage}
            return {"steals": dict(self.steals),
                    "requeues": list(self.requeues),
                    "renew_rejections": self.renew_rejections,
                    "locality": dict(self.locality_stats),
                    "summary_nodes": sorted(self._summaries),
                    "cache": {n: dict(st)
                              for n, st in self._cache_stats.items()},
                    "cache_totals": totals,
                    "cache_hit_rate": (hits / lookups) if lookups else 0.0,
                    "fabric": dict(self.fabric_stats),
                    "fabric_nodes": sorted(self._blob_addrs),
                    "peer_links": peer_links,
                    "dag": dag}

    def locate_blobs(self, digests: Sequence[str],
                     node_id: Optional[str] = None) -> Dict[str, List[str]]:
        """Peer candidates for content-addressed blobs: ``{digest: [blob
        server addr, ...]}`` ranked warmest-first
        (:func:`~repro.dist.placement.best_peers` over the digest summaries
        this coordinator already holds). Only alive nodes that advertised a
        blob server are candidates, and the requester (``node_id``) never
        gets itself back. Membership is Bloom-probabilistic — a candidate
        may 404, the fetcher falls back — and digests no live peer holds
        are simply absent from the answer, so an empty dict is the honest
        "go read shared storage". Bounded (``LOCATE_DIGEST_CAP`` digests,
        ``LOCATE_PEERS_PER_DIGEST`` peers each) to keep lock time flat."""
        with self._lock:
            self.fabric_stats["locates"] += 1
            out: Dict[str, List[str]] = {}
            cand = [n for n in self._queues
                    if n not in self._dead and n != node_id
                    and n in self._blob_addrs]
            if not cand:
                self.fabric_stats["unlocated_digests"] += min(
                    len(digests), LOCATE_DIGEST_CAP)
                return out
            load = {n: len(q) for n, q in self._queues.items()}
            for digest in list(digests)[:LOCATE_DIGEST_CAP]:
                if not isinstance(digest, str):
                    continue
                holders = best_peers(digest, cand, self._summaries, load,
                                     limit=LOCATE_PEERS_PER_DIGEST)
                if holders:
                    out[digest] = [self._blob_addrs[n] for n in holders]
                    self.fabric_stats["located_digests"] += 1
                else:
                    self.fabric_stats["unlocated_digests"] += 1
            return out

    def summaries_snapshot(self) -> Dict[str, dict]:
        """Per-alive-node cache digest summaries as versioned full wires
        (``{node_id: {"v": 1, "full": ...}}``) — what the campaign planner
        (:mod:`repro.core.campaign`) pulls from a live coordinator to shard
        the *next* cohort's job array by where bytes already sit. Served
        over rpc like the rest of the surface; empty when no node has
        pushed a summary (the planner then degrades to blind admission)."""
        with self._lock:
            return {n: {"v": SUMMARY_WIRE_VERSION, "full": s.to_wire()}
                    for n, s in self._summaries.items() if n not in self._dead}

    def active_leases(self) -> Dict[str, str]:
        """``job_id -> node_id`` for every in-flight lease (primary + twin) —
        the view :func:`repro.core.query.query_available_work` consumes to
        avoid double-submitting leased sessions."""
        with self._lock:
            out = {self.units[i].job_id: l.node_id
                   for i, l in self._leases.items() if i not in self._done}
            for i, l in self._spec.items():
                if i not in self._done:
                    out.setdefault(self.units[i].job_id, l.node_id)
            return out
