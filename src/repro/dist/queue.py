"""Shared work-unit queue with per-node leases, tail-stealing, and heartbeat
reaping — the control plane of the multi-node executor (``repro.dist.cluster``).

Protocol (see ``docs/cluster.md`` for the failure model):

* **Partition** — units are dealt round-robin into one deque per node, so an
  N-node cluster starts with balanced locality and zero coordination.
* **Lease** — ``next_unit(node)`` pops the node's own deque head and grants a
  :class:`Lease` carrying a per-unit **epoch** (bumped on every grant). The
  epoch is stamped into the committed provenance, so a record tells apart a
  first-run commit from a post-requeue re-run.
* **Steal** — an idle node steals the *tail half* of the longest peer deque
  (tails preserve the victim's head locality and any prefetch it has issued
  for imminent units). Stealing moves only unleased entries; in-flight work
  is never stolen, only speculated or reaped.
* **Heartbeat + reap** — nodes heartbeat on a timer decoupled from compute
  (a long unit must not look like a dead node). ``reap()`` declares nodes
  whose heartbeat is older than ``lease_ttl_s`` dead, requeues their leased
  units (epoch++) and redistributes their queued entries to the
  least-loaded alive nodes. A reaped "zombie" that later finishes anyway is
  harmless: the provenance commit arbitration admits exactly one ok record.
* **Speculate** — ``speculate(idx, node)`` grants a *twin* lease on a
  different node for a straggling unit; twins race the primary through the
  same idempotent commit, and duplicates surface as ``status="speculative"``.

Everything is guarded by one lock — the queue is the single shared-state
object, designed so a network transport (each call becomes an RPC to the
coordinator) can replace the in-process instance without touching nodes.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from ..core.query import WorkUnit


@dataclasses.dataclass(frozen=True)
class Lease:
    """One node's exclusive (or, for twins, speculative) claim on a unit."""
    unit_idx: int
    node_id: str
    epoch: int
    granted_at: float
    speculative: bool = False


class WorkQueue:
    """In-process coordinator state: per-node deques + leases + heartbeats.

    Thread-safe; every public method takes the single internal lock. ``now``
    is injectable for deterministic tests.
    """

    def __init__(self, units: Sequence[WorkUnit], node_ids: Sequence[str], *,
                 lease_ttl_s: float = 2.0, now=time.time):
        if not node_ids:
            raise ValueError("WorkQueue needs at least one node")
        self.units = list(units)
        self.lease_ttl_s = float(lease_ttl_s)
        self._now = now
        self._lock = threading.Lock()
        self._queues: Dict[str, Deque[int]] = {n: deque() for n in node_ids}
        for i in range(len(self.units)):            # round-robin partition
            self._queues[node_ids[i % len(node_ids)]].append(i)
        self._epochs: Dict[int, int] = {i: 0 for i in range(len(self.units))}
        self._leases: Dict[int, Lease] = {}          # primary lease per unit
        self._spec: Dict[int, Lease] = {}            # at most one twin per unit
        self._spec_queues: Dict[str, Deque[int]] = {n: deque() for n in node_ids}
        self._started: Dict[int, float] = {}         # compute began (post-prefetch)
        self._done: Dict[int, str] = {}              # unit idx -> terminal status
        self._failed_pending: Dict[int, str] = {}    # primary failed, twin racing
        self._heartbeats: Dict[str, float] = {n: now() for n in node_ids}
        self._dead: set = set()
        self.steals: Dict[str, int] = {n: 0 for n in node_ids}
        self.requeues: List[int] = []                # reaped unit idxs (log)

    # -- leasing ------------------------------------------------------------

    def _grant(self, idx: int, node_id: str, speculative: bool) -> Lease:
        self._epochs[idx] += 1
        lease = Lease(idx, node_id, self._epochs[idx], self._now(),
                      speculative=speculative)
        (self._spec if speculative else self._leases)[idx] = lease
        return lease

    def next_unit(self, node_id: str) -> Optional[Tuple[WorkUnit, Lease]]:
        """Lease the next unit for ``node_id``: own speculative work first,
        then own deque head, then steal the tail half of the longest peer
        deque. Returns ``None`` when no leasable work exists *right now*
        (the node should re-poll until :meth:`finished`)."""
        with self._lock:
            if node_id in self._dead:
                return None
            sq = self._spec_queues[node_id]
            while sq:
                idx = sq.popleft()
                if idx in self._done:
                    self._spec.pop(idx, None)
                    continue
                return self.units[idx], self._spec[idx]
            q = self._queues[node_id]
            if not q:
                self._steal_into(node_id)
            while q:
                idx = q.popleft()
                if idx in self._done:
                    continue
                return self.units[idx], self._grant(idx, node_id, False)
            return None

    def _steal_into(self, thief: str):
        victims = [(len(q), n) for n, q in self._queues.items()
                   if n != thief and n not in self._dead and len(q)]
        if not victims:
            return
        _, victim = max(victims)
        vq = self._queues[victim]
        k = max(1, len(vq) // 2)
        grabbed = [vq.pop() for _ in range(k)]
        # reverse: popping the tail reversed the order; keep victim's ordering
        self._queues[thief].extend(reversed(grabbed))
        self.steals[thief] += 1

    def mark_started(self, idx: int):
        """Compute (not prefetch) began — the straggler clock starts here."""
        with self._lock:
            self._started.setdefault(idx, self._now())

    def complete(self, idx: int, node_id: str, status: str, *,
                 speculative: bool = False):
        """Record a terminal result for a lease.

        Primary leases retire the unit on ``ok``/``skipped``; a terminal
        ``failed`` (retries exhausted — same semantics as ``LocalRunner``)
        retires it only when no speculative twin is still racing — otherwise
        retirement is deferred so the twin's ok can still save the unit. A
        twin retires the unit on ``ok``/``skipped``, and on ``failed`` only
        settles a deferred primary failure (both racers lost). Results from
        nodes already declared dead are ignored for retirement — their unit
        was requeued, and the provenance commit arbitration already made any
        late zombie write harmless — and late completions of already-done
        units are no-ops."""
        with self._lock:
            if node_id in self._dead:
                return
            if speculative:
                spec = self._spec.get(idx)
                if spec is not None and spec.node_id == node_id:
                    self._spec.pop(idx)
                if idx in self._done:
                    return
                if status in ("ok", "skipped"):
                    self._done[idx] = status
                    self._started.pop(idx, None)
                    self._failed_pending.pop(idx, None)
                elif idx in self._failed_pending:
                    self._done[idx] = self._failed_pending.pop(idx)
                return
            lease = self._leases.get(idx)
            if lease is not None and lease.node_id == node_id:
                self._leases.pop(idx)
                self._started.pop(idx, None)
            if idx in self._done:
                return
            if status == "failed" and idx in self._spec:
                self._failed_pending[idx] = status   # twin still racing
                return
            self._done[idx] = status
            self._failed_pending.pop(idx, None)

    # -- speculation --------------------------------------------------------

    def speculate(self, idx: int, node_id: str) -> Optional[Lease]:
        """Queue a speculative twin of ``idx`` on ``node_id`` (must differ
        from the primary lease holder; at most one twin per unit)."""
        with self._lock:
            lease = self._leases.get(idx)
            if (idx in self._done or idx in self._spec or lease is None
                    or lease.node_id == node_id or node_id in self._dead):
                return None
            twin = self._grant(idx, node_id, True)
            self._spec_queues[node_id].append(idx)
            return twin

    def running(self) -> List[Tuple[int, float, str]]:
        """Units in compute: (idx, started_at, node) for straggler checks."""
        with self._lock:
            return [(i, t0, self._leases[i].node_id)
                    for i, t0 in self._started.items()
                    if i not in self._done and i in self._leases]

    # -- heartbeats + failure handling --------------------------------------

    def heartbeat(self, node_id: str):
        with self._lock:
            if node_id not in self._dead:
                self._heartbeats[node_id] = self._now()

    def mark_dead(self, node_id: str):
        """Explicit fail-fast path (e.g. a node's thread crashed)."""
        with self._lock:
            self._declare_dead(node_id)

    def reap(self) -> List[int]:
        """Declare heartbeat-expired nodes dead; requeue their leased units
        (epoch bumps on re-grant) and redistribute their queued entries onto
        the least-loaded alive nodes. Returns the requeued unit idxs."""
        with self._lock:
            now = self._now()
            newly_dead = [n for n, hb in self._heartbeats.items()
                          if n not in self._dead and now - hb > self.lease_ttl_s]
            requeued: List[int] = []
            for n in newly_dead:
                requeued.extend(self._declare_dead(n))
            return requeued

    def _declare_dead(self, node_id: str) -> List[int]:
        if node_id in self._dead:
            return []
        self._dead.add(node_id)
        alive = [n for n in self._queues if n not in self._dead]
        orphans: List[int] = []
        # leased-but-unfinished units held by the dead node
        for idx, lease in list(self._leases.items()):
            if lease.node_id == node_id and idx not in self._done:
                self._leases.pop(idx)
                self._started.pop(idx, None)
                orphans.append(idx)
        # a twin on a dead node just evaporates — the primary still runs,
        # unless the primary already failed and was waiting on this twin
        for idx, lease in list(self._spec.items()):
            if lease.node_id == node_id:
                self._spec.pop(idx)
                if idx in self._failed_pending and idx not in self._done:
                    self._done[idx] = self._failed_pending.pop(idx)
        self._spec_queues[node_id].clear()
        # unleased entries still sitting in its deque
        orphans.extend(i for i in self._queues[node_id] if i not in self._done)
        self._queues[node_id].clear()
        if alive:
            for idx in orphans:
                target = min(alive, key=lambda n: len(self._queues[n]))
                # front of the queue: requeued work is the oldest work
                self._queues[target].appendleft(idx)
        self.requeues.extend(orphans)
        return orphans

    # -- introspection ------------------------------------------------------

    def finished(self) -> bool:
        with self._lock:
            return len(self._done) == len(self.units)

    def pending(self) -> int:
        with self._lock:
            return len(self.units) - len(self._done)

    def alive_nodes(self) -> List[str]:
        with self._lock:
            return [n for n in self._queues if n not in self._dead]

    def done_status(self) -> Dict[int, str]:
        with self._lock:
            return dict(self._done)

    def queue_depths(self) -> Dict[str, int]:
        with self._lock:
            return {n: len(q) for n, q in self._queues.items()}

    def active_leases(self) -> Dict[str, str]:
        """``job_id -> node_id`` for every in-flight lease (primary + twin) —
        the view :func:`repro.core.query.query_available_work` consumes to
        avoid double-submitting leased sessions."""
        with self._lock:
            out = {self.units[i].job_id: l.node_id
                   for i, l in self._leases.items() if i not in self._done}
            for i, l in self._spec.items():
                if i not in self._done:
                    out.setdefault(self.units[i].job_id, l.node_id)
            return out
