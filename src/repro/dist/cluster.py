"""Multi-node work-stealing executor: ``LocalRunner``'s stage graph
(prefetch -> compute -> arbitrated commit) generalized across N nodes.

The paper's burst path runs one pipelined executor per host; this module is
the next rung — a cluster of :class:`Node` workers draining one
:class:`~repro.dist.queue.WorkQueue` of work units:

* **Per-node prefetch** — each node leases a small in-hand window of units
  and verifies+loads their inputs (``sha256_load_array``, one read per byte)
  on a loader thread while the current unit computes. Only *leased* units are
  prefetched, so work-stealing never invalidates a node's prefetch.
* **Work stealing** — a node that drains its deque steals the tail half of
  the longest peer deque, keeping completion counts balanced under
  heterogeneous node speeds (the paper's low-cost-hardware setting).
* **Cross-node speculation** — the coordinator watches compute start times;
  a unit running ``straggler_factor`` x the cluster-wide median gets a twin
  lease on a *different* node. Twins race the primary through the same
  idempotent atomic tmp+rename commit with exactly-one-ok-provenance
  arbitration (``repro.core.workflow``), and every duplicate is reported as
  ``status="speculative"`` so per-image counts stay exact.
* **Heartbeats + lease reaping** — nodes heartbeat on a timer decoupled from
  compute; when a node misses ``lease_ttl_s`` of heartbeats the coordinator
  reaps it, requeuing its leased + queued units (lease epoch bumps) onto the
  surviving nodes. A zombie that later commits anyway loses the commit
  arbitration and surfaces as ``skipped``.

Nodes here are threads sharing a filesystem root (in-process cluster), but
every node<->coordinator interaction goes through the ``WorkQueue`` method
surface, which is designed to become an RPC boundary: pointing the same node
loop at a network-backed queue implementation is the intended transport
follow-up (see ROADMAP).

Failure model: fail-stop nodes (crash = heartbeat silence; no Byzantine
nodes), shared storage survives node death, and commits are atomic. Under
those assumptions every unit ends in exactly one committed ok provenance (or
a terminal ``failed`` after per-node retries), no matter how many nodes die
or how many twins race — see ``docs/cluster.md``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from ..core.pipelines import Pipeline
from ..core.query import WorkUnit
from ..core.workflow import (StragglerDetector, UnitResult, dedupe_results,
                             run_unit, run_unit_with_retries,
                             safe_load_unit_inputs)
from .queue import Lease, WorkQueue


class Node:
    """One thread-backed worker: lease -> prefetch -> compute -> record.

    The worker thread is named after ``node_id`` so test fault hooks can
    target a node via ``threading.current_thread().name``. :meth:`kill`
    simulates a crash: the heartbeat stops immediately and no further unit is
    started — in-hand leases die with the node and are reaped by the
    coordinator. ``die_after=k`` self-crashes the node after recording ``k``
    units (fault injection for dead-node requeue tests).
    """

    def __init__(self, node_id: str, queue: WorkQueue, pipeline: Pipeline,
                 data_root: Path,
                 record: Callable[[int, UnitResult, Lease], None], *,
                 prefetch: int = 1, max_retries: int = 2,
                 backoff_s: float = 0.05,
                 fault_hook: Optional[Callable[[WorkUnit, int], None]] = None,
                 hb_interval_s: float = 0.25, poll_s: float = 0.02,
                 die_after: Optional[int] = None):
        self.node_id = node_id
        self.queue = queue
        self.pipeline = pipeline
        self.data_root = Path(data_root)
        self.record = record
        self.prefetch = max(0, int(prefetch))
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.fault_hook = fault_hook
        self.hb_interval_s = hb_interval_s
        self.poll_s = poll_s
        self.die_after = die_after
        self.killed = threading.Event()
        self.processed = 0
        self.crash: Optional[str] = None
        self._loader = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"{node_id}-loader")
        self._worker = threading.Thread(
            target=self._work, name=node_id, daemon=True)
        self._hb = threading.Thread(
            target=self._heartbeat, name=f"{node_id}-hb", daemon=True)

    # -- lifecycle ----------------------------------------------------------

    def start(self):
        self._worker.start()
        self._hb.start()

    def kill(self):
        """Crash the node: heartbeat and compute stop, leases go down with it."""
        self.killed.set()

    def join(self, timeout: Optional[float] = None):
        self._worker.join(timeout)

    def is_alive(self) -> bool:
        return self._worker.is_alive()

    # -- stages -------------------------------------------------------------

    def _heartbeat(self):
        while not self.killed.is_set():
            self.queue.heartbeat(self.node_id)
            self.killed.wait(self.hb_interval_s)

    def _safe_load(self, unit: WorkUnit):
        return safe_load_unit_inputs(unit, self.data_root)

    def _work(self):
        inhand: deque = deque()            # [(unit, lease, load_future|None)]
        try:
            while not self.killed.is_set():
                # top up the leased in-hand window; prefetch primary inputs
                # (a speculative twin skips prefetch — it must start *now*)
                while len(inhand) < 1 + self.prefetch:
                    nxt = self.queue.next_unit(self.node_id)
                    if nxt is None:
                        break
                    unit, lease = nxt
                    fut = (None if lease.speculative
                           else self._loader.submit(self._safe_load, unit))
                    if lease.speculative:
                        inhand.appendleft((unit, lease, fut))
                    else:
                        inhand.append((unit, lease, fut))
                if not inhand:
                    if self.queue.finished():
                        break
                    time.sleep(self.poll_s)
                    continue
                unit, lease, fut = inhand.popleft()
                if self.killed.is_set():
                    break
                idx = lease.unit_idx
                pre = fut.result() if fut is not None else None
                # straggler clock starts at compute, not at the input load —
                # a slow prefetch must not trigger spurious speculation
                self.queue.mark_started(idx)
                if lease.speculative:
                    res = run_unit(unit, self.pipeline, self.data_root,
                                   attempt=self.max_retries + 2,
                                   fault_hook=self.fault_hook,
                                   node_id=self.node_id,
                                   lease_epoch=lease.epoch)
                else:
                    res = run_unit_with_retries(
                        unit, self.pipeline, self.data_root,
                        max_retries=self.max_retries,
                        backoff_s=self.backoff_s, fault_hook=self.fault_hook,
                        preloaded=pre, node_id=self.node_id,
                        lease_epoch=lease.epoch)
                self.processed += 1
                self.record(idx, res, lease)
                if self.die_after is not None and self.processed >= self.die_after:
                    self.kill()
        except Exception:  # noqa: BLE001 — a crashed node is a dead node
            self.crash = traceback.format_exc(limit=5)
            self.queue.mark_dead(self.node_id)
        finally:
            self._loader.shutdown(wait=False)


@dataclasses.dataclass
class ClusterStats:
    """Per-run observability: what the control plane actually did."""
    processed: Dict[str, int]
    steals: Dict[str, int]
    requeued: List[int]
    speculated: int
    dead_nodes: List[str]


class ClusterRunner:
    """Drive ``nodes`` in-process :class:`Node` workers over one unit list.

    Same result contract as ``LocalRunner.run``: one result per unit with a
    committed status, plus ``status="speculative"`` rows for every duplicate
    (twins and zombie re-runs) so ok-counts are never inflated. After
    :meth:`run`, :attr:`stats` holds steal/requeue/speculation counters.
    """

    def __init__(self, pipeline: Pipeline, data_root: Path, *,
                 nodes: int = 4, prefetch: int = 1, max_retries: int = 2,
                 backoff_s: float = 0.05, straggler_factor: float = 3.0,
                 straggler_min_s: float = 0.5, lease_ttl_s: float = 2.0,
                 hb_interval_s: float = 0.25, poll_s: float = 0.05,
                 fault_hook: Optional[Callable[[WorkUnit, int], None]] = None,
                 die_after: Optional[Dict[str, int]] = None):
        if nodes < 1:
            raise ValueError("need at least one node")
        self.pipeline = pipeline
        self.data_root = Path(data_root)
        self.n_nodes = int(nodes)
        self.prefetch = prefetch
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.lease_ttl_s = lease_ttl_s
        self.hb_interval_s = hb_interval_s
        self.poll_s = poll_s
        self.fault_hook = fault_hook
        self.die_after = dict(die_after or {})
        self.stats: Optional[ClusterStats] = None
        self.queue: Optional[WorkQueue] = None

    def node_ids(self) -> List[str]:
        return [f"node-{i}" for i in range(self.n_nodes)]

    def run(self, units: List[WorkUnit]) -> List[UnitResult]:
        if not units:
            return []
        node_ids = self.node_ids()
        queue = WorkQueue(units, node_ids, lease_ttl_s=self.lease_ttl_s)
        self.queue = queue
        detector = StragglerDetector(self.straggler_factor,
                                     self.straggler_min_s)
        primaries: Dict[int, UnitResult] = {}
        extras: List[Tuple[int, UnitResult]] = []
        rec_lock = threading.Lock()

        def record(idx: int, res: UnitResult, lease: Lease):
            with rec_lock:
                if lease.speculative or idx in primaries:
                    extras.append((idx, res))
                else:
                    primaries[idx] = res
                if res.status == "ok":
                    detector.observe(res.seconds)
            queue.complete(idx, lease.node_id, res.status,
                           speculative=lease.speculative)

        nodes = [Node(nid, queue, self.pipeline, self.data_root, record,
                      prefetch=self.prefetch, max_retries=self.max_retries,
                      backoff_s=self.backoff_s, fault_hook=self.fault_hook,
                      hb_interval_s=self.hb_interval_s, poll_s=self.poll_s,
                      die_after=self.die_after.get(nid))
                 for nid in node_ids]
        speculated: set = set()
        for nd in nodes:
            nd.start()
        try:
            while not queue.finished():
                time.sleep(self.poll_s)
                queue.reap()
                alive = set(queue.alive_nodes())
                if not alive and not queue.finished():
                    raise RuntimeError(
                        f"all nodes dead with {queue.pending()} units pending")
                # cross-node straggler speculation: twin on a different node
                now = time.time()
                depths = queue.queue_depths()
                for idx, t0, holder in queue.running():
                    if idx in speculated or not detector.is_straggler(now - t0):
                        continue
                    targets = [n for n in alive if n != holder]
                    if not targets:
                        continue
                    target = min(targets, key=lambda n: depths.get(n, 0))
                    if queue.speculate(idx, target) is not None:
                        speculated.add(idx)
        finally:
            for nd in nodes:
                nd.kill()
            for nd in nodes:
                nd.join(timeout=5.0)
        self.stats = ClusterStats(
            processed={nd.node_id: nd.processed for nd in nodes},
            steals=dict(queue.steals), requeued=list(queue.requeues),
            speculated=len(speculated),
            dead_nodes=[n for n in node_ids if n not in queue.alive_nodes()])
        # fold: exactly one committed-status result per unit; a unit whose
        # only finisher was a twin (primary died mid-flight) promotes it
        pending_extras: List[Tuple[int, UnitResult]] = []
        for idx, res in sorted(extras, key=lambda e: e[1].status != "ok"):
            if idx not in primaries:
                primaries[idx] = res
            else:
                pending_extras.append((idx, res))
        if len(primaries) < len(units):
            crashes = "; ".join(nd.crash for nd in nodes if nd.crash)
            raise RuntimeError(
                f"{len(units) - len(primaries)} unit(s) ended without a "
                f"result{': ' + crashes if crashes else ''}")
        order = sorted(primaries)
        pos = {idx: p for p, idx in enumerate(order)}
        return dedupe_results([primaries[idx] for idx in order],
                              [(pos[idx], res) for idx, res in pending_extras])
