"""Multi-node work-stealing executor: ``LocalRunner``'s stage graph
(prefetch -> compute -> arbitrated commit) generalized across N nodes.

The paper's burst path runs one pipelined executor per host; this module is
the next rung — a cluster of :class:`Node` workers draining one
:class:`~repro.dist.queue.WorkQueue` of work units:

* **Per-node prefetch** — each node leases a small in-hand window of units
  and verifies+loads their inputs (``sha256_load_array``, one read per byte)
  on a loader thread while the current unit computes. Only *leased* units are
  prefetched, so work-stealing never invalidates a node's prefetch.
* **Work stealing** — a node that drains its deque steals the tail half of
  the longest peer deque, keeping completion counts balanced under
  heterogeneous node speeds (the paper's low-cost-hardware setting).
* **Cross-node speculation** — the coordinator watches compute start times;
  a unit running ``straggler_factor`` x the cluster-wide median gets a twin
  lease on a *different* node. Twins race the primary through the same
  idempotent atomic tmp+rename commit with exactly-one-ok-provenance
  arbitration (``repro.core.workflow``), and every duplicate is reported as
  ``status="speculative"`` so per-image counts stay exact.
* **Heartbeats + lease reaping** — nodes heartbeat on a timer decoupled from
  compute; when a node misses ``lease_ttl_s`` of heartbeats the coordinator
  reaps it, requeuing its leased + queued units (lease epoch bumps) onto the
  surviving nodes. A zombie that later commits anyway loses the commit
  arbitration and surfaces as ``skipped``.

Every node<->coordinator interaction goes through the ``WorkQueue`` method
surface, which *is* an RPC boundary: with ``transport="rpc"`` the
coordinator serves its queue over ``repro.dist.rpc`` and every local
:class:`Node` talks to it through a :class:`~repro.dist.rpc.QueueClient`
socket — and worker processes on other hosts join the same queue via
:func:`run_worker` (or ``python -m repro.dist.rpc work``), register
themselves, steal work, and commit to shared storage. Their results flow
back as ``complete(meta=...)`` payloads and are folded into the
coordinator's result list from ``results_snapshot()``. Long-haul leases stay
alive through the node heartbeat thread's **renewal loop** (``renew`` per
held lease), and each host serves repeated inputs from its content-addressed
:class:`~repro.dist.cache.InputCache` instead of shared storage.

Failure model: fail-stop nodes (crash = heartbeat silence; no Byzantine
nodes), shared storage survives node death, and commits are atomic. Under
those assumptions every unit ends in exactly one committed ok provenance (or
a terminal ``failed`` after per-node retries), no matter how many nodes die
or how many twins race — see ``docs/cluster.md``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from ..core.pipelines import Pipeline
from ..core.query import WorkUnit
from ..core.workflow import (StragglerDetector, UnitResult, dedupe_results,
                             run_unit, run_unit_with_retries,
                             safe_load_unit_inputs)
from .cache import InputCache, cache_from_env
from .queue import Lease, WorkQueue


def result_meta(res: UnitResult) -> dict:
    """JSON-safe result payload attached to ``complete`` so coordinators in
    other processes can rebuild a :class:`UnitResult` (sans the unit object,
    which both sides already hold by index). Carries the data-movement
    stamps too, so ``results_snapshot`` shows cache hit-rates and placement
    quality without anyone grepping provenance files."""
    return {"seconds": res.seconds, "attempts": res.attempts,
            "error": res.error, "bytes_from_cache": res.bytes_from_cache,
            "bytes_from_peer": res.bytes_from_peer,
            "locality_score": res.locality_score}


def _meta_result(unit: WorkUnit, m: dict) -> UnitResult:
    return UnitResult(unit, m["status"], m.get("seconds", 0.0),
                      m.get("attempts", 1), m.get("error"),
                      bytes_from_cache=m.get("bytes_from_cache", 0),
                      bytes_from_peer=m.get("bytes_from_peer", 0),
                      locality_score=m.get("locality_score", 0.0))


class Node:
    """One thread-backed worker: lease -> prefetch -> compute -> record.

    The worker thread is named after ``node_id`` so test fault hooks can
    target a node via ``threading.current_thread().name``. :meth:`kill`
    simulates a crash: the heartbeat stops immediately and no further unit is
    started — in-hand leases die with the node and are reaped by the
    coordinator. ``die_after=k`` self-crashes the node after recording ``k``
    units (fault injection for dead-node requeue tests).

    ``pipeline`` is either a single :class:`Pipeline` (every unit runs it,
    the original shape) or a ``Mapping[str, Pipeline]`` resolved per unit by
    ``unit.pipeline`` name — what a staged campaign DAG needs, where one
    queue mixes stages of different pipelines. A unit naming a pipeline the
    mapping lacks fails terminally (and blocks its DAG descendants) instead
    of crashing the node.
    """

    def __init__(self, node_id: str, queue: WorkQueue, pipeline,
                 data_root: Path,
                 record: Optional[Callable[[int, UnitResult, Lease],
                                           None]] = None, *,
                 prefetch: int = 1, max_retries: int = 2,
                 backoff_s: float = 0.05,
                 fault_hook: Optional[Callable[[WorkUnit, int], None]] = None,
                 hb_interval_s: float = 0.25, poll_s: float = 0.02,
                 die_after: Optional[int] = None,
                 cache: Optional[InputCache] = None, renew: bool = True,
                 summary_cursor: Optional[int] = None,
                 blob_server=None):
        self.node_id = node_id
        self.queue = queue
        self.pipeline = pipeline
        self.data_root = Path(data_root)
        self.record = record
        self.prefetch = max(0, int(prefetch))
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.fault_hook = fault_hook
        self.hb_interval_s = hb_interval_s
        self.poll_s = poll_s
        self.die_after = die_after
        self.cache = cache
        self.renew = renew
        # the host's BlobServer (peer fabric), when this node owns one: its
        # lifecycle is tied to the node — kill() stops it, so a simulated
        # node crash takes the host's serving down with it, exactly like a
        # real host dying mid-transfer (peers see a connection error and
        # fall back to shared storage)
        self.blob_server = blob_server
        # cache op-log position last pushed; a caller that already announced
        # the full summary (run_worker piggybacks it on register) hands the
        # sync cursor in, so the loop doesn't re-send an identical full push
        self._summary_cursor = summary_cursor or 0
        self._summary_pushed = summary_cursor is not None
        self._fabric_announced = False
        # reconnect-aware transports tell us when the coordinator was
        # replaced: everything we pushed (summary, blob addr) died with the
        # old incarnation, so flag both for a re-push on the next heartbeat
        hook = getattr(queue, "add_restart_hook", None)
        if hook is not None:
            hook(self._on_coordinator_restart)
        self.killed = threading.Event()
        self.processed = 0
        self.lease_lost = 0                  # renewals rejected (stale epoch)
        self.crash: Optional[str] = None
        self._held: set = set()              # (unit_idx, epoch) in-hand leases
        self._held_lock = threading.Lock()
        self._loader = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"{node_id}-loader")
        self._worker = threading.Thread(
            target=self._work, name=node_id, daemon=True)
        self._hb = threading.Thread(
            target=self._heartbeat, name=f"{node_id}-hb", daemon=True)

    def _pipeline_for(self, unit: WorkUnit) -> Optional[Pipeline]:
        if isinstance(self.pipeline, Mapping):
            return self.pipeline.get(unit.pipeline)
        return self.pipeline

    # -- lifecycle ----------------------------------------------------------

    def start(self):
        self._worker.start()
        self._hb.start()

    def kill(self):
        """Crash the node: heartbeat and compute stop, leases go down with
        it — and so does the host's blob server, mid-transfer included."""
        self.killed.set()
        if self.blob_server is not None:
            try:
                self.blob_server.stop()
            except Exception:  # noqa: BLE001 — a dying node stays dead
                pass
        fabric = getattr(self.cache, "fabric", None)
        if fabric is not None:
            try:
                fabric.close()           # pooled peer connections
            except Exception:  # noqa: BLE001
                pass

    def join(self, timeout: Optional[float] = None):
        self._worker.join(timeout)

    def is_alive(self) -> bool:
        return self._worker.is_alive()

    # -- stages -------------------------------------------------------------

    def _push_summary(self):
        """Full digest-summary push for this host's cache — the coordinator
        learns what bytes this node already holds before it makes any
        placement decision for it. Best-effort: an old coordinator (no
        ``put_summary``) leaves the run locality-blind, never broken."""
        if self.cache is None or self._summary_pushed:
            return
        cursor, wire = self.cache.summary_sync()
        try:
            put = getattr(self.queue, "put_summary", None)
            if put is not None and put(self.node_id, wire) is not False:
                self._summary_cursor = cursor
                self._summary_pushed = True
        except RuntimeError:
            pass                           # pre-summary coordinator: blind

    def _on_coordinator_restart(self):
        """Restart-hook body (fires on whichever thread detected the new
        incarnation): only flips flags — the heartbeat loop does the actual
        re-pushing on its next beat, off the detecting thread's hot path."""
        self._summary_pushed = False
        self._fabric_announced = False

    def _announce_fabric(self):
        """Advertise this host's blob server to the coordinator (a register
        refresh carrying ``blob_addr``), so locate_blobs can route peers
        here. Best-effort with the same downgrade discipline as summaries:
        an old coordinator (TypeError on the param) leaves this host
        fabric-invisible — it still fetches from peers, never serves."""
        if self.blob_server is None or self._fabric_announced:
            return
        try:
            self.queue.register(self.node_id,
                                blob_addr=self.blob_server.advertise)
            self._fabric_announced = True
        except (TypeError, RuntimeError, ConnectionError):
            pass                       # pre-fabric coordinator: unadvertised

    def _summary_delta(self):
        """Delta wire for the heartbeat piggyback (None when the transport
        downgraded to the pre-summary protocol)."""
        if self.cache is None:
            return None
        cursor, wire = self.cache.summary_delta_since(self._summary_cursor)
        self._summary_cursor = cursor
        return wire

    def _heartbeat(self):
        """Node-level heartbeat plus the lease renewal loop: every interval,
        re-assert liveness — piggybacking the cache digest-summary delta, so
        coordinator-side placement scoring tracks this host's cache within a
        heartbeat — and renew each in-hand lease. A rejected renewal
        (the coordinator reaped us or re-granted the unit — WAN-scale TTLs
        make this routine) is counted and the stale lease dropped from the
        renew set; the unit itself still runs to completion, where commit
        arbitration makes the zombie write harmless."""
        while not self.killed.is_set():
            try:
                # no-ops while already pushed/announced; after a detected
                # coordinator restart the flags are down and the new
                # incarnation gets the full summary + blob addr within one
                # beat, without manual intervention
                self._push_summary()
                self._announce_fabric()
                self.queue.heartbeat(self.node_id,
                                     summary_delta=self._summary_delta())
                if self.renew:
                    with self._held_lock:
                        held = sorted(self._held)
                    verdicts = self._renew_held(held) if held else []
                    for (idx, epoch), ok in zip(held, verdicts):
                        if ok:
                            continue
                        with self._held_lock:
                            # only a lease we still hold counts as lost —
                            # a renew losing the race with its own unit's
                            # completion is routine, not a WAN event
                            lost = (idx, epoch) in self._held
                            self._held.discard((idx, epoch))
                        if lost:
                            self.lease_lost += 1
            except ConnectionError:
                return                       # transport gone: die silent,
            self.killed.wait(self.hb_interval_s)  # the reaper does the rest

    def _renew_held(self, held):
        """Renew a snapshot of in-hand leases: one ``renew_batch`` round trip
        when the queue has it (in-process queues and new coordinators via
        the shedding client), else per-op renews — same verdicts, N trips."""
        batch = getattr(self.queue, "renew_batch", None)
        if batch is not None:
            return batch(self.node_id, [[i, e] for i, e in held])
        return [self.queue.renew(i, self.node_id, e) for i, e in held]

    def _next_units(self, max_units: int):
        """Grant up to ``max_units`` leases: one ``next_units`` round trip
        when the queue has it, else one per-op grant (the caller's top-up
        loop keeps asking, preserving the old shape)."""
        batch = getattr(self.queue, "next_units", None)
        if batch is not None:
            return batch(self.node_id, max_units)
        got = self.queue.next_unit(self.node_id)
        return [] if got is None else [got]

    def _safe_load(self, unit: WorkUnit):
        return safe_load_unit_inputs(unit, self.data_root, cache=self.cache)

    def _report(self, idx: int, res: UnitResult, lease: Lease):
        """Commit a finished unit through this node's *own* queue handle.

        Over rpc that means the completion travels the node's socket — the
        one that survives (reconnects across) a coordinator restart — rather
        than a coordinator-side closure holding a reference to a queue
        object that may since have been replaced by recovery. The optional
        ``record`` callback is pure local bookkeeping (provenance fold,
        per-node tallies) and runs after the commit is accepted."""
        self.queue.complete(idx, lease.node_id, res.status,
                            speculative=lease.speculative,
                            meta=result_meta(res))
        if self.record is not None:
            self.record(idx, res, lease)

    def _work(self):
        inhand: deque = deque()            # [(unit, lease, load_future|None)]
        try:
            # announce this host's warm bytes before asking for work: the
            # very first grant can then already be locality-aware — and its
            # blob server, so peers can start pulling from it just as early
            self._push_summary()
            self._announce_fabric()
            while not self.killed.is_set():
                # top up the leased in-hand window — the whole shortfall in
                # one (batched) ask; prefetch primary inputs (a speculative
                # twin skips prefetch — it must start *now*)
                while len(inhand) < 1 + self.prefetch:
                    need = 1 + self.prefetch - len(inhand)
                    grants = self._next_units(need)
                    for unit, lease in grants:
                        with self._held_lock:
                            self._held.add((lease.unit_idx, lease.epoch))
                        fut = (None if lease.speculative
                               else self._loader.submit(self._safe_load, unit))
                        if lease.speculative:
                            inhand.appendleft((unit, lease, fut))
                        else:
                            inhand.append((unit, lease, fut))
                    if len(grants) < need:
                        break              # nothing more leasable right now
                if not inhand:
                    if self.queue.finished():
                        break
                    time.sleep(self.poll_s)
                    continue
                unit, lease, fut = inhand.popleft()
                if self.killed.is_set():
                    break
                idx = lease.unit_idx
                pipe = self._pipeline_for(unit)
                if pipe is None:
                    # a unit naming a pipeline this node doesn't carry is a
                    # terminal config failure, not a node crash: record it
                    # and keep working (its DAG descendants go blocked)
                    self.processed += 1
                    with self._held_lock:
                        self._held.discard((idx, lease.epoch))
                    self._report(idx, UnitResult(
                        unit, "failed", 0.0, attempts=1,
                        error=f"no pipeline named {unit.pipeline!r} "
                              f"available on node {self.node_id}"), lease)
                    continue
                pre = fut.result() if fut is not None else None
                # straggler clock starts at compute, not at the input load —
                # a slow prefetch must not trigger spurious speculation
                self.queue.mark_started(idx)
                # grant-time placement estimate, normalized to the unit's
                # input bytes — stamped into provenance as locality_score
                total = unit.total_input_bytes
                score = (min(1.0, lease.local_bytes / total) if total else 0.0)
                if lease.speculative:
                    res = run_unit(unit, pipe, self.data_root,
                                   attempt=self.max_retries + 2,
                                   fault_hook=self.fault_hook,
                                   node_id=self.node_id,
                                   lease_epoch=lease.epoch, cache=self.cache,
                                   locality_score=score)
                else:
                    res = run_unit_with_retries(
                        unit, pipe, self.data_root,
                        max_retries=self.max_retries,
                        backoff_s=self.backoff_s, fault_hook=self.fault_hook,
                        preloaded=pre, node_id=self.node_id,
                        lease_epoch=lease.epoch, cache=self.cache,
                        locality_score=score)
                self.processed += 1
                with self._held_lock:
                    self._held.discard((idx, lease.epoch))
                self._report(idx, res, lease)
                if self.die_after is not None and self.processed >= self.die_after:
                    self.kill()
        except Exception:  # noqa: BLE001 — a crashed node is a dead node
            self.crash = traceback.format_exc(limit=5)
            try:
                self.queue.mark_dead(self.node_id)
            except ConnectionError:
                pass     # transport already gone: silence reaches the reaper
        finally:
            self._loader.shutdown(wait=False)


@dataclasses.dataclass
class ClusterStats:
    """Per-run observability: what the control plane actually did."""
    processed: Dict[str, int]
    steals: Dict[str, int]
    requeued: List[int]
    speculated: int
    dead_nodes: List[str]
    remote_nodes: List[str] = dataclasses.field(default_factory=list)
    renew_rejections: int = 0
    cache: Optional[Dict[str, int]] = None    # coordinator-host cache stats
                                              # (summed over per-node caches)
    locality: Optional[Dict[str, int]] = None  # queue placement counters
    cache_by_node: Optional[Dict[str, Dict[str, int]]] = None
    fabric: Optional[Dict[str, int]] = None    # locate_blobs routing counters
    peer_links: Optional[Dict[str, Dict[str, int]]] = None
    # ^ {fetcher node: {peer addr: bytes}} — who pulled how much from whom


class ClusterRunner:
    """Drive ``nodes`` :class:`Node` workers over one unit list.

    Same result contract as ``LocalRunner.run``: one result per unit with a
    committed status, plus ``status="speculative"`` rows for every duplicate
    (twins and zombie re-runs) so ok-counts are never inflated. After
    :meth:`run`, :attr:`stats` holds steal/requeue/speculation counters.

    Transport injection: with ``transport="local"`` (default) nodes call the
    in-process :class:`WorkQueue` directly; with ``transport="rpc"`` the
    coordinator serves the queue over ``repro.dist.rpc`` and every node —
    still threads here — talks to it through a socket-backed
    :class:`~repro.dist.rpc.QueueClient`, byte-identical to what a worker on
    another machine uses. ``serve_addr`` (``"host:port"``, port 0 = ephemeral;
    implied by ``transport="rpc"``) additionally opens the queue to external
    worker processes (:func:`run_worker`): they register, steal work, commit
    to shared storage, and their results are folded in from
    ``results_snapshot()``. ``cache_dir`` gives the coordinator host one
    content-addressed input cache shared by its nodes. ``pipeline`` may be
    a single :class:`Pipeline` or a ``Mapping[str, Pipeline]`` resolved per
    unit by name (staged DAG campaigns mix pipelines in one queue)."""

    def __init__(self, pipeline, data_root: Path, *,
                 nodes: int = 4, prefetch: int = 1, max_retries: int = 2,
                 backoff_s: float = 0.05, straggler_factor: float = 3.0,
                 straggler_min_s: float = 0.5, lease_ttl_s: float = 2.0,
                 hb_interval_s: float = 0.25, poll_s: float = 0.05,
                 fault_hook: Optional[Callable[[WorkUnit, int], None]] = None,
                 die_after: Optional[Dict[str, int]] = None,
                 transport: str = "local", serve_addr: Optional[str] = None,
                 cache_dir: Optional[Path] = None,
                 cache_bytes: Optional[int] = None,
                 cache_per_node: bool = False, peer_fabric: bool = False,
                 locality: bool = True, partition: str = "round_robin",
                 plan=None, journal_dir: Optional[Path] = None,
                 journal_overwrite: bool = False,
                 client_kwargs: Optional[Dict] = None,
                 client_dial: Optional[Callable] = None):
        if nodes < 1:
            raise ValueError("need at least one node")
        if transport not in ("local", "rpc"):
            raise ValueError(f"unknown transport {transport!r}")
        if peer_fabric and not (cache_dir and cache_per_node):
            # the fabric is a between-hosts construct: it needs one cache
            # per simulated host to have distinct peers to route between
            raise ValueError("peer_fabric needs cache_dir + cache_per_node")
        self.pipeline = pipeline
        self.data_root = Path(data_root)
        self.n_nodes = int(nodes)
        self.prefetch = prefetch
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.lease_ttl_s = lease_ttl_s
        self.hb_interval_s = hb_interval_s
        self.poll_s = poll_s
        self.fault_hook = fault_hook
        self.die_after = dict(die_after or {})
        self.transport = transport
        self.serve_addr = serve_addr
        self.cache_dir = cache_dir
        self.cache_bytes = cache_bytes
        # cache_per_node gives every local node its own cache dir
        # (cache_dir/<node_id>) — the multi-host shape (one cache per host)
        # simulated in one process, which is what makes locality-aware
        # placement testable and benchmarkable without a real cluster
        self.cache_per_node = cache_per_node
        # peer_fabric starts one BlobServer per node cache (loopback,
        # ephemeral ports) and attaches a PeerFabric to each cache, so a
        # node's local miss streams from whichever sibling already holds
        # the blob — the multi-host content-delivery tier in one process
        self.peer_fabric = peer_fabric
        self.locality = locality
        self.partition = partition
        # a CampaignPlan (repro.core.campaign) seeds the queue's per-node
        # partitions from the admission-time shards: the cluster starts on
        # the warm placement the planner computed instead of rediscovering
        # it grant by grant (plan implies partition="plan" in WorkQueue)
        self.plan = plan
        # journal_dir turns on the coordinator write-ahead log: every queue
        # mutation is journaled there, and restart_coordinator() (or a fresh
        # process pointed at the same dir) can rebuild the queue mid-run.
        # run() refuses a directory that already holds a journal unless
        # journal_overwrite=True — the leftover is a crashed run's only
        # recoverable state (`rpc serve` recovers it; see docs/operating.md)
        self.journal_dir = Path(journal_dir) if journal_dir else None
        self.journal_overwrite = bool(journal_overwrite)
        # client_kwargs feed every node's QueueClient (e.g. {"binary": False}
        # pins JSON framing; reconnect knobs); client_dial rewrites the
        # upstream (host, port) into the address clients actually dial —
        # the hook a fault-injection proxy routes through
        self.client_kwargs = dict(client_kwargs or {})
        self.client_dial = client_dial
        self.stats: Optional[ClusterStats] = None
        self.queue: Optional[WorkQueue] = None
        self.server = None                   # QueueServer once run() serves
        self._journal = None
        self._ctl_lock = threading.Lock()    # guards restart vs shutdown
        self._stopping = False

    def node_ids(self) -> List[str]:
        return [f"node-{i}" for i in range(self.n_nodes)]

    def _make_cache(self, node_id: Optional[str] = None) -> Optional[InputCache]:
        if self.cache_dir is None:
            return None
        root = Path(self.cache_dir)
        if self.cache_per_node and node_id is not None:
            root = root / node_id
        kw = {} if self.cache_bytes is None else {"max_bytes": self.cache_bytes}
        return InputCache(root, **kw)

    def run(self, units: List[WorkUnit]) -> List[UnitResult]:
        if not units:
            return []
        node_ids = self.node_ids()
        journal = None
        if self.journal_dir is not None:
            from .journal import Journal
            journal = Journal(self.journal_dir)
            if journal.exists() and not self.journal_overwrite:
                # attaching would truncate wal.log and overwrite state.json —
                # destroying the one copy of a crashed run's recoverable
                # state. Recovery is a deliberate act (`rpc serve --journal`
                # or WorkQueue.recover), never a side effect of starting a
                # new run over the same directory.
                raise RuntimeError(
                    f"{self.journal_dir} already holds a coordinator "
                    f"journal; recover it (python -m repro.dist.rpc serve "
                    f"--journal {self.journal_dir} ...) or pass "
                    f"journal_overwrite=True to discard it")
        queue = WorkQueue(units, node_ids, lease_ttl_s=self.lease_ttl_s,
                          locality=self.locality, partition=self.partition,
                          plan=self.plan, journal=journal)
        self.queue = queue
        self._journal = journal
        self._stopping = False
        serving = self.transport == "rpc" or self.serve_addr is not None
        clients = []
        if serving:
            from .rpc import QueueServer, parse_addr
            host, port = parse_addr(self.serve_addr or "127.0.0.1:0")
            self.server = QueueServer(queue, host, port).start()
        detector = StragglerDetector(self.straggler_factor,
                                     self.straggler_min_s)
        primaries: Dict[int, UnitResult] = {}
        extras: List[Tuple[int, UnitResult]] = []
        rec_lock = threading.Lock()

        def record(idx: int, res: UnitResult, lease: Lease):
            # pure coordinator-side bookkeeping: the committing complete()
            # already travelled the node's own queue handle (see
            # Node._report), so this closure never touches the queue — it
            # must stay valid across a mid-run coordinator restart
            with rec_lock:
                if lease.speculative or idx in primaries:
                    extras.append((idx, res))
                else:
                    primaries[idx] = res
                if res.status == "ok":
                    detector.observe(res.seconds)

        def node_queue():
            """The queue handle a local node drives: the in-process object,
            or a per-node socket client when the transport is rpc."""
            if self.transport != "rpc":
                return queue
            from .rpc import QueueClient
            host, port = self.server.address
            if host in ("0.0.0.0", "::", ""):    # wildcard bind: dial loopback
                host = "127.0.0.1"
            dial = (host, port)
            if self.client_dial is not None:
                dial = self.client_dial(dial)
            client = QueueClient(dial, **self.client_kwargs)
            clients.append(client)
            return client

        caches = {nid: (self._make_cache(nid) if self.cache_per_node
                        else None) for nid in node_ids}
        shared_cache = None if self.cache_per_node else self._make_cache()
        nodes = []
        for nid in node_ids:
            nq = node_queue()
            cache = caches[nid] or shared_cache
            blob_server = None
            if self.peer_fabric:
                from .blobserve import BlobServer, PeerFabric
                blob_server = BlobServer(cache).start()

                def locate(digests, _q=nq, _nid=nid):
                    loc = getattr(_q, "locate_blobs", None)
                    return loc(digests, node_id=_nid) if loc else {}

                cache.attach_fabric(PeerFabric(
                    locate, self_addr=blob_server.advertise))
            nodes.append(Node(
                nid, nq, self.pipeline, self.data_root,
                record, prefetch=self.prefetch,
                max_retries=self.max_retries, backoff_s=self.backoff_s,
                fault_hook=self.fault_hook,
                hb_interval_s=self.hb_interval_s, poll_s=self.poll_s,
                die_after=self.die_after.get(nid),
                cache=cache, blob_server=blob_server))
        local_ids = set(node_ids)
        speculated: set = set()
        log_cursor = 0
        for nd in nodes:
            nd.start()
        try:
            # the loop re-reads self.queue every tick: restart_coordinator()
            # swaps in the recovered queue object mid-run, and monitoring
            # must follow the live incarnation (a stray call against the old
            # object is harmless — its journal is closed, appends dropped)
            while not (q := self.queue).finished():
                time.sleep(self.poll_s)
                q = self.queue
                q.reap()
                alive = set(q.alive_nodes())
                if not alive and not q.finished():
                    raise RuntimeError(
                        f"all nodes dead with {q.pending()} units pending")
                # fold remote ok durations into the straggler median so
                # cross-node speculation sees the whole cluster's pace —
                # incremental (cursor into the retirement log), so a tick's
                # cost tracks new completions, not the whole history
                for m in q.primary_log(log_cursor):
                    log_cursor += 1
                    if m["node_id"] not in local_ids and m["status"] == "ok":
                        detector.observe(m.get("seconds", 0.0))
                # cross-node straggler speculation: twin on a different node,
                # placed by the queue itself — on the node already holding
                # the most of the unit's input bytes (least-loaded when no
                # summary covers it), so the twin starts from warm local disk
                now = time.time()
                for idx, t0, holder in q.running():
                    if idx in speculated or not detector.is_straggler(now - t0):
                        continue
                    if q.speculate(idx) is not None:
                        speculated.add(idx)
        finally:
            with self._ctl_lock:
                self._stopping = True        # fence out restart_coordinator
            for nd in nodes:
                nd.kill()
            for nd in nodes:
                nd.join(timeout=5.0)
            for client in clients:
                client.close()
            if self.server is not None:
                self.server.stop()
            if self._journal is not None:
                self._journal.close()
        # units finished by worker processes (never seen by record()) come
        # back through the queue's result metadata — read from the *final*
        # queue incarnation, which holds the whole run's state whether or
        # not the coordinator was restarted along the way
        queue = self.queue
        snap = queue.results_snapshot()
        remote_primaries = {idx: m for idx, m in snap["primaries"].items()
                            if m["node_id"] not in local_ids}
        remote_processed: Dict[str, int] = {}
        for idx, m in remote_primaries.items():
            remote_processed[m["node_id"]] = \
                remote_processed.get(m["node_id"], 0) + 1
            extras.append((idx, _meta_result(units[idx], m)))
        for m in snap["duplicates"]:
            if m["node_id"] not in local_ids:
                extras.append((m["idx"], _meta_result(units[m["idx"]], m)))
        # coordinator-host cache stats: one shared cache, or the sum over the
        # per-node caches (the simulated multi-host shape)
        node_caches = {nd.node_id: nd.cache.stats() for nd in nodes
                       if nd.cache is not None}
        if shared_cache is not None:
            cache_stats = shared_cache.stats()
        elif node_caches:
            cache_stats: Dict[str, int] = {}
            for st in node_caches.values():
                for k, v in st.items():
                    if isinstance(v, (int, float)):   # skip per-addr maps
                        cache_stats[k] = cache_stats.get(k, 0) + v
        else:
            cache_stats = None
        qstats = queue.stats_snapshot()
        self.stats = ClusterStats(
            processed={**{nd.node_id: nd.processed for nd in nodes},
                       **remote_processed},
            steals=dict(queue.steals), requeued=list(queue.requeues),
            speculated=len(speculated),
            dead_nodes=[n for n in node_ids if n not in queue.alive_nodes()],
            remote_nodes=sorted(set(queue.queue_depths()) - local_ids),
            renew_rejections=queue.renew_rejections,
            cache=cache_stats,
            locality=dict(qstats["locality"]),
            cache_by_node=(node_caches if self.cache_per_node else None),
            fabric=dict(qstats.get("fabric") or {}) or None,
            peer_links={nid: dict(st["peer_bytes_by_addr"])
                        for nid, st in node_caches.items()
                        if st.get("peer_bytes_by_addr")} or None)
        # fold: exactly one committed-status result per unit; a unit whose
        # only finisher was a twin (primary died mid-flight) promotes it
        pending_extras: List[Tuple[int, UnitResult]] = []
        for idx, res in sorted(extras, key=lambda e: e[1].status != "ok"):
            if idx not in primaries:
                primaries[idx] = res
            else:
                pending_extras.append((idx, res))
        # DAG failure policy: descendants of a terminally-failed parent were
        # never granted (no node ever saw them), so they have no completion
        # record anywhere — synthesize their terminal ``blocked`` result
        # instead of mistaking them for lost work
        for idx, st in queue.done_status().items():
            if st == "blocked" and idx not in primaries:
                primaries[idx] = UnitResult(
                    units[idx], "blocked", 0.0, attempts=0,
                    error="blocked: a depends_on ancestor failed terminally")
        if len(primaries) < len(units):
            crashes = "; ".join(nd.crash for nd in nodes if nd.crash)
            raise RuntimeError(
                f"{len(units) - len(primaries)} unit(s) ended without a "
                f"result{': ' + crashes if crashes else ''}")
        order = sorted(primaries)
        pos = {idx: p for p, idx in enumerate(order)}
        return dedupe_results([primaries[idx] for idx in order],
                              [(pos[idx], res) for idx, res in pending_extras])

    def restart_coordinator(self) -> Optional[Dict[str, float]]:
        """Kill the live coordinator mid-run and bring up a recovered one on
        the same port — the crash-recovery drill, callable from any thread
        while :meth:`run` is in flight.

        Requires ``transport="rpc"`` (clients must be able to redial; local
        nodes hold direct object references that recovery can't swap) and a
        ``journal_dir``. The sequence is exactly what a fresh process
        pointed at the journal would do: hard-crash the server (no drain —
        this simulates a dying host, in-flight frames are torn),
        close the old journal (fencing any zombie appends), replay
        snapshot + WAL tail into a new :class:`WorkQueue`, and rebind a
        :class:`~repro.dist.rpc.QueueServer` on the *same* host:port so
        reconnecting clients land on the new incarnation without
        re-resolution. Returns timing/recovery facts, or ``None`` when the
        run is already shutting down (the race is expected under chaos
        harnesses — callers treat ``None`` as "too late, stand down")."""
        if self.transport != "rpc":
            raise ValueError("restart_coordinator needs transport='rpc'")
        if self.journal_dir is None:
            raise ValueError("restart_coordinator needs a journal_dir")
        from .journal import Journal
        from .rpc import QueueServer
        with self._ctl_lock:
            if self._stopping or self.server is None or self._journal is None:
                return None
            t0 = time.monotonic()
            host, port = self.server.address
            self.server.crash()
            self._journal.close()
            journal = Journal(self.journal_dir)
            q = WorkQueue.recover(journal, lease_ttl_s=self.lease_ttl_s,
                                  locality=self.locality)
            t_recovered = time.monotonic()
            self.queue = q
            self._journal = journal
            self.server = QueueServer(q, host, port).start()
            return {"recover_s": t_recovered - t0,
                    "total_s": time.monotonic() - t0,
                    "done": float(len(q.done_status())),
                    "pending": float(q.pending())}


def run_worker(addr, pipeline, data_root: Path, node_id: str, *,
               prefetch: int = 1, max_retries: int = 2,
               backoff_s: float = 0.05, hb_interval_s: float = 0.25,
               poll_s: float = 0.05,
               cache: Optional[InputCache] = None) -> int:
    """Join a remote queue as one worker host and drain it: the process
    behind ``python -m repro.dist.rpc work``.

    Dials ``addr``, registers ``node_id`` — announcing the host cache's
    digest summary, so a warm worker is placed locality-aware from its first
    grant — and runs one :class:`Node` loop — the same code the
    coordinator's threads run — against the socket-backed queue, with inputs
    served through this host's content-addressed cache
    (default: built from ``$REPRO_CACHE_DIR`` / ``$REPRO_CACHE_MAX_MB``).

    Peer fabric: with a cache configured, the worker joins the blob fabric
    as a *fetcher* automatically (local misses try warm peers before shared
    storage; disable with ``$REPRO_PEER_FETCH=0``), and as a *server* when
    ``$REPRO_BLOB_ADDR`` names a ``host:port`` to serve cached blobs on —
    the advertised address rides ``register``, and a coordinator that
    predates the fabric degrades both halves to plain storage reads.
    Results travel back as ``complete(meta=...)`` payloads; outputs and
    provenance are committed to shared storage exactly as in-process nodes
    commit them, so the coordinator's exactly-one-ok arbitration spans
    processes for free. Returns the number of units this worker recorded.
    A lost coordinator (connection drop) ends the worker quietly: its
    silence is the crash signal the reaper is built around."""
    import os as _os
    from ..core.pipelines import builtin_pipelines
    from .blobserve import (BLOB_ADDR_ENV, PEER_FETCH_ENV, BlobServer,
                            PeerFabric, parse_blob_addr)
    from .rpc import QueueClient
    if isinstance(pipeline, str):
        # "auto" hands the node the whole builtin registry, resolved per
        # unit by name — what a worker joining a staged (mixed-pipeline)
        # DAG campaign wants; any other string names a single pipeline
        pipeline = (builtin_pipelines() if pipeline == "auto"
                    else builtin_pipelines()[pipeline])
    if cache is None:
        cache = cache_from_env()
    client = QueueClient(addr)
    cursor = summary = None
    blob_server = None
    if cache is not None:
        cursor, summary = cache.summary_sync()
        raw = _os.environ.get(BLOB_ADDR_ENV)
        if raw:
            blob_server = BlobServer(cache, *parse_blob_addr(raw)).start()
        if _os.environ.get(PEER_FETCH_ENV, "1") != "0":
            cache.attach_fabric(PeerFabric(
                lambda digests: client.locate_blobs(digests, node_id=node_id),
                self_addr=blob_server.advertise if blob_server else None))
    try:
        if not client.register(node_id, summary=summary,
                               blob_addr=(blob_server.advertise
                                          if blob_server else None)):
            raise RuntimeError(
                f"queue at {addr} rejected node id {node_id!r} "
                "(reaped earlier? rejoin under a fresh id)")

        # no record callback: the Node commits every completion through its
        # own client handle (Node._report), which is also what lets a
        # reconnecting worker keep committing across a coordinator restart
        node = Node(node_id, client, pipeline, Path(data_root),
                    prefetch=prefetch, max_retries=max_retries,
                    backoff_s=backoff_s, hb_interval_s=hb_interval_s,
                    poll_s=poll_s, cache=cache, summary_cursor=cursor,
                    blob_server=blob_server)
        blob_server = None               # the node owns its shutdown now
        node.start()
        try:
            while node.is_alive():
                node.join(timeout=poll_s * 4)
        except KeyboardInterrupt:
            node.kill()
            node.join(timeout=5.0)
        finally:
            node.kill()                  # stops the blob server too
            client.close()
        return node.processed
    finally:
        if blob_server is not None:      # register failed before handoff
            blob_server.stop()
