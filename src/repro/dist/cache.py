"""Per-host content-addressed input cache for the cluster data plane.

The paper's cost argument rests on keeping storage->compute transfer fast
(0.60 Gb/s over the lab network vs 0.33 Gb/s from cloud storage); once nodes
are real machines behind ``repro.dist.rpc``, every input fetch crosses that
link. This cache makes repeated fetches free: a work unit whose inputs were
already pulled by *any* prior lease on the host — a retried unit, a stolen
unit whose neighbour shares a subject, a speculative twin — hits node-local
disk instead of shared storage.

Design:

* **Content-addressed blobs.** A cached file is stored once under the sha256
  of its bytes (``<cache>/blobs/<digest>``), so two source paths with equal
  content share one blob, and the digest a hit returns is byte-for-byte the
  digest the provenance records (``inputs: path -> sha256``).
* **Source index.** Lookups key on ``abspath:size:mtime_ns`` of the shared-
  storage file — anything cheaper than reading the bytes — mapping to the
  content digest. A source file whose rewrite changes its size or mtime
  gets a new key, so its stale blob is never served (the old blob ages out
  via LRU). The residual window is a same-size in-place rewrite within the
  storage filesystem's mtime granularity (coarse on NFS/FAT) — served bytes
  still match the *recorded* checksum, so provenance stays self-consistent,
  but archive-discipline (no in-place mutation of inputs) is what rules the
  window out; see the caveat in ``docs/operating.md``.
* **Verified hits.** A hit re-hashes the local bytes and falls back to a
  miss (dropping the blob) on mismatch — a corrupted cache degrades to
  shared-storage reads, never to wrong data. One read per byte either way,
  the same single-pass discipline as :mod:`repro.core.integrity`.
* **Size-bounded LRU.** Total blob bytes are capped at ``max_bytes``;
  inserting past the cap evicts least-recently-used blobs. The source index
  persists as an append-only JSON-lines journal (O(1) per insert; compacted
  atomically on eviction, torn tail lines skipped on load) so a restarted
  worker re-uses the host's warm cache.
* **Peer fabric.** With a :class:`~repro.dist.blobserve.PeerFabric`
  attached, a local miss whose content digest is known from the manifest
  first asks the coordinator which warm peer already holds that blob and
  streams it over the node-to-node link instead of the shared-storage choke
  point — the paper's 0.60 Gb/s storage link becomes a last resort, not the
  only path. Peer bytes are sha256-re-verified on arrival and every failure
  (dead peer, timeout, Bloom false positive, digest mismatch) falls back to
  the storage read, so correctness is never routed through the fabric.
* **Pinned reads.** Blob reads — local hits and peer serves alike — hold a
  refcount pin for the duration of the read, and ``_evict_to_budget`` skips
  pinned blobs (temporarily overshooting the byte budget rather than
  unlinking a file a concurrent reader has open).
* **Digest summary.** The cache maintains a :class:`DigestSummary` — a
  counting Bloom filter over the blob sha256s, updated on every insert and
  evict — that serializes to a few KB no matter how many blobs the host
  holds. Nodes push it (full on join, deltas piggybacked on heartbeats) to
  the coordinator, whose :class:`~repro.dist.queue.WorkQueue` scores
  candidate units by estimated cache-local bytes and places work where its
  inputs already live. That turns this cache from a lucky retry win into a
  placement policy (see the placement-policy section of ``docs/cluster.md``).

Thread-safe: one lock guards index + LRU state; nodes sharing a host (and a
cache dir) within a process share one :class:`InputCache`. Cross-process
sharing of a cache dir is safe for blobs (content-addressed, atomically
committed) with last-writer-wins on the index — the loser's entries are
re-fetched, never corrupted.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import secrets
import threading
import time
from collections import OrderedDict, deque
from contextlib import contextmanager
from pathlib import Path
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core import stream as stream_mod
from ..core.integrity import atomic_write_bytes

# Runbook knobs (docs/operating.md): where the host cache lives and how big
# it may grow. Read by the worker CLI (repro.dist.rpc) and ClusterRunner.
CACHE_DIR_ENV = "REPRO_CACHE_DIR"
CACHE_MAX_MB_ENV = "REPRO_CACHE_MAX_MB"
DEFAULT_MAX_BYTES = 1 << 30          # 1 GiB per host


def cache_from_env(default_dir: Optional[Path] = None) -> Optional["InputCache"]:
    """Build an :class:`InputCache` from the runbook env knobs; ``None`` when
    no cache dir is configured (cold path: every fetch hits shared storage)."""
    root = os.environ.get(CACHE_DIR_ENV) or default_dir
    if not root:
        return None
    max_mb = os.environ.get(CACHE_MAX_MB_ENV)
    max_bytes = int(float(max_mb) * 2**20) if max_mb else DEFAULT_MAX_BYTES
    if max_bytes <= 0:
        return None                  # a zero budget means "no cache", not a crash
    return InputCache(Path(root), max_bytes=max_bytes)


SUMMARY_WIRE_VERSION = 1     # bump when the summary wire shape changes;
                             # receivers ignore versions they don't speak
                             # (locality-blind fallback, never a crash)

# retained per-cache op-log window: a consumer whose cursor fell further
# behind than this gets a full summary instead of a delta
SUMMARY_OPS_RETAINED = 4096

# Bloom positions require a sha256 of the digest *string*; the coordinator
# probes the same unit digests against every node's summary on every grant,
# so memoize the hash bytes process-wide (positions are then one cheap mod
# per cell). Bounded by wholesale clear; GIL makes the get/set race benign —
# a lost write just re-hashes once.
_DIGEST_HASH_CACHE: Dict[str, bytes] = {}
_DIGEST_HASH_CACHE_MAX = 1 << 16


def _digest_hash(digest: str) -> bytes:
    h = _DIGEST_HASH_CACHE.get(digest)
    if h is None:
        if len(_DIGEST_HASH_CACHE) >= _DIGEST_HASH_CACHE_MAX:
            _DIGEST_HASH_CACHE.clear()
        h = hashlib.sha256(digest.encode()).digest()
        _DIGEST_HASH_CACHE[digest] = h
    return h


class DigestSummary:
    """Counting Bloom filter over blob content digests.

    The compact "what does this host hold" answer the coordinator needs for
    locality-aware placement: ``d in summary`` is *probably in the cache*
    (false positives at the usual Bloom rate, never false negatives for
    balanced add/discard), costs O(k), and the whole structure serializes to
    a few KB regardless of blob count. Counting (not bit) cells make
    evictions removable, so one summary tracks a churning LRU cache for the
    life of the host.

    Positions are derived by re-hashing the digest string (sha256 of its
    UTF-8 bytes, k 4-byte windows mod m) — uniform for any key, including
    non-hex test digests. Not thread-safe on its own; :class:`InputCache`
    mutates it under its lock, and the coordinator under the queue lock.
    """

    def __init__(self, m: int = 8192, k: int = 4):
        if m <= 0 or k <= 0 or 4 * k > 32:
            raise ValueError(f"bad summary geometry m={m} k={k}")
        self.m = int(m)
        self.k = int(k)
        self._counts: List[int] = [0] * self.m
        self._n = 0                          # distinct adds currently held

    def _positions(self, digest: str) -> List[int]:
        raw = _digest_hash(digest)
        return [int.from_bytes(raw[4 * i:4 * i + 4], "big") % self.m
                for i in range(self.k)]

    def add(self, digest: str):
        for p in self._positions(digest):
            if self._counts[p] < 0xFFFF:     # saturate, never wrap
                self._counts[p] += 1
        self._n += 1

    def discard(self, digest: str):
        """Remove one prior ``add``. A discard for a digest never added is a
        no-op (decrementing would manufacture false negatives elsewhere)."""
        pos = self._positions(digest)
        if any(self._counts[p] == 0 for p in pos):
            return
        for p in pos:
            self._counts[p] -= 1
        self._n = max(0, self._n - 1)

    def __contains__(self, digest: str) -> bool:
        return all(self._counts[p] > 0 for p in self._positions(digest))

    def __len__(self) -> int:
        return self._n

    def to_wire(self) -> dict:
        """Sparse JSON encoding: only non-zero cells travel, so an empty or
        lightly-loaded summary is tens of bytes and a full one a few KB."""
        return {"v": SUMMARY_WIRE_VERSION, "m": self.m, "k": self.k,
                "n": self._n,
                "nz": [[i, c] for i, c in enumerate(self._counts) if c]}

    @classmethod
    def from_wire(cls, wire: object) -> Optional["DigestSummary"]:
        """Decode a :meth:`to_wire` payload; ``None`` for anything this
        version doesn't speak — the caller falls back to locality-blind."""
        if not isinstance(wire, dict) or wire.get("v") != SUMMARY_WIRE_VERSION:
            return None
        try:
            s = cls(int(wire["m"]), int(wire["k"]))
            for i, c in wire["nz"]:
                s._counts[int(i)] = min(0xFFFF, max(0, int(c)))
            s._n = max(0, int(wire.get("n", 0)))
            return s
        except (KeyError, TypeError, ValueError, IndexError):
            return None


class InputCache:
    """sha256-keyed, size-bounded LRU blob cache on node-local disk."""

    def __init__(self, root: Path, *, max_bytes: int = DEFAULT_MAX_BYTES):
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        self.root = Path(root)
        self.blob_dir = self.root / "blobs"
        self.blob_dir.mkdir(parents=True, exist_ok=True)
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._index: Dict[str, str] = {}              # source key -> digest
        self._blobs: "OrderedDict[str, int]" = OrderedDict()  # digest -> bytes (LRU)
        self._total = 0                               # running blob byte total
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_from_cache = 0     # blob bytes served locally (hits)
        self.bytes_from_storage = 0   # bytes that crossed the storage link
        self.bytes_from_peer = 0      # bytes that crossed a node-to-node link
        self.peer_hits = 0            # misses satisfied by a warm peer
        self.peer_serves = 0          # blob reads served TO peers (blobserve)
        self.bytes_to_peers = 0
        self.storage_seconds = 0.0    # wall time on the storage link (misses)
        self.peer_seconds = 0.0       # wall time on peer links (fetch side)
        # streaming-ingest meters (repro.core.stream): misses whose digest
        # was computed chunk-by-chunk while the bytes moved, and the wall
        # time the overlap pipeline saved versus a load-then-hash sequence
        self.stream_fetches = 0
        self.stream_bytes = 0
        self.stream_chunks = 0
        self.stream_hash_seconds = 0.0
        self.stream_device_seconds = 0.0
        self.stream_overlap_seconds = 0.0
        self._peer_bytes_by_addr: Dict[str, int] = {}   # per-link byte meter
        self._pins: Dict[str, int] = {}     # digest -> open-reader refcount
        # optional PeerFabric (repro.dist.blobserve): when attached, misses
        # with a manifest digest hint try warm peers before shared storage
        self.fabric = None
        # digest summary + op log for locality-aware placement: every blob
        # insert/evict lands in the summary and in a bounded op window that
        # nodes drain as heartbeat-piggybacked deltas (multiple nodes sharing
        # one host cache each keep their own cursor; a cursor that falls off
        # the window triggers a full re-sync instead)
        self.summary = DigestSummary()
        self._ops: Deque[Tuple[int, str, str]] = deque()   # (seq, op, digest)
        # op seqs start at a per-life random base: a consumer's cursor from
        # a previous cache life (wiped dir, counter reset) can then never
        # alias into this life's seq range, so a cross-life delta request
        # degrades to a full resync instead of silently serving a partial
        # delta that leaves the consumer's summary drifted forever
        self._seq = secrets.randbits(48)
        self._load_persisted()

    # -- persistence ---------------------------------------------------------
    # append-only JSON-lines journal: O(1) write per insert (a full-index
    # rewrite per miss would make cold runs O(n^2)), last entry per key wins
    # on load, compacted to the live set whenever eviction shrinks it

    def _index_path(self) -> Path:
        return self.root / "index.jsonl"

    def _load_persisted(self):
        """Adopt blobs + index left by a previous worker on this host."""
        persisted: Dict[str, str] = {}
        try:
            for line in self._index_path().read_text().splitlines():
                try:
                    entry = json.loads(line)
                    persisted[entry["k"]] = entry["d"]
                except (json.JSONDecodeError, KeyError, TypeError):
                    continue             # torn tail line from a crash: skip
        except OSError:
            pass
        found = []
        for p in self.blob_dir.iterdir():
            if p.name.startswith("."):           # in-flight atomic-write tmps
                continue
            try:                                 # concurrent evict/rename race
                st = p.stat()
            except OSError:
                continue
            found.append((st.st_mtime, p.name, st.st_size))
        for _, name, size in sorted(found):      # oldest first = LRU order
            self._blobs[name] = size
            self.summary.add(name)
        self._total = sum(self._blobs.values())
        self._index = {k: d for k, d in persisted.items() if d in self._blobs}

    def _append_index(self, key: str, digest: str):
        with open(self._index_path(), "a") as f:
            f.write(json.dumps({"k": key, "d": digest}) + "\n")

    def _compact_index(self):
        lines = "".join(json.dumps({"k": k, "d": d}) + "\n"
                        for k, d in self._index.items())
        atomic_write_bytes(self._index_path(), lines.encode(), fsync=False)

    # -- core ----------------------------------------------------------------

    @staticmethod
    def _source_key(path: Path) -> Optional[str]:
        try:
            st = os.stat(path)
        except OSError:
            return None
        return f"{os.path.abspath(path)}:{st.st_size}:{st.st_mtime_ns}"

    def _blob_path(self, digest: str) -> Path:
        return self.blob_dir / digest

    def _record_op(self, op: str, digest: str):
        """Caller holds the lock: mirror a blob insert/evict into the digest
        summary and the bounded delta window nodes drain for the coordinator."""
        (self.summary.add if op == "add" else self.summary.discard)(digest)
        self._seq += 1
        self._ops.append((self._seq, op, digest))
        while len(self._ops) > SUMMARY_OPS_RETAINED:
            self._ops.popleft()

    def _evict_to_budget(self, evicted_out: List[str]) -> bool:
        """Caller holds the lock. Drops LRU entries from the in-memory state
        and appends their digests to ``evicted_out`` — the caller unlinks the
        files *after* releasing the lock (disk I/O never blocks peers).
        Pinned blobs (a concurrent local read or peer serve in flight) are
        never victims: the cache overshoots its byte budget until the pin is
        released rather than unlink a file a reader has open."""
        evicted = False
        while self._total > self.max_bytes:
            victim = next((d for d in self._blobs if d not in self._pins),
                          None)
            if victim is None:
                break                # every resident blob is mid-read
            size = self._blobs.pop(victim)
            self._total -= size
            evicted_out.append(victim)
            self.evictions += 1
            self._record_op("drop", victim)
            evicted = True
        if evicted:
            live = set(self._blobs)
            self._index = {k: d for k, d in self._index.items() if d in live}
        return evicted

    # -- pinned blob reads (local hits and the peer-serving path) ------------

    def pin(self, digest: str) -> bool:
        """Take a refcount hold on ``digest`` so eviction cannot unlink its
        file while a read is in flight. ``False`` (no pin taken) when the
        blob is not resident."""
        with self._lock:
            if digest not in self._blobs:
                return False
            self._pins[digest] = self._pins.get(digest, 0) + 1
            return True

    def unpin(self, digest: str):
        with self._lock:
            n = self._pins.get(digest, 0) - 1
            if n > 0:
                self._pins[digest] = n
            else:
                self._pins.pop(digest, None)

    @contextmanager
    def hold(self, digest: str):
        """Context-managed :meth:`pin`; yields whether the hold was taken."""
        ok = self.pin(digest)
        try:
            yield ok
        finally:
            if ok:
                self.unpin(digest)

    def read_blob(self, digest: str) -> Optional[bytes]:
        """Raw blob bytes for the peer-serving path
        (:class:`repro.dist.blobserve.BlobServer`), pinned for the duration
        of the read so :meth:`_evict_to_budget` cannot unlink the file
        mid-serve. ``None`` when the blob is not resident — the requester's
        Bloom summary gave a false positive (or the summary is stale) and it
        falls back to shared storage. The *receiving* side re-verifies the
        sha256, so this path serves bytes without re-hashing them."""
        if not self.pin(digest):
            return None
        try:
            data = self._blob_path(digest).read_bytes()
        except OSError:
            return None
        finally:
            self.unpin(digest)
        with self._lock:
            if digest in self._blobs:
                self._blobs.move_to_end(digest)      # a served blob is warm
            self.peer_serves += 1
            self.bytes_to_peers += len(data)
        return data

    def attach_fabric(self, fabric):
        """Attach a :class:`repro.dist.blobserve.PeerFabric`; subsequent
        misses with a manifest digest hint try warm peers before storage.
        The fabric rides the cache handle, so every call site that already
        passes ``cache=`` (workflow, cluster, retries that distrust the
        cache after attempt 1) inherits the peer path with no new plumbing."""
        self.fabric = fabric

    @staticmethod
    def _read_storage(src: Path) -> bytes:
        """The one seam every shared-storage read crosses. Benchmarks
        monkeypatch this to model the paper's 0.60 Gb/s storage link without
        faking the peer path; production never overrides it."""
        return Path(src).read_bytes()

    def _storage_chunks(self, src: Path, chunk_bytes: int):
        """Chunked twin of :meth:`_read_storage` for the streaming data
        plane (``repro.core.stream``): yields the file's bytes in
        ``chunk_bytes`` pieces so hashing and QA can overlap the transfer.
        When a benchmark (or test) has monkeypatched ``_read_storage`` to
        model the storage link, that seam is honored — its whole-file
        result is re-chunked, so the modeled link cost still lands on the
        read stage — and benchmarks that model a *chunked* link override
        this method directly."""
        rs = self._read_storage
        if rs is not _DEFAULT_READ_STORAGE:
            yield from stream_mod.bytes_chunks(rs(src), chunk_bytes)
        else:
            yield from stream_mod.file_chunks(Path(src), chunk_bytes)

    def _insert_blob(self, digest: str, data: bytes, key: Optional[str]):
        """Commit ``data`` as blob ``digest``, map ``key`` to it (when
        given), then evict down to budget. The multi-MB blob write happens
        OUTSIDE the lock — it must not serialize the other prefetch threads'
        fetches. Content addressing + atomic rename make a racing duplicate
        writer idempotent (same bytes, last rename wins)."""
        with self._lock:
            known = digest in self._blobs
        if not known:
            atomic_write_bytes(self._blob_path(digest), data, fsync=False)
        evict: List[str] = []
        with self._lock:
            if digest not in self._blobs:
                self._total += len(data)
                self._record_op("add", digest)
            self._blobs[digest] = len(data)
            self._blobs.move_to_end(digest)
            if key:
                self._index[key] = digest
            if self._evict_to_budget(evict):
                self._compact_index()
            elif key:
                self._append_index(key, digest)
        for d in evict:                          # unlinks, after the lock
            self._blob_path(d).unlink(missing_ok=True)

    def fetch_array(self, src: Path, *, digest_hint: Optional[str] = None,
                    size_hint: Optional[int] = None,
                    device_qa: bool = False,
                    ) -> Tuple[np.ndarray, str, str, int, Optional[dict]]:
        """Load the .npy at ``src``, serving from the host cache when its
        bytes are already local. Returns
        ``(array, sha256, origin, nbytes, stream)`` where ``origin`` is
        ``"cache"`` (local blob hit), ``"peer"`` (blob streamed from a warm
        peer over the fabric) or ``"storage"`` (shared storage read) — the
        digest is of the file content in every case, so provenance input
        checksums are identical across origins, and ``nbytes`` is the file
        size that moved over (or stayed off) each link. ``stream`` is the
        :class:`repro.core.stream.StreamReport` dict for a chunk-streamed
        storage miss (digest — and with ``device_qa`` the fused QA fold —
        computed while the bytes moved; see ``REPRO_STREAM_INGEST``), else
        ``None``. On a local miss, a manifest ``digest_hint`` plus an
        attached fabric tries the warmest peer first; any peer failure
        falls back to one storage read, after which the bytes are inserted
        locally (then evicted down to ``max_bytes``). ``size_hint`` (the
        manifest's byte count) guards the peer path against a source file
        rewritten since the manifest scan: on size disagreement the fetch
        goes straight to storage so it observes the current bytes."""
        src = Path(src)
        key = self._source_key(src)
        with self._lock:
            digest = self._index.get(key) if key else None
            pinned = False
            if digest is not None and digest in self._blobs:
                # pin under the same lock that resolved the index entry, so
                # eviction cannot unlink the file before read_bytes opens it
                self._pins[digest] = self._pins.get(digest, 0) + 1
                pinned = True
        if digest is not None:
            try:
                data = self._blob_path(digest).read_bytes()
            except OSError:
                data = None
            finally:
                if pinned:
                    self.unpin(digest)
            if data is not None and hashlib.sha256(data).hexdigest() == digest:
                with self._lock:
                    if digest in self._blobs:
                        self._blobs.move_to_end(digest)       # LRU touch
                    self.hits += 1
                    self.bytes_from_cache += len(data)
                return (np.load(io.BytesIO(data), allow_pickle=False),
                        digest, "cache", len(data), None)
            with self._lock:                # corrupt or vanished blob: drop it
                size = self._blobs.pop(digest, None)
                if size is not None:
                    self._total -= size
                    self._record_op("drop", digest)
                self._blob_path(digest).unlink(missing_ok=True)
                self._index = {k: d for k, d in self._index.items()
                               if d != digest}
        # local miss: try the peer fabric before touching the storage link.
        # The fabric re-verifies sha256(data) == digest_hint before handing
        # bytes back, so a lying or corrupted peer degrades to the storage
        # read below, never to wrong data.
        st_size: Optional[int] = None
        try:
            st_size = os.stat(src).st_size
        except OSError:
            pass                 # storage blip: the peer path may still save us
        fabric = self.fabric
        if (fabric is not None and digest_hint
                and (size_hint is None or st_size is None
                     or st_size == size_hint)):
            t0 = time.perf_counter()
            got = fabric.fetch(digest_hint)
            dt = time.perf_counter() - t0
            arr = None
            if got is not None:
                data, addr = got
                try:
                    arr = np.load(io.BytesIO(data), allow_pickle=False)
                except Exception:        # manifest digest of a non-npy: fall back
                    arr = None
            with self._lock:
                self.peer_seconds += dt
                if arr is not None:
                    self.misses += 1     # still a *local* miss
                    self.peer_hits += 1
                    self.bytes_from_peer += len(data)
                    self._peer_bytes_by_addr[addr] = (
                        self._peer_bytes_by_addr.get(addr, 0) + len(data))
            if arr is not None:
                if len(data) <= self.max_bytes:
                    # map the source key only when the file on storage still
                    # matches the fetched size — a stale manifest must not
                    # alias a rewritten source onto old content
                    self._insert_blob(digest_hint, data,
                                      key if st_size == len(data) else None)
                return arr, digest_hint, "peer", len(data), None
        # storage: one pass over the shared link. With streaming on (the
        # default) the bytes cross chunk-by-chunk and the sha256 — plus,
        # when asked, the fused device QA fold — runs *while* they move; a
        # prefetch thread keeps the link busy during each chunk's hashing.
        # REPRO_STREAM_INGEST=0 restores the read-then-hash sequence.
        stream_info: Optional[dict] = None
        t0 = time.perf_counter()
        if stream_mod.stream_enabled():
            cb = stream_mod.stream_chunk_bytes()
            pf = stream_mod._Prefetcher(self._storage_chunks(src, cb))
            data, digest, _qa, rep = stream_mod.stream_chunks(
                pf, npy_qa=device_qa, chunk_bytes=cb, prefetch=pf)
            stream_info = rep.to_dict()
        else:
            data = self._read_storage(src)
            digest = hashlib.sha256(data).hexdigest()
            rep = None
        dt = time.perf_counter() - t0
        arr = np.load(io.BytesIO(data), allow_pickle=False)
        with self._lock:
            self.misses += 1
            self.bytes_from_storage += len(data)
            self.storage_seconds += dt
            if rep is not None:
                self.stream_fetches += 1
                self.stream_bytes += rep.nbytes
                self.stream_chunks += rep.chunks
                self.stream_hash_seconds += rep.hash_s
                self.stream_device_seconds += rep.device_s
                self.stream_overlap_seconds += rep.overlap_s
        if len(data) > self.max_bytes:
            # an input bigger than the whole budget can never be served
            # later; inserting it would wipe every warm blob on the host
            # (and re-wipe on each fetch) for nothing — pass it through
            return arr, digest, "storage", len(data), stream_info
        self._insert_blob(digest, data, key)
        return arr, digest, "storage", len(data), stream_info

    def put_bytes(self, data: bytes, *, digest: Optional[str] = None,
                  source: Optional[Path] = None) -> Optional[str]:
        """Write-through insertion: commit ``data`` as a content-addressed
        blob without a fetch having missed first. This is how pipeline
        *outputs* land in the producer host's cache the moment their
        provenance commits, so a DAG child scheduled on the same host
        (producer placement, ``repro.core.campaign``) hits local blobs
        instead of re-reading shared storage. ``source`` additionally maps
        the committed file's source key to the blob, making a later
        ``fetch_array`` of that exact path a direct hit; ``digest`` (when
        the caller already hashed the bytes, e.g. ``sha256_save_array``)
        skips re-hashing. Returns the digest, or ``None`` for data bigger
        than the whole budget (same passthrough rule as ``fetch_array`` —
        inserting it would wipe every warm blob for nothing)."""
        if len(data) > self.max_bytes:
            return None
        d = digest or hashlib.sha256(data).hexdigest()
        key = self._source_key(Path(source)) if source is not None else None
        self._insert_blob(d, data, key)
        return d

    # -- digest-summary sync (locality-aware placement) ----------------------

    def _stats_locked(self) -> Dict[str, object]:
        st: Dict[str, object] = {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions,
            "bytes": self._total, "blobs": len(self._blobs),
            "bytes_from_cache": self.bytes_from_cache,
            "bytes_from_storage": self.bytes_from_storage,
            "bytes_from_peer": self.bytes_from_peer,
            "peer_hits": self.peer_hits,
            "peer_serves": self.peer_serves,
            "bytes_to_peers": self.bytes_to_peers,
            "storage_seconds": self.storage_seconds,
            "peer_seconds": self.peer_seconds,
            "stream_fetches": self.stream_fetches,
            "stream_bytes": self.stream_bytes,
            "stream_chunks": self.stream_chunks,
            "stream_hash_seconds": self.stream_hash_seconds,
            "stream_device_seconds": self.stream_device_seconds,
            "stream_overlap_seconds": self.stream_overlap_seconds,
            "peer_false_positives": 0,
            # per-link byte meter: {peer addr -> bytes fetched from it};
            # travels with the stats so WorkQueue.stats_snapshot can expose
            # cluster-wide link utilisation (numeric roll-ups skip it)
            "peer_bytes_by_addr": dict(self._peer_bytes_by_addr)}
        if self.fabric is not None:
            st.update(self.fabric.counters())
        return st

    # full-push wires list exact digests up to this many blobs (64-hex chars
    # each: 64k blobs ≈ 4 MiB, inside the rpc frame cap); a larger cache
    # omits the list and the coordinator's warm-set index rebuild falls back
    # to probing the Bloom filter, exactly the pre-list behaviour
    SUMMARY_DIGEST_LIST_CAP = 65536

    def summary_sync(self) -> Tuple[int, dict]:
        """Full summary push: ``(cursor, wire)`` where the wire carries the
        whole Bloom filter, an exact ``digests`` list (capped; lets the
        coordinator rebuild its warm-set index without Bloom false
        positives — old coordinators ignore the unknown key), plus current
        cache stats. A node sends this once on join
        (``register``/``put_summary``) and keeps ``cursor`` to drain deltas
        from."""
        with self._lock:
            wire = {"v": SUMMARY_WIRE_VERSION,
                    "full": self.summary.to_wire(),
                    "stats": self._stats_locked()}
            if len(self._blobs) <= self.SUMMARY_DIGEST_LIST_CAP:
                wire["digests"] = sorted(self._blobs)
            return self._seq, wire

    def summary_delta_since(self, cursor: int) -> Tuple[int, dict]:
        """Heartbeat piggyback: ``(new_cursor, wire)``. The wire carries the
        blob digests added/dropped since ``cursor`` (typically empty or a
        handful — bytes, not KB) and always the live stats counters. A
        cursor that fell off the retained op window degrades to a full
        summary, so a long-asleep node resyncs instead of drifting."""
        with self._lock:
            stats = self._stats_locked()
            # a delta is complete only for a cursor contiguous with the
            # retained window: within [oldest_seq - 1, seq] (with no ops
            # retained, exactly seq). Anything else — fell off the window,
            # ahead of the counter, or a cursor from a previous cache life
            # (the random per-life seq base makes those land outside the
            # range) — degrades to a full resync, never a partial delta
            oldest = self._ops[0][0] if self._ops else self._seq + 1
            if cursor > self._seq or cursor < oldest - 1:
                return self._seq, {"v": SUMMARY_WIRE_VERSION,
                                   "full": self.summary.to_wire(),
                                   "stats": stats}
            add = [d for seq, op, d in self._ops if seq > cursor and op == "add"]
            drop = [d for seq, op, d in self._ops if seq > cursor and op == "drop"]
            return self._seq, {"v": SUMMARY_WIRE_VERSION, "add": add,
                               "drop": drop, "stats": stats}

    # -- introspection -------------------------------------------------------

    def total_bytes(self) -> int:
        with self._lock:
            return self._total

    def blob_count(self) -> int:
        with self._lock:
            return len(self._blobs)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return self._stats_locked()


# Captured at import so ``_storage_chunks`` can tell whether a benchmark or
# test has monkeypatched the ``_read_storage`` seam (the modeled link then
# keeps its cost, re-chunked).
_DEFAULT_READ_STORAGE = InputCache._read_storage


# ---------------------------------------------------------------------------
# serialized summaries: the offline half of campaign planning
# ---------------------------------------------------------------------------
# A live coordinator serves per-node summaries over rpc
# (``WorkQueue.summaries_snapshot``); on an HPC login node there is no live
# coordinator, only last night's cache directories on each host. These
# helpers make summaries a file-shaped artifact: harvest them from cache
# dirs, ship one JSON to wherever ``repro.core.campaign`` plans the next
# job array, and load them back — same versioned wire either way, so the
# planner cannot tell (and does not care) whether its view came off a
# socket or a filesystem.

def harvest_summary(cache_dir: Path) -> Optional[dict]:
    """The full summary wire for one host's persisted cache directory, by
    adopting its blobs exactly as a restarted worker would. ``None`` for a
    path that is not a cache dir (no ``blobs/``) — callers skip, not crash."""
    cache_dir = Path(cache_dir)
    if not (cache_dir / "blobs").is_dir():
        return None
    _, wire = InputCache(cache_dir).summary_sync()
    return wire


def summaries_from_cache_dirs(root: Path) -> Dict[str, dict]:
    """``{node_id: summary wire}`` for every ``<root>/<node_id>`` cache dir
    — the per-node layout ``ClusterRunner(cache_per_node=True)`` writes and
    a multi-host fleet mirrors one level up. Sorted for determinism."""
    root = Path(root)
    out: Dict[str, dict] = {}
    if not root.is_dir():
        return out
    for child in sorted(p for p in root.iterdir() if p.is_dir()):
        wire = harvest_summary(child)
        if wire is not None:
            out[child.name] = wire
    return out


def save_summary_file(path: Path, summaries: Dict[str, object]) -> Path:
    """Serialize ``{node_id: DigestSummary | wire}`` to one deterministic
    JSON file (the campaign planner's ``summaries=`` input)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    wires = {n: ({"v": SUMMARY_WIRE_VERSION, "full": s.to_wire()}
                 if isinstance(s, DigestSummary) else s)
             for n, s in summaries.items()}
    path.write_text(json.dumps(wires, sort_keys=True, indent=1) + "\n")
    return path


def load_summary_file(path: Path) -> Dict[str, dict]:
    """Load a :func:`save_summary_file` artifact. Wire validation happens at
    use (``DigestSummary.from_wire``) so version skew degrades to blind
    planning for that node, consistent with the coordinator's fail-soft."""
    raw = json.loads(Path(path).read_text())
    if not isinstance(raw, dict):
        raise ValueError(f"{path}: summaries file must be a JSON object")
    return raw
