import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
512 placeholder CPU devices standing in for the production TPU mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Writes one JSON per cell under experiments/dryrun/ containing
memory_analysis, cost_analysis, parsed collective bytes, and roofline terms.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _set_mesh(mesh):
    """``jax.set_mesh`` appeared after 0.4.x; a ``Mesh`` is already a context
    manager there, so fall back to entering the mesh itself."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh

from ..analysis.flops import step_flops, step_hbm_bytes
from ..analysis.hlo_parse import HloCosts
from ..analysis.roofline import (HW, collective_bytes_from_hlo, model_flops,
                                 roofline_terms, summarize_memory)
from ..configs import (SHAPE_BY_NAME, SHAPES, ARCH_IDS, cell_is_runnable,
                       get_config)
from ..dist.sharding import Rules, use_rules
from ..launch.mesh import make_production_mesh
from ..launch.specs import (batch_specs, cache_specs, decode_inputs,
                            safe_sharding, state_specs)
from ..serve.serve_step import make_decode_step, make_prefill_step
from ..train.optimizer import OptConfig
from ..train.train_step import make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def rules_kind(shape) -> str:
    if shape.kind == "train":
        return "train"
    if shape.name.startswith("long"):
        return "long"
    return shape.kind


def lower_cell(cfg, shape, mesh, *, extra_tag: str = "", step_override=None,
               policy: str = "tp"):
    """Lower + compile one cell. Returns the result record."""
    kind = rules_kind(shape)
    rules = Rules(mesh, kind, policy, global_batch=shape.global_batch)
    t0 = time.time()
    with _set_mesh(mesh), use_rules(rules):
        if shape.kind == "train":
            params, pshard, opt, oshard = state_specs(cfg, rules)
            batch, bshard = batch_specs(cfg, shape, rules, "train")
            step = step_override or make_train_step(
                cfg, OptConfig(), accum_steps=getattr(cfg, "accum_steps", 1))
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params, opt, batch)
        elif shape.kind == "prefill":
            params, pshard, _, _ = state_specs(cfg, rules, dtype=jnp.bfloat16)
            batch, bshard = batch_specs(cfg, shape, rules, "prefill")
            _, cshard = cache_specs(cfg, shape, rules)
            logits_shard = safe_sharding(mesh, rules.spec("batch", "vocab"),
                                         (shape.global_batch, cfg.vocab_size))
            step = step_override or make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(pshard, bshard),
                             out_shardings=(logits_shard, cshard))
            lowered = jitted.lower(params, batch)
        else:  # decode
            params, pshard, _, _ = state_specs(cfg, rules, dtype=jnp.bfloat16)
            cache, cshard = cache_specs(cfg, shape, rules)
            (token, tshard), (pos, posshard) = decode_inputs(cfg, shape, rules)
            logits_shard = safe_sharding(mesh, rules.spec("batch", None, "vocab"),
                                         (shape.global_batch, 1, cfg.vocab_size))
            step = step_override or make_decode_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(pshard, cshard, tshard, posshard),
                             out_shardings=(logits_shard, cshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, cache, token, pos)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = summarize_memory(compiled.memory_analysis())
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    n_chips = mesh.devices.size
    # loop-aware collective accounting (per-chip byte totals; see hlo_parse)
    coll = HloCosts(hlo).collective_bytes()
    coll["naive"] = collective_bytes_from_hlo(hlo)   # loop bodies counted once
    # analytic flops/bytes (cost_analysis undercounts scanned loops)
    fl = step_flops(cfg, shape, shape.kind)
    flops_per_chip = fl["total"] / n_chips
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    bytes_per_chip = step_hbm_bytes(cfg, shape, shape.kind, n_chips, tp)
    terms = roofline_terms(flops_per_chip, bytes_per_chip,
                           coll.get("tpu_bf16_adjusted_bytes",
                                    coll["weighted_bytes"]))
    terms["collective_raw_s"] = coll["weighted_bytes"] / 50e9
    mf = model_flops(cfg, shape, shape.kind)
    rec = {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "mesh_axes": list(mesh.axis_names), "n_chips": int(n_chips),
        "tag": extra_tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "flops_per_chip": flops_per_chip,
        "flops_breakdown": fl,
        "bytes_per_chip": bytes_per_chip,
        "cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                              "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": terms,
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flop_ratio": (mf / n_chips) / flops_per_chip if flops_per_chip else None,
        "hbm_per_chip_gb": round(mem.get("peak_est_bytes", 0) / 2**30, 3),
    }
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, tag: str = "",
             policy: str = "tp"):
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why,
                "mesh": "multi" if multi_pod else "single"}
    if policy == "auto":
        # per-arch policies are tuned for training; inference shapes keep the
        # standard TP mesh (tp2d's reshaped mesh hurt llama4 prefill 30x)
        policy = cfg.preferred_policy if shape.kind == "train" else (
            "tp" if cfg.preferred_policy == "tp2d" else cfg.preferred_policy)
    if policy == "tp2d":
        from ..launch.mesh import make_tp2d_mesh
        mesh = make_tp2d_mesh(multi_pod=multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    return lower_cell(cfg, shape, mesh, extra_tag=tag, policy=policy)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--policy", default="tp",
                    choices=("tp", "fsdp", "tp2d", "auto"))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mtag = "multi" if mp else "single"
                name = f"{arch}_{shape}_{mtag}" + (f"_{args.tag}" if args.tag else "")
                out = Path(args.out) if args.out else OUT_DIR / f"{name}.json"
                try:
                    rec = run_cell(arch, shape, mp, args.tag, args.policy)
                    out.write_text(json.dumps(rec, indent=1))
                    if "skipped" in rec:
                        print(f"[skip] {name}: {rec['skipped']}")
                    else:
                        r = rec["roofline"]
                        print(f"[ok]   {name}: bound={r['bound']} "
                              f"c={r['compute_s']:.4f}s m={r['memory_s']:.4f}s "
                              f"x={r['collective_s']:.4f}s "
                              f"hbm={rec['hbm_per_chip_gb']}GB "
                              f"compile={rec['compile_s']}s", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append(name)
                    out.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mtag,
                         "error": f"{type(e).__name__}: {e}"}, indent=1))
                    print(f"[FAIL] {name}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
