"""ShapeDtypeStruct input specs + shardings for every (arch x shape) cell.

This is the dry-run's contract: weak-type-correct, shardable stand-ins for
every model input — no device allocation ever happens for full-size configs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ModelConfig, ShapeConfig
from ..dist.sharding import Rules, param_specs, shardings_for
from ..models import init_cache, init_params
from ..train.optimizer import adamw_init


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def safe_sharding(mesh: Mesh, spec: P, shape) -> NamedSharding:
    """NamedSharding with axes that don't divide the dim dropped."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_n(ax):
        if ax is None:
            return 1
        axs = (ax,) if isinstance(ax, str) else ax
        n = 1
        for a in axs:
            n *= sizes.get(a, 1)
        return n

    padded = tuple(spec) + (None,) * (len(shape) - len(spec))
    return NamedSharding(mesh, P(*[ax if dim % axis_n(ax) == 0 else None
                                   for dim, ax in zip(shape, padded)]))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules,
                kind: str) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (ShapeDtypeStruct batch, matching NamedSharding tree)."""
    mesh = rules.mesh
    B, S = shape.global_batch, shape.seq_len
    bspec = rules.spec("batch", None)
    b3spec = rules.spec("batch", None, None)
    batch: Dict[str, Any] = {}
    shard: Dict[str, Any] = {}

    n_text = S
    if cfg.vlm is not None:
        n_text = S - cfg.vlm.n_patches
        batch["embeds"] = sds((B, cfg.vlm.n_patches, cfg.d_model), jnp.bfloat16)
        shard["embeds"] = NamedSharding(mesh, b3spec)
    if cfg.encoder is not None:
        batch["enc_embeds"] = sds((B, cfg.encoder.enc_seq, cfg.d_model), jnp.bfloat16)
        shard["enc_embeds"] = NamedSharding(mesh, b3spec)

    batch["tokens"] = sds((B, n_text), jnp.int32)
    shard["tokens"] = NamedSharding(mesh, bspec)
    if kind == "train":
        batch["targets"] = sds((B, S), jnp.int32)
        shard["targets"] = NamedSharding(mesh, bspec)
    return batch, shard


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules):
    """ShapeDtypeStructs + shardings for the decode cache."""
    mesh = rules.mesh
    cache = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch,
                                              shape.seq_len, jnp.bfloat16))

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _axis_n(ax):
        if ax is None:
            return 1
        axs = (ax,) if isinstance(ax, str) else ax
        n = 1
        for a in axs:
            n *= sizes.get(a, 1)
        return n

    def spec_for(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        nd = leaf.ndim
        if name in ("k", "v", "ck", "cv"):
            s = rules.spec(*([None, "batch", "cache_seq"] + [None] * (nd - 3)))
        elif name in ("wkv", "ssm"):
            s = rules.spec(*([None, "batch", "act_model"] + [None] * (nd - 3)))
        else:
            s = rules.spec(*([None, "batch"] + [None] * (nd - 2)))
        # divisibility guard (e.g. whisper's 1500-frame cross-attn cache)
        return P(*[ax if dim % _axis_n(ax) == 0 else None
                   for dim, ax in zip(leaf.shape, tuple(s) + (None,) * (nd - len(s)))])

    specs = jax.tree_util.tree_map_with_path(spec_for, cache)
    shards = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
    return cache, shards


SERVE_REPLICATE_BUDGET = 6 * 2**30     # bf16 params/chip to allow TP-only


def state_specs(cfg: ModelConfig, rules: Rules, dtype=jnp.float32):
    """Param (+ optimizer) ShapeDtypeStructs and shardings.

    Serving (bf16 params, kind != train): FSDP-sharded weights would be
    re-all-gathered EVERY decode token (e.g. 12.75 GB/chip/token for llama4).
    When the TP-only footprint fits the budget, drop the 'data' axis from
    weight shardings — weights stream from local HBM instead of the wire
    (§Perf S2); big models keep FSDP storage (they cannot fit replicated).
    """
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))
    pspecs = param_specs(params, rules.mesh)
    if rules.kind != "train" and dtype == jnp.bfloat16:
        tp = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape)
                  ).get("model", 1)
        if cfg.n_params() * 2 / max(tp, 1) <= SERVE_REPLICATE_BUDGET:
            def drop_data(spec):
                return P(*[None if ax == "data" else
                           (tuple(a for a in ax if a != "data") or None
                            if isinstance(ax, tuple) else ax)
                           for ax in spec])
            pspecs = jax.tree.map(drop_data, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    pshard = shardings_for(rules.mesh, pspecs)
    opt = jax.eval_shape(lambda: adamw_init(params))
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    oshard = shardings_for(rules.mesh, ospecs)
    return params, pshard, opt, oshard


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules):
    """(token, pos) specs for a decode step."""
    mesh = rules.mesh
    B = shape.global_batch
    token = sds((B, 1), jnp.int32)
    tshard = NamedSharding(mesh, rules.spec("batch", None))
    pos = sds((), jnp.int32)
    pshard = NamedSharding(mesh, P())
    return (token, tshard), (pos, pshard)
