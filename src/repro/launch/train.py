"""Training driver: data pipeline -> pjit train loop -> async checkpoints.

On CPU it runs reduced configs end-to-end (examples/train_lm.py); on a real
cluster the same entrypoint runs the full config on the production mesh
(SLURM launch scripts from ``launch/slurm.py``).

Fault tolerance: resume from the latest checkpoint (``--resume``), async
saves, deterministic data (a restart replays the exact batch sequence).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data import DataPipeline, ShardedTokenSource
from ..ckpt import CheckpointManager, latest_step, restore_checkpoint
from ..train import OptConfig, init_train_state, make_train_step


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 128,
          data_dir: str = "data", ckpt_dir: str = "ckpt", reduced: bool = True,
          ckpt_every: int = 50, resume: bool = False, lr: float = 3e-4,
          log_every: int = 10, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    data_path = Path(data_dir)
    if not (data_path / ShardedTokenSource.MANIFEST).exists():
        ShardedTokenSource.synthesize(
            data_path, n_shards=4,
            tokens_per_shard=max(batch * (seq + 1) * 8, 65536),
            vocab_size=cfg.vocab_size, seed=seed)
    src = ShardedTokenSource(data_path)
    pipe = DataPipeline(src, batch=batch, seq_len=seq, seed=seed)

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(seed))
    opt = OptConfig(lr=lr, warmup_steps=max(steps // 20, 5), total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, opt))
    mgr = CheckpointManager(ckpt_dir, keep=2, digest=cfg.digest())

    start = 0
    if resume and latest_step(ckpt_dir) is not None:
        tmpl = jax.eval_shape(lambda: {"params": params, "opt": opt_state})
        restored, start, _ = restore_checkpoint(ckpt_dir, tmpl)
        params = jax.tree.map(jnp.asarray, restored["params"])
        opt_state = jax.tree.map(jnp.asarray, restored["opt"])
        print(f"resumed from step {start}")

    t0 = time.time()
    losses = []
    for s in range(start, steps):
        params, opt_state, m = step_fn(params, opt_state, pipe.batch_at(s))
        losses.append(float(m["loss"]))
        if (s + 1) % log_every == 0:
            tok_s = batch * seq * log_every / (time.time() - t0)
            print(f"step {s+1:5d}  loss {np.mean(losses[-log_every:]):.4f}  "
                  f"acc {float(m['acc']):.3f}  gnorm {float(m['grad_norm']):.2f}  "
                  f"lr {float(m['lr']):.2e}  {tok_s:,.0f} tok/s", flush=True)
            t0 = time.time()
        if (s + 1) % ckpt_every == 0 or s + 1 == steps:
            mgr.save_async(s + 1, {"params": params, "opt": opt_state},
                           extra={"loss": float(m["loss"])})
    mgr.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-dir", default="data")
    ap.add_argument("--ckpt-dir", default="ckpt")
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs the production mesh)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          data_dir=args.data_dir, ckpt_dir=args.ckpt_dir,
          reduced=not args.full, resume=args.resume, lr=args.lr)


if __name__ == "__main__":
    main()
