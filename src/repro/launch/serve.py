"""Serving driver: batched prefill + greedy decode loop with KV/SSM caches."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import init_cache, init_params
from ..serve import greedy_sample, make_decode_step, make_prefill_step


def serve_batch(arch: str, prompts: np.ndarray, max_new: int = 16,
                reduced: bool = True, seed: int = 0):
    """prompts: (B, S) int32. Returns (B, max_new) generated tokens."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed), jnp.bfloat16)
    B, S = prompts.shape
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    if cfg.encoder is not None:
        batch["enc_embeds"] = jnp.zeros((B, cfg.encoder.enc_seq, cfg.d_model),
                                        jnp.bfloat16)
    if cfg.vlm is not None:
        batch["embeds"] = jnp.zeros((B, cfg.vlm.n_patches, cfg.d_model),
                                    jnp.bfloat16)
    logits, cache = prefill(params, batch)
    # move prefill cache into a max-length decode cache
    total = S + max_new + (cfg.vlm.n_patches if cfg.vlm is not None else 0)
    full = init_cache(cfg, B, total)

    def graft(dst, src):
        if dst.ndim >= 4 and dst.shape[-3] >= src.shape[-3] and dst.ndim == src.ndim \
                and dst.shape[:-3] == src.shape[:-3]:
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)
        return src.astype(dst.dtype)
    cache = jax.tree.map(graft, full, cache)

    tok = greedy_sample(logits)[:, None]
    out = [tok]
    pos = S + (cfg.vlm.n_patches if cfg.vlm is not None else 0)
    for i in range(max_new - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(pos + i))
        tok = greedy_sample(logits[:, 0])[:, None]
        out.append(tok)
    return np.concatenate([np.asarray(t) for t in out], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    toks = serve_batch(args.arch, prompts, max_new=args.max_new)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.1f}s "
          f"({toks.size / dt:.1f} tok/s incl. compile)")
    print(toks)


if __name__ == "__main__":
    main()
