"""SLURM launch-script generation: multi-pod training arrays (the paper's
job machinery pointed at TPU/TRN pods instead of MRI pipelines) and the
per-shard campaign arrays the admission-time planner emits.

One array task per host; each host joins the jax distributed runtime and runs
``launch/train.py`` with the production mesh. Burst-to-local fallback mirrors
the paper's §2.3 (same entrypoint, local mesh).
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

POD_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={name}
#SBATCH --array=0-{last_host}
#SBATCH --nodes=1
#SBATCH --cpus-per-task={cpus}
#SBATCH --time={walltime}
#SBATCH --output={log_dir}/%x_%a.out
set -euo pipefail

export JAX_COORDINATOR_ADDRESS={coordinator}
export JAX_NUM_PROCESSES={n_hosts}
export JAX_PROCESS_ID=$SLURM_ARRAY_TASK_ID

srun python -m repro.launch.train \\
    --arch {arch} --full --steps {steps} \\
    --data-dir {data_dir} --ckpt-dir {ckpt_dir} --resume
"""


# One campaign shard = one job array pinned (when the plan could place it)
# to the host already holding the shard's input bytes — brainlife.io-style
# job-to-data routing at the batch-system layer. The cold shard (no warm
# host anywhere) stays untargeted so SLURM places it wherever there is room.
SHARD_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={name}
#SBATCH --array=0-{last_idx}%{throttle}
#SBATCH --cpus-per-task={cpus}
#SBATCH --mem={mem_gb}G
#SBATCH --time={walltime}
#SBATCH --output={log_dir}/%x_%a.out
{placement_line}

set -euo pipefail
# allocator and XLA hygiene, resolved on the *compute* node (tcmalloc
# paths differ per host; LD_PRELOAD must be set before python starts) —
# fail-soft when the package is not importable there
eval "$(python -m repro.launch.env --role worker 2>/dev/null || true)"
MANIFEST={manifest_json}
python -m repro.core.workflow --run-one {units_json} --index $SLURM_ARRAY_TASK_ID \\
    --data-root {data_root} --scratch $SLURM_TMPDIR
"""


def write_shard_script(out_dir: Path, *, name: str, n_units: int,
                       units_json: str, manifest_json: str, data_root: str,
                       node_id: Optional[str] = None, throttle: int = 100,
                       cpus: int = 4, mem_gb: int = 16,
                       walltime: str = "24:00:00") -> Path:
    """Write one campaign shard's SLURM array script. ``node_id`` pins the
    array to the host whose cache already holds the shard's bytes; ``None``
    (the cold shard) leaves placement to the scheduler."""
    if n_units < 1:
        raise ValueError("a shard script needs at least one unit")
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    log_dir = out_dir / "logs"
    log_dir.mkdir(exist_ok=True)
    placement = (f"#SBATCH --nodelist={node_id}" if node_id
                 else "# cold shard: no warm host for these units; "
                      "scheduler places freely")
    script = SHARD_TEMPLATE.format(
        name=name, last_idx=n_units - 1, throttle=throttle, cpus=cpus,
        mem_gb=mem_gb, walltime=walltime, log_dir=str(log_dir),
        placement_line=placement, manifest_json=manifest_json,
        units_json=units_json, data_root=data_root)
    p = out_dir / f"{name}.slurm"
    p.write_text(script)
    return p


def write_pod_launch(out_dir: Path, *, arch: str, n_hosts: int = 64,
                     coordinator: str = "pod0-host0:8476", steps: int = 10000,
                     data_dir: str = "/data/shards", ckpt_dir: str = "/ckpt",
                     cpus: int = 16, walltime: str = "48:00:00") -> Path:
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    script = POD_TEMPLATE.format(
        name=f"train_{arch}", last_host=n_hosts - 1, n_hosts=n_hosts,
        coordinator=coordinator, arch=arch, steps=steps, data_dir=data_dir,
        ckpt_dir=ckpt_dir, cpus=cpus, walltime=walltime,
        log_dir=str(out_dir / "logs"))
    p = out_dir / f"train_{arch}.slurm"
    p.write_text(script)
    return p
