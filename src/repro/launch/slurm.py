"""SLURM launch-script generation for multi-pod training (the paper's job
machinery pointed at TPU/TRN pods instead of MRI pipelines).

One array task per host; each host joins the jax distributed runtime and runs
``launch/train.py`` with the production mesh. Burst-to-local fallback mirrors
the paper's §2.3 (same entrypoint, local mesh).
"""
from __future__ import annotations

from pathlib import Path

POD_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={name}
#SBATCH --array=0-{last_host}
#SBATCH --nodes=1
#SBATCH --cpus-per-task={cpus}
#SBATCH --time={walltime}
#SBATCH --output={log_dir}/%x_%a.out
set -euo pipefail

export JAX_COORDINATOR_ADDRESS={coordinator}
export JAX_NUM_PROCESSES={n_hosts}
export JAX_PROCESS_ID=$SLURM_ARRAY_TASK_ID

srun python -m repro.launch.train \\
    --arch {arch} --full --steps {steps} \\
    --data-dir {data_dir} --ckpt-dir {ckpt_dir} --resume
"""


def write_pod_launch(out_dir: Path, *, arch: str, n_hosts: int = 64,
                     coordinator: str = "pod0-host0:8476", steps: int = 10000,
                     data_dir: str = "/data/shards", ckpt_dir: str = "/ckpt",
                     cpus: int = 16, walltime: str = "48:00:00") -> Path:
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    script = POD_TEMPLATE.format(
        name=f"train_{arch}", last_host=n_hosts - 1, n_hosts=n_hosts,
        coordinator=coordinator, arch=arch, steps=steps, data_dir=data_dir,
        ckpt_dir=ckpt_dir, cpus=cpus, walltime=walltime,
        log_dir=str(out_dir / "logs"))
    p = out_dir / f"train_{arch}.slurm"
    p.write_text(script)
    return p
