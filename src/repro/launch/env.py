"""Allocator / XLA-flags environment profile for coordinator and worker
processes — the launch-script hygiene every serious JAX deployment carries
in its ``run.sh``, folded into the tree so ``python -m repro.dist.rpc
serve|work`` applies it without a wrapper script.

What the profile sets (and why):

* ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` — silence tcmalloc's
  large-allocation warnings for the multi-hundred-MB image volumes the
  pipelines routinely allocate; the reports are stderr noise at best and a
  per-allocation slowdown at worst.
* ``TF_CPP_MIN_LOG_LEVEL=4`` — mute the TF/XLA C++ banner and dataset
  warnings that otherwise swamp worker logs at fleet scale.
* ``XLA_FLAGS`` — ``--xla_force_host_platform_device_count=1``: control-
  plane and per-unit pipeline processes want one host device, not one per
  core (faster startup, no pointless intra-host sharding of tiny pipeline
  stages). Merged, never clobbered: flags the operator already set win.
* ``LD_PRELOAD`` → tcmalloc, when a known ``libtcmalloc`` exists on the
  host. A dynamic linker option can only take effect at process start, so
  :func:`apply_env_profile` exports it for *children* (the worker
  subprocesses a coordinator or launcher spawns) while
  :func:`format_exports` emits it for shell scripts that can set it before
  exec — the SLURM shard template evals the latter on the compute node.

Everything is fail-soft and override-safe: variables the process already
has keep their values, a missing tcmalloc just drops the preload, and
``REPRO_ENV_PROFILE=off`` disables the whole profile.
"""
from __future__ import annotations

import os
import shlex
from typing import Dict, Mapping, Optional

ENV_PROFILE_ENV = "REPRO_ENV_PROFILE"     # "off"/"0"/"none" disables

ROLES = ("coordinator", "worker")

# allocator + logging hygiene, identical for both roles
_COMMON = {
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
    "TF_CPP_MIN_LOG_LEVEL": "4",
}

# per-role XLA flags, merged into any operator-set XLA_FLAGS
_XLA_FLAGS = {
    "coordinator": ["--xla_force_host_platform_device_count=1"],
    "worker": ["--xla_force_host_platform_device_count=1"],
}

# well-known tcmalloc locations (Debian/Ubuntu full + minimal builds);
# first hit wins, no hit = no preload
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
)


def _find_tcmalloc() -> Optional[str]:
    for cand in TCMALLOC_CANDIDATES:
        if os.path.exists(cand):
            return cand
    return None


def _merge_xla_flags(existing: str, wanted) -> str:
    """Append each wanted flag unless a flag with the same ``--name`` is
    already present (operator settings win; repeated application is a
    no-op)."""
    parts = existing.split()
    have = {p.split("=", 1)[0] for p in parts}
    for flag in wanted:
        if flag.split("=", 1)[0] not in have:
            parts.append(flag)
    return " ".join(parts)


def env_profile(role: str = "worker",
                base: Optional[Mapping[str, str]] = None) -> Dict[str, str]:
    """The variables the profile would set for ``role``, given the current
    (or a supplied) environment — only the ones that change: anything the
    environment already pins is left out (except ``XLA_FLAGS``, which is
    returned merged when new flags are added)."""
    if role not in ROLES:
        raise ValueError(f"unknown role {role!r} (want one of {ROLES})")
    base = os.environ if base is None else base
    out: Dict[str, str] = {}
    for k, v in _COMMON.items():
        if k not in base:
            out[k] = v
    merged = _merge_xla_flags(base.get("XLA_FLAGS", ""), _XLA_FLAGS[role])
    if merged != base.get("XLA_FLAGS", ""):
        out["XLA_FLAGS"] = merged
    if "LD_PRELOAD" not in base:
        tcm = _find_tcmalloc()
        if tcm is not None:
            out["LD_PRELOAD"] = tcm
    return out


def _disabled() -> bool:
    return os.environ.get(ENV_PROFILE_ENV, "").lower() in ("off", "0", "none")


def apply_env_profile(role: str = "worker") -> Dict[str, str]:
    """Apply the profile to ``os.environ`` (call before importing jax — the
    flags are read at import). Returns what was set. ``LD_PRELOAD`` set
    here cannot re-link the *current* process (the dynamic linker already
    ran); it still reaches every child process, which is where workers and
    their pipelines run. No-op when ``REPRO_ENV_PROFILE`` disables it."""
    if _disabled():
        return {}
    prof = env_profile(role)
    os.environ.update(prof)
    return prof


def format_exports(role: str = "worker",
                   base: Optional[Mapping[str, str]] = None) -> str:
    """The profile as ``export K=V`` shell lines (values quoted) — for
    launch scripts, where ``LD_PRELOAD`` can take effect before the python
    process starts. Empty string when the profile is disabled."""
    if _disabled():
        return ""
    prof = env_profile(role, base=base)
    return "\n".join(f"export {k}={shlex.quote(v)}"
                     for k, v in sorted(prof.items()))


def _main():
    import argparse
    ap = argparse.ArgumentParser(
        description="print the repro env profile as shell export lines "
                    "(eval \"$(python -m repro.launch.env --role worker)\")")
    ap.add_argument("--role", default="worker", choices=ROLES)
    args = ap.parse_args()
    exports = format_exports(args.role)
    if exports:
        print(exports)


if __name__ == "__main__":
    _main()
