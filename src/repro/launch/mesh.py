"""Production mesh construction (v5e-like pods).

A function — not a module-level constant — so importing never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tp2d_mesh(*, multi_pod: bool = False):
    """Same chips, 'model' axis factored (8, 2): attention TP uses the 8-way
    sub-axis (KV=8 archs shard kv-heads exactly), expert/vocab TP uses the
    full 16 via ('model','model2'). §Perf L3 — for archs whose head counts
    cannot carry a 16-way axis (llama4: H=40, KV=8)."""
    shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
    axes = (("pod", "data", "model", "model2") if multi_pod
            else ("data", "model", "model2"))
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a 1x1xN 'model' mesh (tests/CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
