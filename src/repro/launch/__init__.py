__all__ = ["make_production_mesh", "make_local_mesh",
           "apply_env_profile", "env_profile", "format_exports"]


def __getattr__(name):
    # mesh pulls in jax; loaded lazily so the env profile (which must run
    # *before* the first jax import to land its XLA flags) can be imported
    # from this package without defeating itself. env is lazy too so
    # ``python -m repro.launch.env`` doesn't trip runpy's
    # found-in-sys.modules warning.
    if name in ("make_production_mesh", "make_local_mesh"):
        from . import mesh
        return getattr(mesh, name)
    if name in ("apply_env_profile", "env_profile", "format_exports"):
        from . import env
        return getattr(env, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
