"""Oracle: the model stack's own rmsnorm."""
from ...models.layers import rmsnorm as rmsnorm_ref  # noqa: F401
