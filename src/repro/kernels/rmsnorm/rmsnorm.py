"""Fused RMSNorm Pallas kernel: one VMEM pass computes the f32 moment and the
scaled output (XLA emits separate reduce + broadcast-multiply passes)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    # mirrors models/layers.rmsnorm: f32 moment accumulation, compute-dtype
    # multiplies (no materialized f32 copy of x)
    x = x_ref[...]                                      # (blk, D)
    var = jnp.sum(x.astype(jnp.float32) * x.astype(jnp.float32),
                  axis=-1, keepdims=True) / x.shape[-1]
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    o_ref[...] = ((x * r) * scale_ref[...].astype(x.dtype)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "blk", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, blk: int = 256,
            interpret: bool = False):
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    blk = min(blk, R)
    pad = (-R) % blk
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xf.shape[0] // blk,),
        in_specs=[pl.BlockSpec((blk, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
