from .rmsnorm import rmsnorm as rmsnorm_op  # noqa: F401
