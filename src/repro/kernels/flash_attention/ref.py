"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B, H, Sq, Dh); k, v: (B, KV, Sk, Dh)."""
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(Dh)
    rows = jnp.arange(Sq)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= rows >= cols
    if window is not None:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)
