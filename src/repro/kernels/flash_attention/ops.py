"""Jitted public wrapper: (B, S, H, Dh) layout in, kernel layout inside."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


def flash_attention_op(q, k, v, *, causal=True, window=None, interpret=False):
    """q: (B, S, H, Dh); k, v: (B, S, KV, Dh) — model-layer layout."""
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          interpret=interpret)
    return jnp.transpose(out, (0, 2, 1, 3))
