"""Flash attention (forward) as a Pallas TPU kernel.

Canonical TPU pattern: grid (B, H, nQ, nKV) with the KV dimension innermost
(sequential on TPU), online-softmax running max/denominator/accumulator in
VMEM scratch, written out on the last KV block. GQA is handled in the
BlockSpec index maps (q head h reads kv head h // G) — K/V are never
repeated. Blocks are MXU-aligned (multiples of 128 on the matmul dims).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, blk_q: int, blk_k: int,
                  seq_k: int):
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)
        acc_ref[:] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (blk_q, dh)
    k = k_ref[0, 0].astype(jnp.float32)            # (blk_k, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    iq = pl.program_id(2)
    rows = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    cols = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = cols < seq_k
    if causal:
        mask &= rows >= cols
    if window is not None:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:]                              # (blk_q,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[:] = l_ref[:] * alpha + jnp.sum(p, axis=1)
    acc_ref[:] = acc_ref[:] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[:] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:], 1e-30)
        o_ref[0, 0] = (acc_ref[:] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, Sq, Dh); k, v: (B, KV, Sk, Dh). Returns (B, H, Sq, Dh)."""
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    pad_q = (-Sq) % blk_q
    pad_k = (-Sk) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = q.shape[2] // blk_q
    n_k = k.shape[2] // blk_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, Dh), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, Dh), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, Dh), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq] if pad_q else out
