"""Oracle: exact sequential SSD recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, lw, Bm, Cm):
    """x: (B,H,S,dh) dt-weighted; lw: (B,H,S); Bm,Cm: (B,S,N).
        S_t = a_t S_{t-1} + x_t B_t^T ;  y_t = S_t C_t   (a_t = exp(lw_t))
    """
    B, H, S, dh = x.shape
    N = Bm.shape[-1]
    x32 = x.astype(jnp.float32)
    a = jnp.exp(lw.astype(jnp.float32))
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(S_, t):
        upd = jnp.einsum("bhd,bn->bhdn", x32[:, :, t], B32[:, t])
        S_ = a[:, :, t][..., None, None] * S_ + upd
        y = jnp.einsum("bhdn,bn->bhd", S_, C32[:, t])
        return S_, y

    S0 = jnp.zeros((B, H, dh, N), jnp.float32)
    _, ys = jax.lax.scan(step, S0, jnp.arange(S))
    return ys.transpose(1, 2, 0, 3)
