"""Mamba-2 SSD chunk scan as a Pallas TPU kernel.

Grid (B, H, n_chunks), chunk innermost (sequential); the (dh x N) SSM state is
VMEM scratch carried across chunks. Per chunk (matching
``models/mamba2.ssd_chunked``):

    Lmat = exp(segsum(lw))                    (T, T) lower-triangular decay
    y    = (C B^T ∘ Lmat) (dt x)  +  C S0^T decayed
    S'   = exp(cum_T) S0 + sum_s exp(cum_T - cum_s) (dt x)_s B_s^T

All matmuls are (T,T)x(T,dh) / (T,N)-shaped — MXU-aligned for T=128+, N=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, lw_ref, b_ref, c_ref, o_ref, state_ref):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xb = x_ref[0, 0].astype(jnp.float32)        # (T, dh) — already dt-weighted
    lw = lw_ref[0, 0].astype(jnp.float32)       # (T,) log-decay, <= 0
    Bm = b_ref[0].astype(jnp.float32)           # (T, N)
    Cm = c_ref[0].astype(jnp.float32)           # (T, N)
    S0 = state_ref[...]                         # (dh, N)

    T = xb.shape[0]
    cum = jnp.cumsum(lw)                        # (T,)
    seg = cum[:, None] - cum[None, :]           # cum_t - cum_s
    tri = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))     # (T, T)
    y = jax.lax.dot_general(CB * Lmat, xb, (((1,), (0,)), ((), ())))
    # inter-chunk: y_t += exp(cum_t) C_t @ S0^T
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, S0, (((1,), (1,)), ((), ())))
    o_ref[0, 0] = y.astype(o_ref.dtype)
    # state update
    w = jnp.exp(cum[-1] - cum)                  # (T,)
    state_ref[...] = jnp.exp(cum[-1]) * S0 + jax.lax.dot_general(
        xb * w[:, None], Bm, (((0,), (0,)), ((), ())))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, lw, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x: (B, H, S, dh) dt-weighted inputs; lw: (B, H, S) log-decay;
    Bm, Cm: (B, S, N). Returns y (B, H, S, dh) f32."""
    B, H, S, dh = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[2] // chunk
    out = pl.pallas_call(
        _ssd_kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((dh, N), jnp.float32)],
        interpret=interpret,
    )(x, lw, Bm, Cm)
    return out[:, :, :S] if pad else out
