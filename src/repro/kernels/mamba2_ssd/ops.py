from .mamba2_ssd import ssd_chunked as ssd_op  # noqa: F401
