"""Numpy oracle — bit-exact with the kernel."""
from __future__ import annotations

import numpy as np

M_POS = 65521


def device_checksum_ref(x: np.ndarray) -> np.ndarray:
    b = np.ascontiguousarray(x).tobytes()
    pad = (-len(b)) % 4
    if pad:
        b += b"\0" * pad
    words = np.frombuffer(b, "<u4").astype(np.uint32)
    idx = (np.arange(words.size, dtype=np.uint64) % M_POS).astype(np.uint32)
    s1 = np.uint32(0)
    s2 = np.uint32(0)
    with np.errstate(over="ignore"):
        s1 = np.sum(words, dtype=np.uint32)
        s2 = np.sum(words * idx, dtype=np.uint32)
    return np.array([s1, s2], dtype=np.uint32).view(np.int32)
