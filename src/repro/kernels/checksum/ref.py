"""Numpy oracle — bit-exact with the kernels.

Float reductions are the subtle part: to make the fused QA sum bit-exact
between the Pallas kernel and this oracle, both sides accumulate with the
SAME fixed reduction tree — a power-of-two halving tree inside each block
(elementwise IEEE f32 adds, no library reassociation), then a sequential
scalar add across blocks. Padding, masking, and block sizes are shared via
:func:`qa_block_size`; keep any change mirrored in ``checksum.py``.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

M_POS = 65521


def device_checksum_ref(x: np.ndarray) -> np.ndarray:
    b = np.ascontiguousarray(x).tobytes()
    pad = (-len(b)) % 4
    if pad:
        b += b"\0" * pad
    words = np.frombuffer(b, "<u4").astype(np.uint32)
    idx = (np.arange(words.size, dtype=np.uint64) % M_POS).astype(np.uint32)
    s1 = np.uint32(0)
    s2 = np.uint32(0)
    with np.errstate(over="ignore"):
        s1 = np.sum(words, dtype=np.uint32)
        s2 = np.sum(words * idx, dtype=np.uint32)
    return np.array([s1, s2], dtype=np.uint32).view(np.int32)


# ---------------------------------------------------------------------------
# fused QA + checksum oracle
# ---------------------------------------------------------------------------

def qa_block_size(n_vals: int, itemsize: int, blk: int = 1024) -> int:
    """Value-block size shared by kernel and oracle: a power of two whose
    byte extent is word-aligned, shrunk toward small inputs."""
    blk = 1 << (int(blk).bit_length() - 1)           # floor to power of two
    min_blk = max(8, 4 // itemsize)                  # word alignment floor
    while blk // 2 >= max(n_vals, min_blk) and (blk // 2) * itemsize % 4 == 0:
        blk //= 2
    while blk * itemsize % 4:                        # itemsize 1/2: stay aligned
        blk *= 2
    return max(blk, min_blk)


def tree_sum_f32(v: np.ndarray) -> np.float32:
    """Fixed power-of-two halving-tree sum (elementwise IEEE f32 adds).
    The kernel runs the identical tree in jnp — bit-exact by construction."""
    v = v.astype(np.float32, copy=True)
    n = v.shape[-1]
    while n > 1:
        n //= 2
        v = v[..., :n] + v[..., n:2 * n]
    return v[..., 0]


def _pack_words_ref(row_bytes: bytes) -> np.ndarray:
    pad = (-len(row_bytes)) % 4
    if pad:
        row_bytes += b"\0" * pad
    return np.frombuffer(row_bytes, "<u4").astype(np.uint32)


def qa_checksum_batched_ref(x: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Oracle for the batched fused kernel. ``x``: (G, ...) — each leading-dim
    slice is one volume of a shape bucket. Returns
    ``(sums int32 (G,2), qa float32 (G,3) = [min, max, sum], cnt int32 (G,1))``
    with min/max/sum over finite values only (min=+inf/max=-inf when none)."""
    x = np.ascontiguousarray(x)
    G = x.shape[0]
    vals = x.reshape(G, -1)
    nv = vals.shape[1]
    blk_v = qa_block_size(nv, x.dtype.itemsize)
    blk_w = blk_v * x.dtype.itemsize // 4

    sums = np.zeros((G, 2), np.uint32)
    qa = np.zeros((G, 3), np.float32)
    cnt = np.zeros((G, 1), np.int32)
    for g in range(G):
        row = vals[g]
        words = _pack_words_ref(row.tobytes())
        nw = words.size
        nsteps = max(-(-nw // blk_w), -(-nv // blk_v), 1)
        wpad = np.zeros(nsteps * blk_w, np.uint32)
        wpad[:nw] = words
        v = row.astype(np.float32)
        vpad = np.zeros(nsteps * blk_v, np.float32)
        vpad[:nv] = v
        s1 = np.uint32(0)
        s2 = np.uint32(0)
        vmin = np.float32(np.inf)
        vmax = np.float32(-np.inf)
        vsum = np.float32(0.0)
        n_fin = np.int32(0)
        with np.errstate(over="ignore"):
            for i in range(nsteps):
                w = wpad[i * blk_w:(i + 1) * blk_w]
                idx = np.arange(i * blk_w, (i + 1) * blk_w, dtype=np.int64)
                pos = np.where(idx < nw, (idx % M_POS).astype(np.uint32),
                               np.uint32(0))
                s1 = np.uint32(s1 + np.sum(w, dtype=np.uint32))
                s2 = np.uint32(s2 + np.sum(w * pos, dtype=np.uint32))
                vb = vpad[i * blk_v:(i + 1) * blk_v]
                vidx = np.arange(i * blk_v, (i + 1) * blk_v)
                finite = np.isfinite(vb) & (vidx < nv)
                n_fin = np.int32(n_fin + np.int32(np.sum(finite)))
                vmin = np.minimum(vmin, np.min(np.where(finite, vb, np.inf)))
                vmax = np.maximum(vmax, np.max(np.where(finite, vb, -np.inf)))
                vsum = np.float32(vsum + tree_sum_f32(np.where(finite, vb, 0.0)))
        sums[g] = (s1, s2)
        qa[g] = (vmin, vmax, vsum)
        cnt[g] = n_fin
    return sums.view(np.int32), qa, cnt


def qa_checksum_ref(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unbatched oracle: (int32[2], float32[3], int32[1])."""
    sums, qa, cnt = qa_checksum_batched_ref(
        np.ascontiguousarray(x).reshape(1, -1))
    return sums[0], qa[0], cnt[0]
