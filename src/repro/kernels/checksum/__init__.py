from .checksum import device_checksum
from .ref import device_checksum_ref

__all__ = ["device_checksum", "device_checksum_ref"]
