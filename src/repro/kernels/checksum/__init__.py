from .checksum import (ACCUMULATOR_DTYPES, QAChecksumAccumulator, QAStats,
                       device_checksum, qa_checksum, qa_checksum_batched,
                       qa_checksum_chunk, qa_stats)
from .ref import (device_checksum_ref, qa_checksum_ref,
                  qa_checksum_batched_ref)

__all__ = ["ACCUMULATOR_DTYPES", "QAChecksumAccumulator", "QAStats",
           "device_checksum", "device_checksum_ref",
           "qa_checksum", "qa_checksum_ref", "qa_checksum_batched",
           "qa_checksum_batched_ref", "qa_checksum_chunk", "qa_stats"]
