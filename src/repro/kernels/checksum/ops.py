from .checksum import device_checksum as device_checksum_op  # noqa: F401
from .checksum import qa_checksum as qa_checksum_op  # noqa: F401
from .checksum import qa_checksum_batched as qa_checksum_batched_op  # noqa: F401
from .checksum import qa_checksum_chunk as qa_checksum_chunk_op  # noqa: F401
