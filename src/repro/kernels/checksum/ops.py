from .checksum import device_checksum as device_checksum_op  # noqa: F401
