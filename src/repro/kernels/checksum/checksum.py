"""On-device data-integrity checksum + fused QA statistics (paper §2.3/§2.1).

The paper checksums every storage<->compute transfer on the host, and runs a
fast visual-QA pass over every ingested volume. Both are single-read
reductions over the same bytes, so we fuse them: ONE device pass over a
volume emits

    s1 = sum_i w_i            (mod 2^32, int32 wrap-around)      \\ transfer
    s2 = sum_i (i mod M) w_i  (mod 2^32),  M = 65521             /  checksum
    min, max, sum             over finite float values            \\ fast QA
    finite_count                                                  /

replacing ~5 separate numpy passes (isfinite, std, mean, checksum, ...) in
``core.ingest._fast_qa`` with a single ``pallas_call``. A batched variant
grids over the leading dim so a whole shape-bucket of volumes is verified in
one launch. ``ref.py`` defines the identical functions in numpy; kernel and
oracle agree bit-exactly — float sums use a fixed power-of-two halving tree
on both sides (elementwise IEEE adds, no reassociation), integer checksums
wrap mod 2^32.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import M_POS, qa_block_size, tree_sum_f32


def _auto_interpret(interpret):
    """Pallas kernels compile only on TPU; elsewhere run interpreted."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ---------------------------------------------------------------------------
# plain checksum (kept: the transfer-only fast path)
# ---------------------------------------------------------------------------

def _checksum_kernel(x_ref, o_ref, *, blk: int, n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = x_ref[...]                                     # (blk,) int32 words
    idx = i * blk + jax.lax.iota(jnp.int32, blk)
    valid = idx < n
    w = jnp.where(valid, w, 0)
    pos = jnp.where(valid, idx % M_POS, 0)
    s1 = jnp.sum(w)                                    # int32 wrap-around
    s2 = jnp.sum(w * pos)
    o_ref[0] = o_ref[0] + s1
    o_ref[1] = o_ref[1] + s2


def _to_words(x) -> jnp.ndarray:
    """Little-endian int32 word view of an array's bytes (zero-padded)."""
    if x.dtype.itemsize == 4:
        return jax.lax.bitcast_convert_type(x.reshape(-1), jnp.int32)
    b = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1)
    pad = (-b.size) % 4
    if pad:
        b = jnp.concatenate([b, jnp.zeros(pad, jnp.uint8)])
    quads = b.reshape(-1, 4).astype(jnp.int32) & 0xFF
    return (quads[:, 0] | (quads[:, 1] << 8) | (quads[:, 2] << 16)
            | (quads[:, 3] << 24))


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def device_checksum(x, *, blk: int = 1024, interpret: bool = False):
    """x: any array. Returns int32[2] = (s1, s2) over its uint32 word view."""
    words = _to_words(x).reshape(-1)
    n = words.size
    blk = min(blk, max(n, 1))
    pad = (-n) % blk
    if pad:
        words = jnp.concatenate([words, jnp.zeros(pad, jnp.int32)])
    return pl.pallas_call(
        functools.partial(_checksum_kernel, blk=blk, n=n),
        grid=(words.size // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
        interpret=interpret,
    )(words)


# ---------------------------------------------------------------------------
# fused QA + checksum
# ---------------------------------------------------------------------------

def _tree_sum_f32(v):
    """Fixed halving-tree f32 sum; mirrors ``ref.tree_sum_f32`` bit-exactly."""
    n = v.shape[0]
    while n > 1:
        n //= 2
        v = v[:n] + v[n:2 * n]
    return v[0]


def _qa_checksum_kernel(w_ref, v_ref, sums_ref, qa_ref, cnt_ref, *,
                        blk_w: int, blk_v: int, nw: int, nv: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        qa_ref[0, 0] = jnp.float32(jnp.inf)
        qa_ref[0, 1] = jnp.float32(-jnp.inf)
        qa_ref[0, 2] = jnp.float32(0.0)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    # checksum over the word view
    w = w_ref[0, :]
    idx = i * blk_w + jax.lax.iota(jnp.int32, blk_w)
    valid = idx < nw
    w = jnp.where(valid, w, 0)
    pos = jnp.where(valid, idx % M_POS, 0)
    sums_ref[0, 0] = sums_ref[0, 0] + jnp.sum(w)
    sums_ref[0, 1] = sums_ref[0, 1] + jnp.sum(w * pos)

    # QA over the float value view (finite values only)
    v = v_ref[0, :].astype(jnp.float32)
    vidx = i * blk_v + jax.lax.iota(jnp.int32, blk_v)
    finite = jnp.isfinite(v) & (vidx < nv)
    cnt_ref[0, 0] = cnt_ref[0, 0] + jnp.sum(finite.astype(jnp.int32))
    qa_ref[0, 0] = jnp.minimum(qa_ref[0, 0],
                               jnp.min(jnp.where(finite, v, jnp.inf)))
    qa_ref[0, 1] = jnp.maximum(qa_ref[0, 1],
                               jnp.max(jnp.where(finite, v, -jnp.inf)))
    qa_ref[0, 2] = qa_ref[0, 2] + _tree_sum_f32(jnp.where(finite, v, 0.0))


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def _qa_checksum_2d(vals, *, blk: int, interpret: bool):
    """Core batched op. vals: (G, nv) in the original dtype. Returns
    (sums int32 (G,2), qa f32 (G,3), cnt int32 (G,1))."""
    G, nv = vals.shape
    itemsize = vals.dtype.itemsize
    blk_v = qa_block_size(nv, itemsize, blk)
    blk_w = blk_v * itemsize // 4
    # pad each ROW's byte extent to a word boundary before packing, so words
    # never straddle volume boundaries (matches the per-row oracle padding)
    row_pad = 0
    while (nv + row_pad) * itemsize % 4:
        row_pad += 1
    wvals = vals
    if row_pad:
        wvals = jnp.concatenate(
            [vals, jnp.zeros((G, row_pad), vals.dtype)], axis=1)
    words = _to_words(wvals).reshape(G, -1)
    nw = words.shape[1]
    nsteps = max(-(-nw // blk_w), -(-nv // blk_v), 1)
    wpad = nsteps * blk_w - nw
    if wpad:
        words = jnp.concatenate(
            [words, jnp.zeros((G, wpad), jnp.int32)], axis=1)
    vpad = nsteps * blk_v - nv
    if vpad:
        vals = jnp.concatenate(
            [vals, jnp.zeros((G, vpad), vals.dtype)], axis=1)
    return pl.pallas_call(
        functools.partial(_qa_checksum_kernel, blk_w=blk_w, blk_v=blk_v,
                          nw=nw, nv=nv),
        grid=(G, nsteps),
        in_specs=[pl.BlockSpec((1, blk_w), lambda g, i: (g, i)),
                  pl.BlockSpec((1, blk_v), lambda g, i: (g, i))],
        out_specs=(pl.BlockSpec((1, 2), lambda g, i: (g, 0)),
                   pl.BlockSpec((1, 3), lambda g, i: (g, 0)),
                   pl.BlockSpec((1, 1), lambda g, i: (g, 0))),
        out_shape=(jax.ShapeDtypeStruct((G, 2), jnp.int32),
                   jax.ShapeDtypeStruct((G, 3), jnp.float32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32)),
        interpret=interpret,
    )(words, vals)


def qa_checksum_batched(x, *, blk: int = 1024, interpret=None):
    """Fused QA+checksum over a shape-bucket: ``x`` is (N, ...) — N volumes
    verified in ONE ``pallas_call`` (grid over the leading dim). Returns
    (int32 (N,2) checksums, float32 (N,3) [min,max,sum], int32 (N,1) counts).
    """
    x = jnp.asarray(x)
    return _qa_checksum_2d(x.reshape(x.shape[0], -1), blk=blk,
                           interpret=_auto_interpret(interpret))


def qa_checksum(x, *, blk: int = 1024, interpret=None):
    """Unbatched fused QA+checksum: one device pass over ``x`` emitting
    ``(s1, s2)``, ``(min, max, sum)`` over finite values, and finite_count.
    Returns (int32[2], float32[3], int32[1]); see :func:`qa_stats` for a
    friendly view."""
    x = jnp.asarray(x)
    sums, qa, cnt = _qa_checksum_2d(x.reshape(1, -1), blk=blk,
                                    interpret=_auto_interpret(interpret))
    return sums[0], qa[0], cnt[0]


# ---------------------------------------------------------------------------
# chunk-accumulating variant (streaming ingest, repro.core.stream)
# ---------------------------------------------------------------------------
# The one-shot kernel above wants the whole volume resident before it can
# launch — which is exactly the host-side pass the streaming ingest path
# exists to kill. This variant folds the SAME per-block reduction over
# arbitrary byte chunks as they arrive off the wire: each launch initialises
# its outputs from the previous launch's (s1, s2, min, max, sum,
# finite_count) carry and advances global word/value offsets, so the
# arithmetic executed across all launches is operation-for-operation the
# one-shot kernel's sequence — bit-exact by construction, for any chunking
# (the accumulator below re-buffers to block alignment so callers may feed
# arbitrary chunk sizes, including one chunk bigger than the volume).


def _qa_chunk_kernel(w_ref, v_ref, off_ref, cs_ref, cqa_ref, ccnt_ref,
                     sums_ref, qa_ref, cnt_ref, *, blk_w: int, blk_v: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = cs_ref[...]
        qa_ref[...] = cqa_ref[...]
        cnt_ref[...] = ccnt_ref[...]

    # checksum over the word view at the chunk's global word offset
    w = w_ref[...]
    idx = off_ref[0] + i * blk_w + jax.lax.iota(jnp.int32, blk_w)
    valid = idx < off_ref[2]
    w = jnp.where(valid, w, 0)
    pos = jnp.where(valid, idx % M_POS, 0)
    sums_ref[0] = sums_ref[0] + jnp.sum(w)
    sums_ref[1] = sums_ref[1] + jnp.sum(w * pos)

    # QA over the value view at the chunk's global value offset
    v = v_ref[...].astype(jnp.float32)
    vidx = off_ref[1] + i * blk_v + jax.lax.iota(jnp.int32, blk_v)
    finite = jnp.isfinite(v) & (vidx < off_ref[3])
    cnt_ref[0] = cnt_ref[0] + jnp.sum(finite.astype(jnp.int32))
    qa_ref[0] = jnp.minimum(qa_ref[0],
                            jnp.min(jnp.where(finite, v, jnp.inf)))
    qa_ref[1] = jnp.maximum(qa_ref[1],
                            jnp.max(jnp.where(finite, v, -jnp.inf)))
    qa_ref[2] = qa_ref[2] + _tree_sum_f32(jnp.where(finite, v, 0.0))


@functools.partial(jax.jit,
                   static_argnames=("blk_w", "blk_v", "nsteps", "interpret"))
def _qa_chunk_call(words, vals, off, carry_sums, carry_qa, carry_cnt, *,
                   blk_w: int, blk_v: int, nsteps: int, interpret: bool):
    return pl.pallas_call(
        functools.partial(_qa_chunk_kernel, blk_w=blk_w, blk_v=blk_v),
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((blk_w,), lambda i: (i,)),
                  pl.BlockSpec((blk_v,), lambda i: (i,)),
                  pl.BlockSpec((4,), lambda i: (0,)),
                  pl.BlockSpec((2,), lambda i: (0,)),
                  pl.BlockSpec((3,), lambda i: (0,)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=(pl.BlockSpec((2,), lambda i: (0,)),
                   pl.BlockSpec((3,), lambda i: (0,)),
                   pl.BlockSpec((1,), lambda i: (0,))),
        out_shape=(jax.ShapeDtypeStruct((2,), jnp.int32),
                   jax.ShapeDtypeStruct((3,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)),
        interpret=interpret,
    )(words, vals, off, carry_sums, carry_qa, carry_cnt)


def qa_checksum_chunk(words, vals, off, carry, *, blk_w: int, blk_v: int,
                      interpret=None):
    """One chunk launch of the accumulating kernel: fold ``nsteps`` blocks of
    (``words``, ``vals``) — already block-padded — into ``carry``
    (``(sums int32[2], qa f32[3], cnt int32[1])``). ``off`` is
    ``int32[4] = (word_offset, value_offset, total_words, total_values)``;
    offsets are traced (not static) so a fixed chunk size compiles once.
    Returns the new carry."""
    nsteps = max(words.shape[0] // blk_w, 1)
    return _qa_chunk_call(words, vals, off, *carry, blk_w=blk_w, blk_v=blk_v,
                          nsteps=nsteps, interpret=_auto_interpret(interpret))


# dtypes both backends fold identically: little-endian native numerics that
# jnp.asarray round-trips losslessly (f64/i64 would silently downcast under
# default-x64-off jax, so they are excluded rather than wrong)
ACCUMULATOR_DTYPES = ("float16", "float32", "int8", "uint8", "int16",
                      "uint16", "int32", "uint32")


class QAChecksumAccumulator:
    """Fold one logical array's bytes through the fused QA+checksum pass,
    chunk by chunk, bit-exact with one-shot :func:`qa_stats` on the whole
    array.

    Feed arbitrary byte chunks via :meth:`update` (internal re-buffering
    aligns launches to the shared kernel/oracle block size, so chunk
    boundaries never have to respect it) and call :meth:`finalize` when the
    last byte is in — the :class:`QAStats` verdict is available the moment
    the transfer completes, with no second pass over the bytes.

    ``backend="device"`` launches the Pallas chunk kernel per fold (each
    :meth:`update` stages its chunk host→device and dispatches
    asynchronously; only :meth:`finalize` blocks). ``backend="host"`` runs a
    vectorized numpy fold with the identical block tree — bit-exact with the
    kernel — for hosts without an accelerator. The default picks ``device``
    on TPU and ``host`` elsewhere (interpret-mode Pallas is for tests, not
    data-plane throughput).
    """

    def __init__(self, n_vals: int, dtype, *, blk: int = 1024,
                 interpret=None, backend: str = "auto"):
        self.dtype = np.dtype(dtype)
        if self.dtype.name not in ACCUMULATOR_DTYPES:
            raise ValueError(
                f"unsupported streaming-QA dtype {self.dtype} "
                f"(supported: {', '.join(ACCUMULATOR_DTYPES)})")
        if n_vals < 0:
            raise ValueError(f"negative n_vals {n_vals}")
        self.n_vals = int(n_vals)
        self.itemsize = self.dtype.itemsize
        self.blk_v = qa_block_size(self.n_vals, self.itemsize, blk)
        self.blk_w = self.blk_v * self.itemsize // 4
        self.align_bytes = self.blk_v * self.itemsize
        self.nw = (self.n_vals * self.itemsize + 3) // 4
        self.total_blocks = max(-(-self.n_vals // self.blk_v), 1)
        if backend == "auto":
            backend = "device" if jax.default_backend() == "tpu" else "host"
        if backend not in ("device", "host"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.interpret = interpret
        self.device_seconds = 0.0      # staging + fold dispatch + final sync
        self._buf = bytearray()
        self._blocks_done = 0
        self._bytes_seen = 0
        self._stats: Optional[QAStats] = None
        if backend == "device":
            self._carry = (jnp.zeros(2, jnp.int32),
                           jnp.asarray([jnp.inf, -jnp.inf, 0.0], jnp.float32),
                           jnp.zeros(1, jnp.int32))
        else:
            self._s1 = np.uint32(0)
            self._s2 = np.uint32(0)
            self._vmin = np.float32(np.inf)
            self._vmax = np.float32(-np.inf)
            self._vsum = np.float32(0.0)
            self._cnt = 0

    # -- per-launch plumbing -------------------------------------------------

    def _chunk_arrays(self, chunk: bytes, nblocks: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Block-pad one aligned chunk into (words, vals) launch operands —
        the same zero-pad + mask discipline as the one-shot kernel, applied
        at the chunk's global offset instead of index 0."""
        vals = np.frombuffer(chunk, dtype=self.dtype)
        want_v = nblocks * self.blk_v
        if vals.size < want_v:
            vals = np.concatenate(
                [vals, np.zeros(want_v - vals.size, self.dtype)])
        wpad = (-len(chunk)) % 4
        words = np.frombuffer(bytes(chunk) + b"\0" * wpad, "<u4")
        want_w = nblocks * self.blk_w
        if words.size < want_w:
            words = np.concatenate(
                [words, np.zeros(want_w - words.size, np.uint32)])
        return words.view(np.int32), vals

    def _fold_device(self, words: np.ndarray, vals: np.ndarray, w0: int,
                     v0: int):
        off = np.array([w0, v0, self.nw, self.n_vals], np.int32)
        t0 = time.perf_counter()
        self._carry = qa_checksum_chunk(
            jnp.asarray(words), jnp.asarray(vals), jnp.asarray(off),
            self._carry, blk_w=self.blk_w, blk_v=self.blk_v,
            interpret=self.interpret)
        self.device_seconds += time.perf_counter() - t0

    def _fold_host(self, words: np.ndarray, vals: np.ndarray, w0: int,
                   v0: int):
        """Vectorized numpy twin of the chunk kernel. Integer checksums are
        associative mod 2^32, so whole-chunk sums match the kernel's
        per-block folds bit-for-bit; the float sum keeps the kernel's exact
        shape — per-block halving tree, then one sequential scalar add per
        block in order."""
        t0 = time.perf_counter()
        w = words.view(np.uint32)
        idx = w0 + np.arange(w.size, dtype=np.int64)
        valid_w = idx < self.nw
        with np.errstate(over="ignore"):
            w = np.where(valid_w, w, np.uint32(0))
            pos = np.where(valid_w, (idx % M_POS).astype(np.uint32),
                           np.uint32(0))
            self._s1 = np.uint32(self._s1 + np.sum(w, dtype=np.uint32))
            self._s2 = np.uint32(self._s2 + np.sum(w * pos, dtype=np.uint32))
        nblocks = vals.size // self.blk_v
        v = vals.astype(np.float32).reshape(nblocks, self.blk_v)
        vidx = (v0 + np.arange(vals.size)).reshape(nblocks, self.blk_v)
        finite = np.isfinite(v) & (vidx < self.n_vals)
        self._cnt += int(np.sum(finite))
        self._vmin = np.minimum(self._vmin,
                                np.float32(np.min(np.where(finite, v, np.inf))))
        self._vmax = np.maximum(self._vmax,
                                np.float32(np.max(np.where(finite, v,
                                                           -np.inf))))
        for t in tree_sum_f32(np.where(finite, v, np.float32(0.0))):
            self._vsum = np.float32(self._vsum + t)
        self.device_seconds += time.perf_counter() - t0

    def _process(self, chunk: bytes, nblocks: int):
        words, vals = self._chunk_arrays(chunk, nblocks)
        w0 = self._blocks_done * self.blk_w
        v0 = self._blocks_done * self.blk_v
        if self.backend == "device":
            self._fold_device(words, vals, w0, v0)
        else:
            self._fold_host(words, vals, w0, v0)
        self._blocks_done += nblocks

    # -- public surface ------------------------------------------------------

    def update(self, data: bytes):
        """Fold the next ``data`` bytes of the array's buffer. Whole blocks
        launch immediately (async on device); a sub-block tail is carried to
        the next update/finalize."""
        if self._stats is not None:
            raise RuntimeError("accumulator already finalized")
        self._bytes_seen += len(data)
        if self._bytes_seen > self.n_vals * self.itemsize:
            raise ValueError(
                f"stream overrun: fed {self._bytes_seen} bytes for a "
                f"{self.n_vals * self.itemsize}-byte array")
        self._buf += data
        nblocks = len(self._buf) // self.align_bytes
        if nblocks:
            cut = nblocks * self.align_bytes
            self._process(bytes(self._buf[:cut]), nblocks)
            del self._buf[:cut]

    def finalize(self) -> QAStats:
        """Fold the carried tail (zero-padded + masked exactly like the
        one-shot kernel's final block) and return the whole-array
        :class:`QAStats`. Raises ``ValueError`` if the byte count fed does
        not match the declared array size — a truncated transfer must fail
        verification, not silently pass QA on a prefix."""
        if self._stats is not None:
            return self._stats
        if self._bytes_seen != self.n_vals * self.itemsize:
            raise ValueError(
                f"stream truncated: fed {self._bytes_seen} of "
                f"{self.n_vals * self.itemsize} bytes")
        remaining = self.total_blocks - self._blocks_done
        if remaining:
            self._process(bytes(self._buf), remaining)
            self._buf.clear()
        if self.backend == "device":
            t0 = time.perf_counter()
            sums = np.asarray(self._carry[0]).view(np.uint32)
            qa = np.asarray(self._carry[1])
            cnt = int(np.asarray(self._carry[2])[0])
            self.device_seconds += time.perf_counter() - t0
            self._stats = QAStats(int(sums[0]), int(sums[1]), float(qa[0]),
                                  float(qa[1]), float(qa[2]), cnt)
        else:
            self._stats = QAStats(int(self._s1), int(self._s2),
                                  float(self._vmin), float(self._vmax),
                                  float(self._vsum), self._cnt)
        return self._stats


@dataclasses.dataclass(frozen=True)
class QAStats:
    """Host-side view of one volume's fused QA+checksum pass."""
    s1: int
    s2: int
    vmin: float
    vmax: float
    vsum: float
    finite_count: int

    @property
    def checksum(self) -> int:
        return ((self.s2 & 0xFFFFFFFF) << 32) | (self.s1 & 0xFFFFFFFF)


def qa_stats(x, *, blk: int = 1024, interpret=None) -> QAStats:
    """Run :func:`qa_checksum` and pull the scalars to the host."""
    import numpy as np
    sums, qa, cnt = qa_checksum(x, blk=blk, interpret=interpret)
    sums = np.asarray(sums).view(np.uint32)
    qa = np.asarray(qa)
    return QAStats(int(sums[0]), int(sums[1]), float(qa[0]), float(qa[1]),
                   float(qa[2]), int(np.asarray(cnt)[0]))
