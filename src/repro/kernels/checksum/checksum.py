"""On-device data-integrity checksum (paper §2.3 adapted to TPU).

The paper checksums every storage<->compute transfer on the host. For
on-device verification (e.g. after a resharding collective or a DMA from
host) we compute a position-weighted wrap-around checksum entirely on-chip:

    s1 = sum_i w_i            (mod 2^32, int32 wrap-around)
    s2 = sum_i (i mod M) w_i  (mod 2^32),  M = 65521

Both sums are order-independent per-block partials, so the grid reduces in
SMEM-free fashion via an accumulator output. ``ref.py`` defines the identical
function in numpy; kernel and oracle agree bit-exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

M_POS = 65521


def _checksum_kernel(x_ref, o_ref, *, blk: int, n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = x_ref[...]                                     # (blk,) int32 words
    idx = i * blk + jax.lax.iota(jnp.int32, blk)
    valid = idx < n
    w = jnp.where(valid, w, 0)
    pos = jnp.where(valid, idx % M_POS, 0)
    s1 = jnp.sum(w)                                    # int32 wrap-around
    s2 = jnp.sum(w * pos)
    o_ref[0] = o_ref[0] + s1
    o_ref[1] = o_ref[1] + s2


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def device_checksum(x, *, blk: int = 1024, interpret: bool = False):
    """x: any array. Returns int32[2] = (s1, s2) over its uint32 word view."""
    if x.dtype.itemsize == 4:
        words = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.int32)
    else:
        # little-endian pack of the byte view into int32 words (zero-padded)
        b = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1)
        pad = (-b.size) % 4
        if pad:
            b = jnp.concatenate([b, jnp.zeros(pad, jnp.uint8)])
        quads = b.reshape(-1, 4).astype(jnp.int32) & 0xFF
        words = (quads[:, 0] | (quads[:, 1] << 8) | (quads[:, 2] << 16)
                 | (quads[:, 3] << 24))
    words = words.reshape(-1)
    n = words.size
    blk = min(blk, max(n, 1))
    pad = (-n) % blk
    if pad:
        words = jnp.concatenate([words, jnp.zeros(pad, jnp.int32)])
    return pl.pallas_call(
        functools.partial(_checksum_kernel, blk=blk, n=n),
        grid=(words.size // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
        interpret=interpret,
    )(words)
