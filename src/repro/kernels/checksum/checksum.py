"""On-device data-integrity checksum + fused QA statistics (paper §2.3/§2.1).

The paper checksums every storage<->compute transfer on the host, and runs a
fast visual-QA pass over every ingested volume. Both are single-read
reductions over the same bytes, so we fuse them: ONE device pass over a
volume emits

    s1 = sum_i w_i            (mod 2^32, int32 wrap-around)      \\ transfer
    s2 = sum_i (i mod M) w_i  (mod 2^32),  M = 65521             /  checksum
    min, max, sum             over finite float values            \\ fast QA
    finite_count                                                  /

replacing ~5 separate numpy passes (isfinite, std, mean, checksum, ...) in
``core.ingest._fast_qa`` with a single ``pallas_call``. A batched variant
grids over the leading dim so a whole shape-bucket of volumes is verified in
one launch. ``ref.py`` defines the identical functions in numpy; kernel and
oracle agree bit-exactly — float sums use a fixed power-of-two halving tree
on both sides (elementwise IEEE adds, no reassociation), integer checksums
wrap mod 2^32.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import M_POS, qa_block_size


def _auto_interpret(interpret):
    """Pallas kernels compile only on TPU; elsewhere run interpreted."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ---------------------------------------------------------------------------
# plain checksum (kept: the transfer-only fast path)
# ---------------------------------------------------------------------------

def _checksum_kernel(x_ref, o_ref, *, blk: int, n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = x_ref[...]                                     # (blk,) int32 words
    idx = i * blk + jax.lax.iota(jnp.int32, blk)
    valid = idx < n
    w = jnp.where(valid, w, 0)
    pos = jnp.where(valid, idx % M_POS, 0)
    s1 = jnp.sum(w)                                    # int32 wrap-around
    s2 = jnp.sum(w * pos)
    o_ref[0] = o_ref[0] + s1
    o_ref[1] = o_ref[1] + s2


def _to_words(x) -> jnp.ndarray:
    """Little-endian int32 word view of an array's bytes (zero-padded)."""
    if x.dtype.itemsize == 4:
        return jax.lax.bitcast_convert_type(x.reshape(-1), jnp.int32)
    b = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1)
    pad = (-b.size) % 4
    if pad:
        b = jnp.concatenate([b, jnp.zeros(pad, jnp.uint8)])
    quads = b.reshape(-1, 4).astype(jnp.int32) & 0xFF
    return (quads[:, 0] | (quads[:, 1] << 8) | (quads[:, 2] << 16)
            | (quads[:, 3] << 24))


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def device_checksum(x, *, blk: int = 1024, interpret: bool = False):
    """x: any array. Returns int32[2] = (s1, s2) over its uint32 word view."""
    words = _to_words(x).reshape(-1)
    n = words.size
    blk = min(blk, max(n, 1))
    pad = (-n) % blk
    if pad:
        words = jnp.concatenate([words, jnp.zeros(pad, jnp.int32)])
    return pl.pallas_call(
        functools.partial(_checksum_kernel, blk=blk, n=n),
        grid=(words.size // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
        interpret=interpret,
    )(words)


# ---------------------------------------------------------------------------
# fused QA + checksum
# ---------------------------------------------------------------------------

def _tree_sum_f32(v):
    """Fixed halving-tree f32 sum; mirrors ``ref.tree_sum_f32`` bit-exactly."""
    n = v.shape[0]
    while n > 1:
        n //= 2
        v = v[:n] + v[n:2 * n]
    return v[0]


def _qa_checksum_kernel(w_ref, v_ref, sums_ref, qa_ref, cnt_ref, *,
                        blk_w: int, blk_v: int, nw: int, nv: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        qa_ref[0, 0] = jnp.float32(jnp.inf)
        qa_ref[0, 1] = jnp.float32(-jnp.inf)
        qa_ref[0, 2] = jnp.float32(0.0)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    # checksum over the word view
    w = w_ref[0, :]
    idx = i * blk_w + jax.lax.iota(jnp.int32, blk_w)
    valid = idx < nw
    w = jnp.where(valid, w, 0)
    pos = jnp.where(valid, idx % M_POS, 0)
    sums_ref[0, 0] = sums_ref[0, 0] + jnp.sum(w)
    sums_ref[0, 1] = sums_ref[0, 1] + jnp.sum(w * pos)

    # QA over the float value view (finite values only)
    v = v_ref[0, :].astype(jnp.float32)
    vidx = i * blk_v + jax.lax.iota(jnp.int32, blk_v)
    finite = jnp.isfinite(v) & (vidx < nv)
    cnt_ref[0, 0] = cnt_ref[0, 0] + jnp.sum(finite.astype(jnp.int32))
    qa_ref[0, 0] = jnp.minimum(qa_ref[0, 0],
                               jnp.min(jnp.where(finite, v, jnp.inf)))
    qa_ref[0, 1] = jnp.maximum(qa_ref[0, 1],
                               jnp.max(jnp.where(finite, v, -jnp.inf)))
    qa_ref[0, 2] = qa_ref[0, 2] + _tree_sum_f32(jnp.where(finite, v, 0.0))


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def _qa_checksum_2d(vals, *, blk: int, interpret: bool):
    """Core batched op. vals: (G, nv) in the original dtype. Returns
    (sums int32 (G,2), qa f32 (G,3), cnt int32 (G,1))."""
    G, nv = vals.shape
    itemsize = vals.dtype.itemsize
    blk_v = qa_block_size(nv, itemsize, blk)
    blk_w = blk_v * itemsize // 4
    # pad each ROW's byte extent to a word boundary before packing, so words
    # never straddle volume boundaries (matches the per-row oracle padding)
    row_pad = 0
    while (nv + row_pad) * itemsize % 4:
        row_pad += 1
    wvals = vals
    if row_pad:
        wvals = jnp.concatenate(
            [vals, jnp.zeros((G, row_pad), vals.dtype)], axis=1)
    words = _to_words(wvals).reshape(G, -1)
    nw = words.shape[1]
    nsteps = max(-(-nw // blk_w), -(-nv // blk_v), 1)
    wpad = nsteps * blk_w - nw
    if wpad:
        words = jnp.concatenate(
            [words, jnp.zeros((G, wpad), jnp.int32)], axis=1)
    vpad = nsteps * blk_v - nv
    if vpad:
        vals = jnp.concatenate(
            [vals, jnp.zeros((G, vpad), vals.dtype)], axis=1)
    return pl.pallas_call(
        functools.partial(_qa_checksum_kernel, blk_w=blk_w, blk_v=blk_v,
                          nw=nw, nv=nv),
        grid=(G, nsteps),
        in_specs=[pl.BlockSpec((1, blk_w), lambda g, i: (g, i)),
                  pl.BlockSpec((1, blk_v), lambda g, i: (g, i))],
        out_specs=(pl.BlockSpec((1, 2), lambda g, i: (g, 0)),
                   pl.BlockSpec((1, 3), lambda g, i: (g, 0)),
                   pl.BlockSpec((1, 1), lambda g, i: (g, 0))),
        out_shape=(jax.ShapeDtypeStruct((G, 2), jnp.int32),
                   jax.ShapeDtypeStruct((G, 3), jnp.float32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32)),
        interpret=interpret,
    )(words, vals)


def qa_checksum_batched(x, *, blk: int = 1024, interpret=None):
    """Fused QA+checksum over a shape-bucket: ``x`` is (N, ...) — N volumes
    verified in ONE ``pallas_call`` (grid over the leading dim). Returns
    (int32 (N,2) checksums, float32 (N,3) [min,max,sum], int32 (N,1) counts).
    """
    x = jnp.asarray(x)
    return _qa_checksum_2d(x.reshape(x.shape[0], -1), blk=blk,
                           interpret=_auto_interpret(interpret))


def qa_checksum(x, *, blk: int = 1024, interpret=None):
    """Unbatched fused QA+checksum: one device pass over ``x`` emitting
    ``(s1, s2)``, ``(min, max, sum)`` over finite values, and finite_count.
    Returns (int32[2], float32[3], int32[1]); see :func:`qa_stats` for a
    friendly view."""
    x = jnp.asarray(x)
    sums, qa, cnt = _qa_checksum_2d(x.reshape(1, -1), blk=blk,
                                    interpret=_auto_interpret(interpret))
    return sums[0], qa[0], cnt[0]


@dataclasses.dataclass(frozen=True)
class QAStats:
    """Host-side view of one volume's fused QA+checksum pass."""
    s1: int
    s2: int
    vmin: float
    vmax: float
    vsum: float
    finite_count: int

    @property
    def checksum(self) -> int:
        return ((self.s2 & 0xFFFFFFFF) << 32) | (self.s1 & 0xFFFFFFFF)


def qa_stats(x, *, blk: int = 1024, interpret=None) -> QAStats:
    """Run :func:`qa_checksum` and pull the scalars to the host."""
    import numpy as np
    sums, qa, cnt = qa_checksum(x, blk=blk, interpret=interpret)
    sums = np.asarray(sums).view(np.uint32)
    qa = np.asarray(qa)
    return QAStats(int(sums[0]), int(sums[1]), float(qa[0]), float(qa[1]),
                   float(qa[2]), int(np.asarray(cnt)[0]))
