"""RWKV-6 chunked WKV as a Pallas TPU kernel.

Grid (B, H, n_chunks) with the chunk dimension innermost (sequential on TPU);
the (dh x dh) recurrent state lives in VMEM scratch and carries across chunk
iterations. Per-chunk math matches ``models/rwkv6.wkv_chunked``: pairwise
decay factors exp(cum_t - cum_s) computed directly (always <= 1, stable).
Working set per (b, h): 4 x (T, dh) inputs + (T, T, dh) decay ~ 4.3 MB at
T=128, dh=64 — fits VMEM with the MXU-aligned (T, T) score matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)          # (T, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)        # (T, dh) log-decay < 0
    u = u_ref[0].astype(jnp.float32)             # (dh,)
    S0 = state_ref[...]                          # (dh, dh)

    cum = jnp.cumsum(lw, axis=0)                 # inclusive
    cumex = cum - lw                             # exclusive
    T = r.shape[0]
    # intra-chunk: scores[t,s] = sum_d r[t,d] k[s,d] exp(cumex[t,d]-cum[s,d])
    decay = jnp.exp(cumex[:, None, :] - cum[None, :, :])       # (T,T,dh)
    scores = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=-1)
    tri = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    scores = jnp.where(tri, scores, 0.0)
    diag = jnp.sum(u[None, :] * r * k, axis=-1)                # (T,)
    out = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))
    out = out + diag[:, None] * v
    # inter-chunk: r_t decayed to chunk start @ S0
    out = out + jax.lax.dot_general(r * jnp.exp(cumex), S0,
                                    (((1,), (0,)), ((), ())))
    o_ref[0, 0] = out.astype(o_ref.dtype)
    # state update: S' = diag(exp(cum_T)) S0 + sum_s diag(exp(cum_T-cum_s)) k_s^T v_s
    pT = jnp.exp(cum[-1])                                      # (dh,)
    ksc = k * jnp.exp(cum[-1][None, :] - cum)                  # (T, dh)
    state_ref[...] = pT[:, None] * S0 + jax.lax.dot_general(
        ksc, v, (((0,), (0,)), ((), ())))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked(r, k, v, logw, u, *, chunk: int = 128, interpret: bool = False):
    """r,k,v,logw: (B, H, S, dh); u: (H, dh). Returns out (B, H, S, dh) f32."""
    B, H, S, dh = r.shape
    pad = (-S) % chunk
    if pad:
        pw = ((0, 0), (0, 0), (0, pad), (0, 0))
        r, k, v = (jnp.pad(a, pw) for a in (r, k, v))
        logw = jnp.pad(logw, pw)         # logw=0 on pad: decay 1, k=v=0
    nc = r.shape[2] // chunk
    out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, dh), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct(r.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return out[:, :, :S] if pad else out
