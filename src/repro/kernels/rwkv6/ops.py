from .rwkv6 import wkv6_chunked as wkv6_op  # noqa: F401
