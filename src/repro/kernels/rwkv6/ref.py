"""Oracle: exact sequential RWKV-6 recurrence (pure jnp lax.scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u):
    """r,k,v,logw: (B, H, S, dh); u: (H, dh). Exact step-by-step recurrence:
        o_t = r_t (S_{t-1} + diag(u) k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    B, H, S, dh = r.shape
    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    u32 = u.astype(jnp.float32)

    def step(S_, t):
        rt, kt, vt, wt = r32[:, :, t], k32[:, :, t], v32[:, :, t], w[:, :, t]
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        ot = jnp.einsum("bhd,bhde->bhe", rt, S_ + u32[None, :, :, None] * kv)
        S_ = wt[..., None] * S_ + kv
        return S_, ot

    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    _, outs = jax.lax.scan(step, S0, jnp.arange(S))
    return outs.transpose(1, 2, 0, 3)        # (B, H, S, dh)
