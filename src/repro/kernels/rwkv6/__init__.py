from .rwkv6 import wkv6_chunked
from .ref import wkv6_ref

__all__ = ["wkv6_chunked", "wkv6_ref"]
