"""Mamba-2 (SSD) layer — used by zamba2 (hybrid) and available standalone.

State-space dual form: per head h with state S in R^{dh x N}:
    S_t = a_t S_{t-1} + (dt_t x_t) B_t^T        (a_t = exp(dt_t * A_h), A_h < 0)
    y_t = C_t^T S_t^T + D_h x_t
Training runs the chunked SSD algorithm (Dao & Gu 2024, "minimal SSD"):
within-chunk quadratic attention-like term + cross-chunk state scan.
Decode is the exact recurrence. ``kernels/mamba2_ssd`` is the Pallas version.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .layers import normal_init, rmsnorm


def init_mamba_layer(key, cfg, n_layers, dtype=jnp.float32):
    D = cfg.d_model
    s = cfg.ssm
    di = s.expand * D
    H = di // s.d_head
    N = s.d_state
    L = (n_layers,)
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.ones(L + (D,), dtype),
        # fused input projection -> [z(di), x(di), B(N), C(N), dt(H)]
        "in_proj": normal_init(ks[0], L + (D, 2 * di + 2 * N + H), dtype=dtype),
        "conv_w": normal_init(ks[1], L + (s.d_conv, di + 2 * N), 0.2, dtype),
        "conv_b": jnp.zeros(L + (di + 2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
                         )[None].repeat(n_layers, 0).astype(dtype),
        "D": jnp.ones(L + (H,), dtype),
        "dt_bias": jnp.zeros(L + (H,), dtype),
        "norm": jnp.ones(L + (di,), dtype),
        "out_proj": normal_init(ks[2], L + (di, D), 0.02 / (2 * max(cfg.n_layers, 1)) ** 0.5,
                                dtype=dtype),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C); returns (y, new_state (B,K-1,C))."""
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return y + b.astype(x.dtype), xp[:, -(K - 1):]


def _segsum(lw):
    """lw: (..., T). Returns (..., T, T) with out[t,s] = sum_{s<tau<=t} lw[tau], -inf above diag."""
    T = lw.shape[-1]
    cum = jnp.cumsum(lw, axis=-1)
    out = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(xh, dt, a_log, Bm, Cm, state, chunk):
    """Chunked SSD. xh: (B,S,H,dh); dt: (B,S,H) (post-softplus);
    a_log: (H,) = A_log; Bm, Cm: (B,S,N); state: (B,H,dh,N) fp32.
    Returns y (B,S,H,dh), new state."""
    B, S, H, dh = xh.shape
    N = Bm.shape[-1]
    Sorig = S
    if S % chunk:
        # identity padding: x=0 (no state update), lw=0 (decay 1)
        pad = chunk - S % chunk
        xh = jnp.pad(xh, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        Bm = jnp.pad(Bm, [(0, 0), (0, pad), (0, 0)])
        Cm = jnp.pad(Cm, [(0, 0), (0, pad), (0, 0)])
        S += pad
    nc = S // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))              # (H,) negative
    lw = dt.astype(jnp.float32) * A                      # (B,S,H) log-decay per step
    xs = (xh.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])  # dt-weighted input

    rs = lambda t, d: t.reshape((B, nc, chunk) + t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1)) if d else t
    xc = xs.reshape(B, nc, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    lc = lw.reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
    Bc = Bm.astype(jnp.float32).reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)

    def body(S0, args):
        xb, lb, Bb, Cb = args                            # (B,T,H,dh),(B,T,H),(B,T,N)
        Lmat = jnp.exp(_segsum(lb.transpose(0, 2, 1)))   # (B,H,T,T)
        # intra-chunk: y[t] = sum_{s<=t} C_t.B_s exp(seg) x_s
        CB = jnp.einsum("btn,bsn->bts", Cb, Bb)          # (B,T,T)
        y = jnp.einsum("bts,bhts,bshd->bthd", CB, Lmat, xb)
        # inter-chunk: y[t] += C_t S0 decayed to t
        cum = jnp.cumsum(lb, axis=1)                     # (B,T,H)
        y += jnp.einsum("btn,bhdn,bth->bthd", Cb, S0, jnp.exp(cum))
        # state: S1 = exp(cum_T) S0 + sum_s exp(cum_T - cum_s) x_s B_s^T
        pT = jnp.exp(cum[:, -1])                         # (B,H)
        w = jnp.exp(cum[:, -1:, :] - cum)                # (B,T,H)
        S1 = pT[..., None, None] * S0 + jnp.einsum("bshd,bsn,bsh->bhdn", xb, Bb, w)
        return S1, y

    state, yc = jax.lax.scan(body, state.astype(jnp.float32), (xc, lc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    return y[:, :Sorig], state


def ssd_step(xh, dt, a_log, Bm, Cm, state):
    """Exact single-step. xh: (B,1,H,dh); dt: (B,1,H); Bm,Cm: (B,1,N)."""
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dt[:, 0].astype(jnp.float32) * A)        # (B,H)
    xb = xh[:, 0].astype(jnp.float32) * dt[:, 0].astype(jnp.float32)[..., None]
    upd = jnp.einsum("bhd,bn->bhdn", xb, Bm[:, 0].astype(jnp.float32))
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bhdn,bn->bhd", state, Cm[:, 0].astype(jnp.float32))
    return y[:, None], state


def mamba_block(x, p, cfg, state):
    """One Mamba2 layer. state: {ssm (B,H,dh,N) fp32, conv (B,K-1,di+2N)}."""
    s = cfg.ssm
    D = cfg.d_model
    di = s.expand * D
    H, dh, N = di // s.d_head, s.d_head, s.d_state
    B, S, _ = x.shape
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", h, p["in_proj"].astype(x.dtype))
    z, xin, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"], state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xin, Bm, Cm = jnp.split(conv_out, [di, di + N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    xh = xin.reshape(B, S, H, dh)
    # TP over SSD heads: bounds the (B,H,T,T) intra-chunk tensors per device
    xh = constrain(xh, "batch", None, "act_model", None)
    dt = constrain(dt, "batch", None, "act_model")
    if S == 1:
        y, ssm = ssd_step(xh, dt, p["A_log"], Bm, Cm, state["ssm"])
    else:
        y, ssm = ssd_chunked(xh, dt, p["A_log"], Bm, Cm, state["ssm"], s.chunk)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    return x + out, {"ssm": ssm, "conv": conv_state}


def init_mamba_state(cfg, n_layers, batch, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H, dh, N = di // s.d_head, s.d_head, s.d_state
    return {
        "ssm": jnp.zeros((n_layers, batch, H, dh, N), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, s.d_conv - 1, di + 2 * N), dtype),
    }
