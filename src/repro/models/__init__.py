from .model import (init_params, forward_train, forward_prefill, forward_decode,
                    init_cache, cache_max_len, cross_entropy)

__all__ = ["init_params", "forward_train", "forward_prefill", "forward_decode",
           "init_cache", "cache_max_len", "cross_entropy"]
