"""Mixture-of-Experts layer (EP-ready).

Dispatch is **scatter-based with per-sequence groups**: each sequence routes its
own tokens into an ``(E, C)`` capacity buffer via differentiable scatter-add
(positions from an exclusive cumsum of the expert one-hot — no sort needed).
Grouping by sequence keeps dispatch local to the data shard under GSPMD; the
only EP collective is the resharding of the buffer's expert axis onto the
``model`` mesh axis (the classic all-to-all), which XLA inserts.

For single-token decode the layer falls back to a dense mixture over experts
(weights for every expert are touched by a 128-token batch anyway; decode is
memory-bound — see EXPERIMENTS.md §Roofline).

Capacity-overflow tokens are dropped (Switch-style), weighted-combine
renormalizes over surviving slots. An auxiliary load-balance loss
(Switch: ``E * sum_e f_e * p_e``) is returned.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .layers import normal_init


def init_moe(key, cfg, n_layers, dtype=jnp.float32):
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 3)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "router": normal_init(ks[0], (n_layers, D, E), dtype=dtype),
        # fused gate+up: one backward all-reduce instead of two (§Perf P1)
        "w13": normal_init(ks[1], (n_layers, E, D, 2 * F), dtype=dtype),
        "w2": normal_init(ks[2], (n_layers, E, F, D), out_scale, dtype=dtype),
    }


def _route(x, router, m):
    """x: (B,S,D) -> sel (B,S,k) int32, w (B,S,k) fp32, aux_loss scalar."""
    logits = jnp.einsum("bsd,de->bse", x, router.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(probs, m.top_k)          # softmax-then-topk
    w = w / jnp.clip(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch aux loss: fraction of tokens per expert x mean router prob
    E = probs.shape[-1]
    f = jnp.mean(jax.nn.one_hot(sel[..., 0], E), axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * p)
    return sel, w, aux


def _dispatch_seq(x, sel, w, E, C):
    """Per-sequence dispatch. x: (S,D); sel,w: (S,k). Returns buffer (E*C, D),
    flat index (S,k), keep mask (S,k)."""
    S, k = sel.shape
    oh = jax.nn.one_hot(sel, E, dtype=jnp.int32)        # (S,k,E)
    row = oh.sum(1)                                      # (S,E)
    excl = jnp.cumsum(row, axis=0) - row                 # tokens before row s
    # within-row offset for slots sharing an expert (top_k gives distinct ids,
    # but stay safe): number of earlier slots in same row with same expert
    intra = jnp.cumsum(oh, axis=1) - oh                  # (S,k,E)
    pos = jnp.take_along_axis(excl[:, None, :] + intra, sel[..., None], -1)[..., 0]
    keep = pos < C                                       # (S,k)
    idx = sel * C + jnp.where(keep, pos, 0)              # clamp dropped to slot 0
    contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(x.dtype)
    # scatter-add each slot's token into its (expert, position) slot — 2-D
    # target so GSPMD can keep the expert dim sharded through the scatter
    buf = jnp.zeros((E, C, x.shape[-1]), x.dtype)
    e_idx = sel.reshape(S * k)
    p_idx = jnp.where(keep, pos, 0).reshape(S * k)
    flat_val = (x[:, None, :] * contrib).reshape(S * k, -1)
    buf = buf.at[e_idx, p_idx].add(flat_val, mode="drop")
    return buf.reshape(E * C, x.shape[-1]), idx, keep


def moe_mlp(x: jax.Array, p: dict, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (B,S,D), aux_loss. p holds this layer's slices."""
    m = cfg.moe
    B, S, D = x.shape
    E, F, k = m.n_experts, m.d_ff_expert, m.top_k
    sel, w, aux = _route(x, p["router"], m)

    if S == 1:
        # decode: dense mixture over experts (memory-bound; see module docstring)
        gates = jnp.sum(jax.nn.one_hot(sel, E, dtype=jnp.float32) * w[..., None],
                        axis=2)                          # (B,1,E)
        gu = jnp.einsum("bsd,edf->bsef", x, p["w13"].astype(x.dtype))
        g1, g3 = jnp.split(gu, 2, axis=-1)
        h = jax.nn.silu(g1) * g3
        y = jnp.einsum("bsef,efd->bsed", h, p["w2"].astype(x.dtype))
        return jnp.einsum("bsed,bse->bsd", y, gates.astype(x.dtype)), aux

    C = max(1, int(math.ceil(S * k * m.capacity_factor / E)))
    buf, idx, keep = jax.vmap(lambda xs, ss, ws: _dispatch_seq(xs, ss, ws, E, C))(
        x, sel, w)
    buf = buf.reshape(B, E, C, D)
    # EP: expert axis onto 'model' — this reshard is the dispatch all-to-all
    buf = constrain(buf, "batch", "act_model", None, None)
    gu = jnp.einsum("becd,edf->becf", buf, p["w13"].astype(x.dtype))
    g1, g3 = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g1) * g3
    y = jnp.einsum("becf,efd->becd", h, p["w2"].astype(x.dtype))   # (B,E,C,D)
    y = y.reshape(B, E * C, D)
    # combine: gather each slot's output, weight, sum over k
    gathered = jnp.take_along_axis(y, idx.reshape(B, S * k)[..., None], axis=1)
    gathered = gathered.reshape(B, S, k, D)
    wk = (w * keep).astype(x.dtype)
    return jnp.einsum("bskd,bsk->bsd", gathered, wk), aux
