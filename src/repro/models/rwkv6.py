"""RWKV-6 (Finch) — attention-free time-mix with data-dependent decay.

Recurrence (per head, state S in R^{dh x dh}):
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
with per-channel decay w_t = exp(-exp(w0 + lora(x_w))) data-dependent per token.

Training uses a **chunked parallel form**: within a chunk the pairwise decay
factors exp(cum_t - cum_s) are computed directly (always <= 1, numerically
stable), cross-chunk state is carried by a scan. Decode is the exact
single-step recurrence. ``kernels/rwkv6`` provides the Pallas TPU version of
the chunk kernel; this module is the XLA path and the oracle's building block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .layers import normal_init, rmsnorm


def init_rwkv_layer(key, cfg, n_layers, dtype=jnp.float32):
    D, F = cfg.d_model, cfg.d_ff
    H, dh = cfg.n_heads, cfg.rwkv.head_size
    r = cfg.rwkv.decay_lora
    L = (n_layers,)
    ks = jax.random.split(key, 12)
    return {
        "ln1": jnp.ones(L + (D,), dtype),
        "ln2": jnp.ones(L + (D,), dtype),
        # static token-shift lerp coefficients for r,k,v,w,g
        "mu": 0.5 * jnp.ones(L + (5, D), dtype),
        "wr": normal_init(ks[0], L + (D, H * dh), dtype=dtype),
        "wk": normal_init(ks[1], L + (D, H * dh), dtype=dtype),
        "wv": normal_init(ks[2], L + (D, H * dh), dtype=dtype),
        "wg": normal_init(ks[3], L + (D, H * dh), dtype=dtype),
        "wo": normal_init(ks[4], L + (H * dh, D), 0.02 / (2 * cfg.n_layers) ** 0.5,
                          dtype=dtype),
        "w0": -6.0 * jnp.ones(L + (H, dh), dtype),          # decay base (slow decay)
        "wa": normal_init(ks[5], L + (D, r), 0.01, dtype),   # decay lora in
        "wb": normal_init(ks[6], L + (r, H * dh), 0.01, dtype),
        "u": normal_init(ks[7], L + (H, dh), 0.5, dtype),    # bonus
        "gn": jnp.ones(L + (H * dh,), dtype),                # output group-norm scale
        # channel-mix
        "mu_c": 0.5 * jnp.ones(L + (2, D), dtype),
        "wck": normal_init(ks[8], L + (D, F), dtype=dtype),
        "wcv": normal_init(ks[9], L + (F, D), 0.02 / (2 * cfg.n_layers) ** 0.5, dtype),
        "wcr": normal_init(ks[10], L + (D, D), dtype=dtype),
    }


def _shift(x, prev):
    """Token shift: x_{t-1}, with `prev` (B,1,D) filling position 0."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _projections(x, xprev, p, H, dh):
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = [x + (xprev - x) * mu[i] for i in range(5)]
    B, S, _ = x.shape
    r = jnp.einsum("bsd,dh->bsh", xr, p["wr"].astype(x.dtype)).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,dh->bsh", xk, p["wk"].astype(x.dtype)).reshape(B, S, H, dh)
    v = jnp.einsum("bsd,dh->bsh", xv, p["wv"].astype(x.dtype)).reshape(B, S, H, dh)
    g = jnp.einsum("bsd,dh->bsh", xg, p["wg"].astype(x.dtype))
    lora = jnp.einsum("br,rh->bh",
                      jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["wa"].astype(x.dtype))
                               ).reshape(B * S, -1),
                      p["wb"].astype(x.dtype)).reshape(B, S, H, dh)
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    logw = jnp.clip(logw, -20.0, -1e-6)                  # (B,S,H,dh), < 0
    # TP: shard heads so the chunked (B,H,T,T,dh) decay tensor is 1/tp-sized
    r, k, v, logw = (constrain(a, "batch", None, "act_model", None)
                     for a in (r, k, v, logw))
    return r, k, v, g, logw


def wkv_chunked(r, k, v, logw, u, state, chunk):
    """Chunked RWKV6 core. r,k,v,logw: (B,S,H,dh); u: (H,dh);
    state: (B,H,dh,dh). Returns out (B,S,H,dh), new state."""
    B, S, H, dh = r.shape
    Sorig = S
    if S % chunk:
        # pad with identity contributions: k=v=0 (no state update), logw=0 (decay 1)
        pad = chunk - S % chunk
        pw = [(0, 0), (0, pad), (0, 0), (0, 0)]
        r, k, v = (jnp.pad(a, pw) for a in (r, k, v))
        logw = jnp.pad(logw, pw)
        S += pad
    nc = S // chunk
    rc, kc, vc, lwc = [a.reshape(B, nc, chunk, H, dh).transpose(1, 0, 3, 2, 4)
                       for a in (r, k, v, logw)]         # (nc,B,H,T,dh)
    uf = u.astype(jnp.float32)

    def body(S0, args):
        rb, kb, vb, lwb = args                           # (B,H,T,dh)
        rb32, kb32, vb32 = rb.astype(jnp.float32), kb.astype(jnp.float32), vb.astype(jnp.float32)
        cum = jnp.cumsum(lwb, axis=2)                    # inclusive
        cumex = cum - lwb                                # exclusive
        # intra-chunk: scores[t,s] = sum_d r[t,d] k[s,d] exp(cumex[t,d]-cum[s,d]), s<t
        decay = jnp.exp(cumex[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,H,T,T,dh)
        scores = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rb32, kb32, decay)
        T = rb.shape[2]
        tri = jnp.tril(jnp.ones((T, T), bool), -1)
        scores = jnp.where(tri, scores, 0.0)
        diag = jnp.einsum("hd,bhtd,bhtd->bht", uf, rb32, kb32)
        out = jnp.einsum("bhts,bhsd->bhtd", scores, vb32)
        out += diag[..., None] * vb32
        # inter-chunk: r_t * P_{t-1} @ S0
        out += jnp.einsum("bhtd,bhde->bhte", rb32 * jnp.exp(cumex), S0)
        # state update: S' = diag(P_T) S0 + sum_s diag(exp(cum_T-cum_s)) k_s^T v_s
        pT = jnp.exp(cum[:, :, -1])                      # (B,H,dh)
        ksc = kb32 * jnp.exp(cum[:, :, -1:, :] - cum)    # (B,H,T,dh)
        S1 = pT[..., None] * S0 + jnp.einsum("bhtd,bhte->bhde", ksc, vb32)
        return S1, out

    state, out = jax.lax.scan(body, state.astype(jnp.float32), (rc, kc, vc, lwc))
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dh)
    return out[:, :Sorig], state


def wkv_step(r, k, v, logw, u, state):
    """Exact single-token recurrence. r,k,v,logw: (B,1,H,dh); state (B,H,dh,dh)."""
    r32 = r[:, 0].astype(jnp.float32)
    k32 = k[:, 0].astype(jnp.float32)
    v32 = v[:, 0].astype(jnp.float32)
    kv = jnp.einsum("bhd,bhe->bhde", k32, v32)
    out = jnp.einsum("bhd,bhde->bhe", r32, state + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = jnp.exp(logw[:, 0].astype(jnp.float32))[..., None] * state + kv
    return out[:, None], state


def time_mix(x, p, cfg, state):
    """state: dict(shift (B,1,D), wkv (B,H,dh,dh)). Returns (y, new_state)."""
    H, dh = cfg.n_heads, cfg.rwkv.head_size
    B, S, D = x.shape
    xprev = _shift(x, state["shift"]) if S > 1 else state["shift"]
    r, k, v, g, logw = _projections(x, xprev, p, H, dh)
    if S == 1:
        out, wkv = wkv_step(r, k, v, logw, p["u"], state["wkv"])
    else:
        out, wkv = wkv_chunked(r, k, v, logw, p["u"], state["wkv"], cfg.rwkv.chunk)
    out = out.reshape(B, S, H * dh).astype(x.dtype)
    # per-head group norm
    out = out.reshape(B, S, H, dh)
    out = out * jax.lax.rsqrt(jnp.mean(jnp.square(out.astype(jnp.float32)), -1,
                                       keepdims=True) + 1e-5).astype(x.dtype)
    out = out.reshape(B, S, H * dh) * p["gn"].astype(x.dtype)
    out = out * jax.nn.silu(g)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    new_state = {"shift": x[:, -1:], "wkv": wkv}
    return y, new_state


def channel_mix(x, p, state_shift):
    xprev = _shift(x, state_shift) if x.shape[1] > 1 else state_shift
    mu = p["mu_c"].astype(x.dtype)
    xk = x + (xprev - x) * mu[0]
    xr = x + (xprev - x) * mu[1]
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wck"].astype(x.dtype))))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wcr"].astype(x.dtype)))
    return rr * jnp.einsum("bsf,fd->bsd", kk, p["wcv"].astype(x.dtype)), x[:, -1:]


def rwkv_block(x, p, cfg, state):
    """One RWKV layer. state: {shift, wkv, cshift}."""
    h, tm_state = time_mix(rmsnorm(x, p["ln1"], cfg.norm_eps), p, cfg,
                           {"shift": state["shift"], "wkv": state["wkv"]})
    x = x + h
    h, cshift = channel_mix(rmsnorm(x, p["ln2"], cfg.norm_eps), p, state["cshift"])
    x = x + h
    return x, {"shift": tm_state["shift"], "wkv": tm_state["wkv"], "cshift": cshift}


def init_rwkv_state(cfg, batch, dtype=jnp.float32):
    H, dh, D = cfg.n_heads, cfg.rwkv.head_size, cfg.d_model
    L = cfg.n_layers
    return {
        "shift": jnp.zeros((L, batch, 1, D), dtype),
        "wkv": jnp.zeros((L, batch, H, dh, dh), jnp.float32),
        "cshift": jnp.zeros((L, batch, 1, D), dtype),
    }
