"""Core transformer layers: RMSNorm, RoPE, GQA/SWA attention (chunked for long
sequences), SwiGLU/GELU MLPs.

All functions are pure; parameters are plain dict pytrees. Attention never
materializes ``(B, H, S, S)`` for long sequences — queries are processed in
chunks via ``lax.scan`` so 32k prefill stays within per-device memory.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import attn_shard_choice, constrain

# Query-chunk size for chunked attention. 2048 keeps the per-chunk score
# slice (B, KV, G, Cq, Sk) a few hundred MB/device on the production mesh.
ATTN_CHUNK = 2048


def _constrain_q(qg, choice, chunked: bool):
    """qg: (B,Sq,KV,G,Dh) or chunked (nc,B,Cq,KV,G,Dh)."""
    if choice is None:
        return qg
    lead = ("None_", "batch") if chunked else ("batch",)
    names = {"kv": (None, "act_model", None, None),
             "g": (None, None, "act_model", None),
             "q": ("act_model", None, None, None)}[choice]
    spec = tuple(None if n == "None_" else n for n in lead) + names
    return constrain(qg, *spec)


def _constrain_kv(k, choice):
    """k/v: (B,Sk,KV,Dh) — shard kv-head dim when that's the chosen axis."""
    if choice == "kv":
        return constrain(k, "batch", None, "act_model", None)
    return k

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with f32 moment accumulation but NO materialized f32 copy of
    ``x``: the variance comes from an f32-accumulating einsum and the
    normalizer is cast down before the multiply. (A full ``x.astype(f32)`` as
    the first op of a scanned layer invites XLA to hoist the convert out of
    the backward loop, duplicating the entire saved-activation stack in f32 —
    measured +8.8 GB/chip on granite-34b; EXPERIMENTS.md §Perf G1.)"""
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    r = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)   # (..., 1) small
    return (x * r) * scale.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, ..., Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    # align: angles (..., S, dh/2) -> (..., S, 1...1, dh/2) matching x (B, S, H.., Dh/2)
    mid = x.ndim - angles.ndim - 1
    angles = angles.reshape(angles.shape[:-1] + (1,) * mid + angles.shape[-1:])
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable,
         static_argnums=(4,))
def _attend_block(q, k, v, mask, scale):
    """q: (B, Cq, KV, G, Dh); k,v: (B, Sk, KV, Dh); mask: (Cq, Sk) or None.

    Returns (B, Cq, KV, G, Dh). GQA is handled by the extra group dim G —
    k/v are never repeated in memory. ``jax.checkpoint`` makes the backward
    recompute scores/probs from (q,k,v) instead of saving the O(S^2) prob
    tensor — flash-attention's memory behaviour, in XLA (the Pallas kernel
    in kernels/flash_attention is the on-TPU hot path).
    """
    scores = jnp.einsum("biegd,bjed->begij", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("begij,bjed->biegd", probs, v)


def _causal_mask(q_pos: jax.Array, k_pos: jax.Array,
                 window: Optional[int], causal: bool) -> Optional[jax.Array]:
    if not causal and window is None:
        return None
    m = None
    if causal:
        m = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        w = (q_pos[:, None] - k_pos[None, :]) < window
        m = w if m is None else (m & w)
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: Optional[int] = None,
              q_offset: int | jax.Array = 0,
              chunk: int = ATTN_CHUNK) -> jax.Array:
    """Multi-head attention with GQA + optional sliding window.

    q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh). H must be a multiple of KV.
    ``q_offset`` is the absolute position of q[:, 0] (decode / chunking).
    Long query sequences are processed in chunks of ``chunk`` to bound the
    score matrix to (B, KV, G, chunk, Sk).
    """
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, KV, G, Dh)
    k_pos = jnp.arange(Sk)
    # When GSPMD can factor the TP axis across (KV, G) (e.g. 8x2 for
    # KV=8,G=4) we leave sharding to it — manual constraints only cause
    # involuntary resharding. When it CANNOT (llama4, whisper) it would shard
    # the Dh contraction dim and all-reduce raw scores; query-position
    # sharding is the clean alternative (§Perf L1).
    choice = attn_shard_choice(KV, G, min(Sq, chunk))

    if Sq <= chunk:
        if choice == "q":
            qg = constrain(qg, "batch", "act_model", None, None, None)
        q_pos = jnp.arange(Sq) + q_offset
        mask = _causal_mask(q_pos, k_pos, window, causal)
        out = _attend_block(qg, k, v, mask, scale)
        if choice == "q":
            out = constrain(out, "batch", "act_model", None, None, None)
        return out.reshape(B, Sq, H, Dh)

    assert Sq % chunk == 0, (Sq, chunk)
    nc = Sq // chunk
    qc = qg.reshape(B, nc, chunk, KV, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    if choice == "q":
        qc = constrain(qc, None, "batch", "act_model", None, None, None)

    def body(_, args):
        ci, qb = args
        q_pos = ci * chunk + jnp.arange(chunk) + q_offset
        mask = _causal_mask(q_pos, k_pos, window, causal)
        return None, _attend_block(qb, k, v, mask, scale)

    _, out = jax.lax.scan(body, None, (jnp.arange(nc), qc))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dh)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None) -> jax.Array:
    """Single-token attention against a (possibly longer-than-pos) cache.

    q: (B, 1, H, Dh); caches: (B, Smax, KV, Dh); pos: scalar int32 — the
    position of the new token (cache entries > pos are masked out).

    With ``window`` the cache is a RING buffer of length Smax == window:
    slot indices are not absolute positions. Once the ring has wrapped
    (pos >= Smax) every slot holds one of the last ``window`` tokens, so all
    are valid; before wrapping, slots <= pos are valid. RoPE is applied
    before writing, so attention is permutation-invariant over slots.
    """
    B, _, H, Dh = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, 1, KV, G, Dh)
    k_pos = jnp.arange(Smax)
    if window is not None:
        valid = (k_pos <= pos) | (pos >= Smax)
    else:
        valid = k_pos <= pos
    scores = jnp.einsum("biegd,bjed->begij", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("begij,bjed->biegd", probs, v_cache)
    return out.reshape(B, 1, H, Dh)


# ---------------------------------------------------------------------------
# projections / MLP
# ---------------------------------------------------------------------------

def split_fused(x, widths, interleave: int):
    """Split the last dim of ``x`` into ``widths``, where the fused dim is
    laid out in ``interleave`` blocks of [w0/t | w1/t | ...]. Extraction is a
    reshape + slice of an UNSHARDED sub-dim, so a TP-sharded fused dim splits
    with zero collectives (shard boundaries align by construction)."""
    t = interleave
    if t <= 1 or any(w % t for w in widths):
        import numpy as _np
        return jnp.split(x, list(_np.cumsum(widths[:-1])), axis=-1)
    tot = x.shape[-1]
    xr = x.reshape(x.shape[:-1] + (t, tot // t))
    parts = []
    off = 0
    for w in widths:
        parts.append(xr[..., off:off + w // t].reshape(x.shape[:-1] + (w,)))
        off += w // t
    return parts


def qkv_fusable(cfg) -> bool:
    """Fused+interleaved qkv requires the head dim to carry the TP sharding
    after the final (B,S,H,Dh) reshape: H, H*Dh and KV*Dh must all divide
    ``tp_fuse``. Otherwise (llama4 H=40, whisper H=12) GSPMD would migrate
    the sharding onto Dh — the attention CONTRACTION dim — and all-reduce raw
    score tensors (measured 960 GiB/step for llama4; §Perf L1)."""
    t = cfg.tp_fuse
    return (t > 1 and cfg.n_heads % t == 0
            and (cfg.n_heads * cfg.d_head) % t == 0
            and (cfg.n_kv_heads * cfg.d_head) % t == 0)


def attn_qkv(x, p, cfg):
    """x: (B, S, D) -> q (B,S,H,Dh), k,v (B,S,KV,Dh).

    Q/K/V are ONE fused matmul (`wqkv`) where shardable: under Megatron TP
    the backward dx of a column-parallel matmul needs a full (B,S,D)
    all-reduce — fusing turns three such all-reduces into one (§Perf P1).
    The fused columns are interleaved per TP shard (``cfg.tp_fuse``) so the
    split is collective-free (§Perf P2). Head order is therefore a fixed
    permutation of the published layout — irrelevant for training from
    scratch; pretrained imports must permute columns accordingly."""
    B, S, _ = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if "wqkv" in p:
        qkv = jnp.einsum("bsd,dh->bsh", x, p["wqkv"].astype(x.dtype))
        q, k, v = split_fused(qkv, [H * Dh, KV * Dh, KV * Dh], cfg.tp_fuse)
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    return (q.reshape(B, S, H, Dh), k.reshape(B, S, KV, Dh),
            v.reshape(B, S, KV, Dh))


def attn_out(o, p):
    B, S, H, Dh = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * Dh), p["wo"].astype(o.dtype))


def mlp(x, p, kind: str = "swiglu", fuse: int = 1):
    if kind == "swiglu":
        # fused gate+up (`w13`): one dx all-reduce in backward instead of two
        # (§Perf P1); interleaved layout keeps the split collective-free (P2)
        gu = jnp.einsum("bsd,df->bsf", x, p["w13"].astype(x.dtype))
        F = gu.shape[-1] // 2
        gate, up = split_fused(gu, [F, F], fuse)
        h = jax.nn.silu(gate) * up
    else:  # gelu
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype)))
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(x.dtype))


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_attn(key, cfg, n_layers=None, dtype=jnp.float32):
    """Stacked attention params (fused qkv where shardable)."""
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    L = () if n_layers is None else (n_layers,)
    ks = jax.random.split(key, 4)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    if qkv_fusable(cfg):
        return {
            "wqkv": normal_init(ks[0], L + (D, (H + 2 * KV) * Dh), dtype=dtype),
            "wo": normal_init(ks[1], L + (H * Dh, D), out_scale, dtype=dtype),
        }
    return {
        "wq": normal_init(ks[0], L + (D, H * Dh), dtype=dtype),
        "wk": normal_init(ks[1], L + (D, KV * Dh), dtype=dtype),
        "wv": normal_init(ks[2], L + (D, KV * Dh), dtype=dtype),
        "wo": normal_init(ks[3], L + (H * Dh, D), out_scale, dtype=dtype),
    }


def init_mlp(key, d_model, d_ff, kind="swiglu", n_layers=None, n_scale_layers=24,
             dtype=jnp.float32):
    L = () if n_layers is None else (n_layers,)
    ks = jax.random.split(key, 2)
    out_scale = 0.02 / math.sqrt(2 * n_scale_layers)
    p = {"w2": normal_init(ks[1], L + (d_ff, d_model), out_scale, dtype=dtype)}
    if kind == "swiglu":
        p["w13"] = normal_init(ks[0], L + (d_model, 2 * d_ff), dtype=dtype)
    else:
        p["w1"] = normal_init(ks[0], L + (d_model, d_ff), dtype=dtype)
    return p
