"""Unified model: one init/train/prefill/decode API across all 10 assigned
architectures (dense / moe / ssm / hybrid / audio / vlm).

Layers are **scanned** (stacked ``(L, ...)`` weights) so HLO size and compile
time are O(1) in depth; the train path wraps the scan body in
``jax.checkpoint`` (nothing saveable) for activation rematerialization.

Caches:
  * transformer: ``{"k","v": (L, B, Smax, KV, Dh)}`` + scalar ``pos``;
    SWA archs use a ring buffer of length ``window``.
  * rwkv6:      ``{shift, wkv, cshift}`` stacked over L (O(1) in sequence).
  * hybrid:     mamba ``{ssm, conv}`` + shared-attn KV slots.
  * audio:      decoder self-attn KV + precomputed cross-attn KV.
"""
from __future__ import annotations

import math
import os
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from ..dist.sharding import constrain, constrain_residual
from . import rwkv6 as rwkv_mod
from . import mamba2 as mamba_mod
from .layers import (apply_rope, attention, attn_out, attn_qkv, decode_attention,
                     init_attn, init_mlp, mlp, normal_init, rmsnorm)
from .moe import init_moe, moe_mlp

Params = Dict[str, Any]


def scan_unroll():
    """Layer-scan unroll factor. The dry-run sets REPRO_SCAN_UNROLL=full so
    ``cost_analysis()`` sees straight-line HLO (XLA does not multiply while-
    loop bodies by trip count); training keeps the rolled scan for O(1)
    compile time."""
    v = os.environ.get("REPRO_SCAN_UNROLL", "1")
    return True if v == "full" else int(v)


def _scan(body, carry, xs, **kw):
    return jax.lax.scan(body, carry, xs, unroll=scan_unroll(), **kw)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg, key, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    params: Params = {"embed": {"tok": normal_init(ks[0], (V, D), dtype=dtype)},
                      "final_norm": jnp.ones((D,), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(ks[1], (D, V), dtype=dtype)

    if cfg.family == "ssm" and cfg.rwkv is not None:
        params["layers"] = {"rwkv": rwkv_mod.init_rwkv_layer(ks[2], cfg, L, dtype)}
        return params

    if cfg.family == "hybrid":
        params["layers"] = {"mamba": mamba_mod.init_mamba_layer(ks[2], cfg, L, dtype)}
        params["shared"] = {
            "ln1": jnp.ones((D,), dtype),
            "attn": init_attn(ks[3], cfg, None, dtype),
            "ln2": jnp.ones((D,), dtype),
            "mlp": init_mlp(ks[4], D, cfg.d_ff, cfg.mlp, None, cfg.n_layers, dtype),
        }
        return params

    # transformer families (dense / moe / vlm / audio-decoder)
    layers: Params = {
        "ln1": jnp.ones((L, D), dtype),
        "attn": init_attn(ks[2], cfg, L, dtype),
        "ln2": jnp.ones((L, D), dtype),
    }
    if cfg.moe is not None:
        layers["moe"] = init_moe(ks[3], cfg, L, dtype)
    else:
        layers["mlp"] = init_mlp(ks[3], D, cfg.d_ff, cfg.mlp, L, cfg.n_layers, dtype)
    if cfg.encoder is not None:   # whisper: cross-attention + encoder stack
        layers["xattn"] = init_attn(ks[4], cfg, L, dtype)
        Le = cfg.encoder.n_layers
        params["encoder"] = {
            "layers": {
                "ln1": jnp.ones((Le, D), dtype),
                "attn": init_attn(ks[5], cfg, Le, dtype),
                "ln2": jnp.ones((Le, D), dtype),
                "mlp": init_mlp(ks[6], D, cfg.d_ff, cfg.mlp, Le, cfg.n_layers, dtype),
            },
            "final_norm": jnp.ones((D,), dtype),
        }
        params["pos_emb"] = normal_init(ks[7], (min(cfg.max_seq, 32_768), D), 0.01, dtype)
    params["layers"] = layers
    return params


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens, compute_dtype):
    x = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(compute_dtype)
    return x * math.sqrt(cfg.d_model) if cfg.family == "audio" else x


def lm_logits(cfg, params, x):
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return constrain(logits, "batch", None, "vocab")


def _build_inputs(cfg, params, batch, compute_dtype):
    """Token embeddings, with modality-stub embeddings (vlm/audio) prepended."""
    x = embed_tokens(cfg, params, batch["tokens"], compute_dtype)
    if cfg.vlm is not None and "embeds" in batch:
        x = jnp.concatenate([batch["embeds"].astype(compute_dtype), x], axis=1)
    return constrain(x, "batch", None, None)


# ---------------------------------------------------------------------------
# transformer stack (train / prefill / decode)
# ---------------------------------------------------------------------------

def _pin_kv(cfg, k):
    """Prefill: the decode cache is sequence-sharded; without pinning, that
    constraint propagates back into the attention contraction and every
    q-chunk all-reduces partial outputs (measured 80 GB/step on glm4 prefill,
    §Perf S1). Pin kv head-sharded when divisible, else replicated-heads
    (GQA kv is tiny); the cache reshard then happens once per layer."""
    from ..dist.sharding import tp_size
    ax = "act_model" if cfg.n_kv_heads % max(tp_size(), 1) == 0 else None
    return constrain(k, "batch", None, ax, None)


def _txf_layer(cfg, x, lp, positions, enc_out, aux, pin_kv=False):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn_qkv(h, lp["attn"], cfg)
    if cfg.family != "audio":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if pin_kv:
        k = _pin_kv(cfg, k)
        v = _pin_kv(cfg, v)
    o = attention(q, k, v, causal=True, window=cfg.sliding_window)
    x = constrain_residual(x + attn_out(o, lp["attn"]))
    if enc_out is not None:
        h = rmsnorm(x, lp["ln_x"], cfg.norm_eps) if "ln_x" in lp else rmsnorm(
            x, lp["ln2"], cfg.norm_eps)
        qx, _, _ = attn_qkv(h, lp["xattn"], cfg)
        _, kx, vx = attn_qkv(enc_out, lp["xattn"], cfg)
        ox = attention(qx, kx, vx, causal=False)
        x = x + attn_out(ox, lp["xattn"])
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y, a = moe_mlp(h, lp["moe"], cfg)
        aux = aux + a
    else:
        y = mlp(h, lp["mlp"], cfg.mlp, cfg.tp_fuse)
    x = constrain_residual(x + y)
    return x, aux, (k, v)


def _encoder_forward(cfg, params, enc_embeds, compute_dtype):
    x = enc_embeds.astype(compute_dtype)

    def body(carry, lp):
        x = carry
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(h, lp["attn"], cfg)
        x = x + attn_out(attention(q, k, v, causal=False), lp["attn"])
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp(h, lp["mlp"], cfg.mlp, cfg.tp_fuse), None

    x, _ = _scan(body, x, params["encoder"]["layers"])
    return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def _txf_stack(cfg, params, x, positions, enc_out, *, remat: bool,
               collect_cache: bool):
    """Scan over stacked transformer layers. Returns (x, aux, cache_or_None)."""

    def body(carry, lp):
        x, aux = carry
        x, aux, kv = _txf_layer(cfg, x, lp, positions, enc_out, aux,
                                pin_kv=collect_cache)
        ys = None
        if collect_cache:
            k, v = kv
            if enc_out is not None:
                _, kx, vx = attn_qkv(enc_out, lp["xattn"], cfg)
                ys = (k, v, kx, vx)
            else:
                ys = (k, v)
        return (x, aux), ys

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), ys = _scan(body, (x, jnp.float32(0.0)), params["layers"])
    cache = None
    if collect_cache:
        if enc_out is not None:
            cache = {"k": ys[0], "v": ys[1], "ck": ys[2], "cv": ys[3]}
        else:
            cache = {"k": ys[0], "v": ys[1]}
        cache = {n: constrain(c, None, "batch", "cache_seq", None, None)
                 for n, c in cache.items()}
    return x, aux, cache


def _txf_decode(cfg, params, x, cache, pos, enc_out):
    """Single-token decode through the scanned stack, updating the KV cache."""
    positions = jnp.array([0]) if cfg.family == "audio" else None
    window = cfg.sliding_window
    Smax = cache["k"].shape[2]
    write_pos = jnp.mod(pos, Smax) if window is not None else pos
    rope_pos = jnp.reshape(pos, (1,))

    def body(carry, xs):
        x = carry
        if "ck" in cache:
            lp, kc, vc, ckc, cvc = xs
        else:
            lp, kc, vc = xs
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(h, lp["attn"], cfg)
        if cfg.family != "audio":
            q = apply_rope(q, rope_pos, cfg.rope_theta)
            k = apply_rope(k, rope_pos, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, write_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, write_pos, 0, 0))
        o = decode_attention(q, kc, vc, pos, window=window)
        x = x + attn_out(o, lp["attn"])
        if "ck" in cache:
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            qx, _, _ = attn_qkv(h, lp["xattn"], cfg)
            ox = decode_attention(qx, ckc, cvc, jnp.int32(ckc.shape[1] - 1))
            x = x + attn_out(ox, lp["xattn"])
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, _ = moe_mlp(h, lp["moe"], cfg)
        else:
            y = mlp(h, lp["mlp"], cfg.mlp, cfg.tp_fuse)
        ys = (kc, vc, ckc, cvc) if "ck" in cache else (kc, vc)
        return x + y, ys

    xs = (params["layers"], cache["k"], cache["v"])
    if "ck" in cache:
        xs = xs + (cache["ck"], cache["cv"])
    x, ys = _scan(body, x, xs)
    new_cache = dict(zip(("k", "v", "ck", "cv"), ys)) if "ck" in cache else \
        {"k": ys[0], "v": ys[1]}
    return x, new_cache


# ---------------------------------------------------------------------------
# rwkv / hybrid stacks
# ---------------------------------------------------------------------------

def _rwkv_stack(cfg, params, x, state, *, remat: bool):
    def body(carry, xs):
        x = carry
        lp, st = xs
        x, st = rwkv_mod.rwkv_block(x, lp["rwkv"], cfg, st)
        return x, st
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_state = _scan(body, x, (params["layers"], state))
    return x, new_state


def _shared_block(cfg, sp, x, positions):
    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
    q, k, v = attn_qkv(h, sp["attn"], cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    x = x + attn_out(attention(q, k, v, causal=True), sp["attn"])
    h = rmsnorm(x, sp["ln2"], cfg.norm_eps)
    return x + mlp(h, sp["mlp"], cfg.mlp, cfg.tp_fuse), (k, v)


def _hybrid_stack(cfg, params, x, state, positions, *, remat: bool,
                  collect_cache: bool):
    """Zamba2: scanned Mamba2 layers; shared attn block every Nth layer."""
    every = cfg.shared_attn_every
    n_slots = cfg.n_layers // every
    sp = params["shared"]
    B, S = x.shape[0], x.shape[1]
    KV, Dh = cfg.n_kv_heads, cfg.d_head

    def body(carry, xs):
        x = carry
        i, lp, st = xs
        x, st = mamba_mod.mamba_block(x, lp["mamba"], cfg, st)
        apply_shared = (i % every) == (every - 1)

        def yes(x):
            return _shared_block(cfg, sp, x, positions)

        def no(x):
            zkv = (jnp.zeros((B, S, KV, Dh), x.dtype),) * 2
            return x, zkv
        x, kv = jax.lax.cond(apply_shared, yes, no, x)
        ys = (st, kv, apply_shared) if collect_cache else (st,)
        return x, ys

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    idx = jnp.arange(cfg.n_layers)
    x, ys = _scan(body, x, (idx, params["layers"], state))
    new_state = ys[0]
    cache = None
    if collect_cache:
        kv, flags = ys[1], ys[2]
        # keep only the slots where the shared block ran: (n_slots, B, S, KV, Dh)
        sel = jnp.nonzero(flags, size=n_slots)[0]
        cache = {"k": jnp.take(kv[0], sel, axis=0), "v": jnp.take(kv[1], sel, axis=0)}
        cache = {n: constrain(c, None, "batch", "cache_seq", None, None)
                 for n, c in cache.items()}
    return x, new_state, cache


def _hybrid_decode(cfg, params, x, cache, pos):
    every = cfg.shared_attn_every
    sp = params["shared"]
    rope_pos = jnp.reshape(pos, (1,))
    kc_all, vc_all = cache["k"], cache["v"]          # (n_slots, B, Smax, KV, Dh)

    def body(carry, xs):
        x, kc_all, vc_all = carry
        i, lp, st = xs
        x, st = mamba_mod.mamba_block(x, lp["mamba"], cfg, st)
        apply_shared = (i % every) == (every - 1)
        slot = i // every

        def yes(args):
            x, kc_all, vc_all = args
            h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(h, sp["attn"], cfg)
            q = apply_rope(q, rope_pos, cfg.rope_theta)
            k = apply_rope(k, rope_pos, cfg.rope_theta)
            kc = jax.lax.dynamic_slice_in_dim(kc_all, slot, 1, 0)[0]
            vc = jax.lax.dynamic_slice_in_dim(vc_all, slot, 1, 0)[0]
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
            o = decode_attention(q, kc, vc, pos)
            x = x + attn_out(o, sp["attn"])
            h = rmsnorm(x, sp["ln2"], cfg.norm_eps)
            x = x + mlp(h, sp["mlp"], cfg.mlp, cfg.tp_fuse)
            kc_all = jax.lax.dynamic_update_slice_in_dim(kc_all, kc[None], slot, 0)
            vc_all = jax.lax.dynamic_update_slice_in_dim(vc_all, vc[None], slot, 0)
            return x, kc_all, vc_all

        x, kc_all, vc_all = jax.lax.cond(apply_shared, yes, lambda a: a,
                                         (x, kc_all, vc_all))
        return (x, kc_all, vc_all), st

    idx = jnp.arange(cfg.n_layers)
    (x, kc_all, vc_all), new_state = _scan(
        body, (x, kc_all, vc_all), (idx, params["layers"], cache["state"]))
    return x, {"k": kc_all, "v": vc_all, "state": new_state}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def forward_train(cfg, params, batch, compute_dtype=jnp.bfloat16, remat=True):
    """Returns (per-token mean loss, metrics dict). batch: tokens, targets,
    optional embeds / enc_embeds."""
    x = _build_inputs(cfg, params, batch, compute_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux = jnp.float32(0.0)
    if cfg.family == "ssm" and cfg.rwkv is not None:
        state = rwkv_mod.init_rwkv_state(cfg, x.shape[0], compute_dtype)
        x, _ = _rwkv_stack(cfg, params, x, state, remat=remat)
    elif cfg.family == "hybrid":
        state = mamba_mod.init_mamba_state(cfg, cfg.n_layers, x.shape[0], compute_dtype)
        x, _, _ = _hybrid_stack(cfg, params, x, state, positions, remat=remat,
                                collect_cache=False)
    else:
        enc_out = None
        if cfg.encoder is not None:
            enc_out = _encoder_forward(cfg, params, batch["enc_embeds"], compute_dtype)
            x = x + params["pos_emb"][:S].astype(compute_dtype)
        x, aux, _ = _txf_stack(cfg, params, x, positions, enc_out, remat=remat,
                               collect_cache=False)
    x = constrain(x, "batch", None, None)   # gather seq back from SP once
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    loss, metrics = chunked_cross_entropy(cfg, params, x, batch["targets"])
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux / cfg.n_layers
        metrics["aux_loss"] = aux / cfg.n_layers
    return loss, metrics


def chunked_cross_entropy(cfg, params, x, targets, chunk=512):
    """Sequence-chunked loss: the (B, chunk, V) logits slice is computed,
    reduced, and discarded inside a rematerialized scan, so the full
    (B, S, V) logits tensor never exists — the dominant memory saving for
    202k-vocab training (EXPERIMENTS.md §Perf)."""
    B, S, D = x.shape
    if S % chunk or S <= chunk:
        return cross_entropy(lm_logits(cfg, params, x), targets)
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)
    # gather/cast the head ONCE, explicitly replicated, outside the chunk
    # scan: otherwise the partitioner re-all-gathers the (D, V) head inside
    # every chunk's dot (§Perf P4b)
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    head = constrain(head.astype(x.dtype), "embed", "vocab")
    head = jax.ad_checkpoint.checkpoint_name(head, "ce_head")

    @partial(jax.checkpoint,
             policy=jax.checkpoint_policies.save_only_these_names("ce_head"))
    def body(carry, xs):
        xb, tb = xs
        logits = constrain(jnp.einsum("bsd,dv->bsv", xb, head),
                           "batch", None, "vocab")
        mask = (tb >= 0).astype(jnp.float32)
        tgt = jnp.maximum(tb, 0)
        lg = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        onehot = constrain(jax.nn.one_hot(tgt, lg.shape[-1], dtype=logits.dtype),
                           "batch", None, "vocab")
        ll = jnp.einsum("bsv,bsv->bs", logits, onehot,
                        preferred_element_type=jnp.float32)
        nll = jnp.sum((logz - ll) * mask)
        acc = jnp.sum((jnp.argmax(lg, -1) == tgt).astype(jnp.float32) * mask)
        c_nll, c_acc, c_n = carry
        return (c_nll + nll, c_acc + acc, c_n + mask.sum()), None

    (nll, acc, n), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), (xc, tc))
    n = jnp.maximum(n, 1.0)
    loss = nll / n
    return loss, {"loss": loss, "acc": acc / n, "tokens": n}


def cross_entropy(logits, targets):
    """logits (B,S,V); targets (B,S) int32, -100 = masked."""
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    # vocab-sharded label pick: one-hot stays (batch, seq, vocab)-sharded and
    # fuses into the reduce — never materialized replicated (DESIGN.md §6)
    onehot = constrain(jax.nn.one_hot(tgt, lg.shape[-1], dtype=logits.dtype),
                       "batch", None, "vocab")
    ll = jnp.einsum("bsv,bsv->bs", lg.astype(logits.dtype), onehot,
                    preferred_element_type=jnp.float32)
    nll = (logz - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    acc = (jnp.argmax(lg, -1) == tgt).astype(jnp.float32)
    return loss, {"loss": loss, "acc": (acc * mask).sum() / denom,
                  "tokens": mask.sum()}


def forward_prefill(cfg, params, batch, compute_dtype=jnp.bfloat16):
    """Process a full prompt; returns (last-token logits (B,V), cache)."""
    x = _build_inputs(cfg, params, batch, compute_dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)
    if cfg.family == "ssm" and cfg.rwkv is not None:
        state = rwkv_mod.init_rwkv_state(cfg, B, compute_dtype)
        x, state = _rwkv_stack(cfg, params, x, state, remat=False)
        cache = state
    elif cfg.family == "hybrid":
        state = mamba_mod.init_mamba_state(cfg, cfg.n_layers, B, compute_dtype)
        x, state, kv = _hybrid_stack(cfg, params, x, state, positions, remat=False,
                                     collect_cache=True)
        cache = {"state": state, **kv}
    else:
        enc_out = None
        if cfg.encoder is not None:
            enc_out = _encoder_forward(cfg, params, batch["enc_embeds"], compute_dtype)
            x = x + params["pos_emb"][:S].astype(compute_dtype)
        x, _, cache = _txf_stack(cfg, params, x, positions, enc_out, remat=False,
                                 collect_cache=True)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, cache


def forward_decode(cfg, params, cache, token, pos, compute_dtype=jnp.bfloat16):
    """One decode step. token: (B,1) int32; pos: scalar int32 (position being
    written). Returns (logits (B,1,V), new cache)."""
    x = embed_tokens(cfg, params, token, compute_dtype)
    if cfg.family == "audio":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos, 1, 0
                                             ).astype(compute_dtype)[None]
    if cfg.family == "ssm" and cfg.rwkv is not None:
        def body(carry, xs):
            x = carry
            lp, st = xs
            x, st = rwkv_mod.rwkv_block(x, lp["rwkv"], cfg, st)
            return x, st
        x, new_state = _scan(body, x, (params["layers"], cache))
        new_cache = new_state
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(cfg, params, x, cache, pos)
    else:
        x, new_cache = _txf_decode(cfg, params, x, cache, pos, None)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def cache_max_len(cfg, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Zero cache sized for decoding up to seq_len."""
    if cfg.family == "ssm" and cfg.rwkv is not None:
        return rwkv_mod.init_rwkv_state(cfg, batch, dtype)
    Smax = cache_max_len(cfg, seq_len)
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    if cfg.family == "hybrid":
        n_slots = cfg.n_layers // cfg.shared_attn_every
        return {
            "state": mamba_mod.init_mamba_state(cfg, cfg.n_layers, batch, dtype),
            "k": jnp.zeros((n_slots, batch, Smax, KV, Dh), dtype),
            "v": jnp.zeros((n_slots, batch, Smax, KV, Dh), dtype),
        }
    L = cfg.n_layers
    cache = {"k": jnp.zeros((L, batch, Smax, KV, Dh), dtype),
             "v": jnp.zeros((L, batch, Smax, KV, Dh), dtype)}
    if cfg.encoder is not None:
        Se = cfg.encoder.enc_seq
        cache["ck"] = jnp.zeros((L, batch, Se, KV, Dh), dtype)
        cache["cv"] = jnp.zeros((L, batch, Se, KV, Dh), dtype)
    return cache
