"""Fault-tolerant checkpointing (paper's archival + provenance discipline
applied to training state).

  * every leaf saved as .npy with a fletcher64 checksum in the step manifest
    (corrupted restores fail loudly — the paper's transfer-integrity rule)
  * provenance JSON (who/when/config digest) beside every step
  * async save (a training step never waits on disk)
  * elastic restore: leaves are saved with *global* shapes, so a checkpoint
    written on one mesh restores onto any other mesh/sharding (node-failure
    recovery: restart with fewer/more pods)
  * cold-tier archival mirrors steps into a TieredStore (Glacier analogue)
"""
from __future__ import annotations

import json
import re
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..core.integrity import IntegrityError, fletcher64
from ..core.provenance import make_provenance


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, tmpl in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: Path, step: int, tree, *, digest: str = "",
                    extra: Optional[Dict[str, Any]] = None) -> Path:
    """Write one step synchronously. Returns the step directory."""
    t0 = time.time()
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = step_dir.with_suffix(".tmp")
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    sums = {}
    for key, arr in flat.items():
        fn = key.replace("/", "__") + ".npy"
        np.save(tmp / fn, arr)
        sums[key] = {"file": fn, "fletcher64": fletcher64(arr),
                     "shape": list(arr.shape), "dtype": str(arr.dtype)}
    manifest = {"step": step, "leaves": sums, "extra": extra or {}}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    make_provenance("checkpoint", digest, {}, {k: str(v["fletcher64"])
                                               for k, v in sums.items()},
                    t0).save(tmp)
    if step_dir.exists():
        import shutil
        shutil.rmtree(step_dir)
    tmp.rename(step_dir)          # atomic publish: partial writes never count
    return step_dir


def restore_checkpoint(ckpt_dir: Path, template, step: Optional[int] = None,
                       shardings=None):
    """Restore (optionally onto a new mesh via ``shardings`` — elastic)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(step_dir / info["file"])
        want = np.dtype(info["dtype"])      # ml_dtypes names (e.g. bfloat16)
        if arr.dtype != want:
            arr = arr.view(want)            # np.save stores bf16 as void16
        if fletcher64(arr) != info["fletcher64"]:
            raise IntegrityError(f"checkpoint leaf {key} corrupted "
                                 f"(step {step})")
        flat[key] = arr
    tree = _unflatten_like(template, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step, manifest.get("extra", {})


def latest_step(ckpt_dir: Path) -> Optional[int]:
    steps = []
    for p in Path(ckpt_dir).glob("step_*"):
        m = re.match(r"step_(\d+)$", p.name)
        if m and (p / "manifest.json").exists():
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class CheckpointManager:
    """Async save + retention + optional cold-tier archival."""

    def __init__(self, ckpt_dir: Path, *, keep: int = 3, digest: str = "",
                 cold_store=None):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self.digest = digest
        self.cold_store = cold_store
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree, extra=None):
        self.wait()                     # one in-flight save at a time
        host_tree = jax.tree.map(np.asarray, tree)   # device->host copy now

        def work():
            try:
                step_dir = save_checkpoint(self.ckpt_dir, step, host_tree,
                                           digest=self.digest, extra=extra)
                self._gc()
                if self.cold_store is not None:
                    for f in step_dir.iterdir():
                        self.cold_store.put(f, f"ckpt/{step_dir.name}/{f.name}",
                                            tier="cold")
            except BaseException as e:   # noqa: BLE001 — surfaced via wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(p for p in self.ckpt_dir.glob("step_*") if p.is_dir())
        for p in steps[:-self.keep]:
            import shutil
            shutil.rmtree(p, ignore_errors=True)

    def restore_latest(self, template, shardings=None):
        self.wait()
        return restore_checkpoint(self.ckpt_dir, template, shardings=shardings)
