#!/usr/bin/env python
"""Check that every intra-repo markdown link resolves.

Scans ``docs/*.md`` plus the repo-root ``*.md`` files for inline links
(``[text](target)``) and reference definitions (``[ref]: target``), and
fails listing every relative target that does not exist on disk. External
schemes (http/https/mailto) and pure in-page anchors are skipped; a
``path#anchor`` target is checked for the file part only.

Run locally:  python tools/check_links.py
CI runs it in the ``docs`` job — a doc that names a file that moved breaks
the build, not the next reader.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# [text](target) — target up to the first unescaped ')'; plus [ref]: target
_INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_REFDEF = re.compile(r"^\[[^\]]+\]:\s+(\S+)", re.M)
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def md_files() -> list[Path]:
    files = sorted(REPO.glob("*.md")) + sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.is_file()]


def strip_code(text: str) -> str:
    """Drop fenced and inline code spans: links in code are examples."""
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return re.sub(r"`[^`\n]*`", "", text)


def check(files: list[Path]) -> list[str]:
    broken: list[str] = []
    for f in files:
        text = strip_code(f.read_text())
        targets = _INLINE.findall(text) + _REFDEF.findall(text)
        for raw in targets:
            if raw.startswith(_SKIP_SCHEMES) or raw.startswith("#"):
                continue
            path = raw.split("#", 1)[0]
            if not path:
                continue
            resolved = (f.parent / path).resolve()
            if not resolved.exists():
                broken.append(f"{_rel(f)}: [{raw}] -> {_rel(resolved)} missing")
    return broken


def _rel(p: Path) -> str:
    return str(p.relative_to(REPO)) if p.is_relative_to(REPO) else str(p)


def main() -> int:
    files = md_files()
    broken = check(files)
    n_links = sum(len(_INLINE.findall(strip_code(f.read_text())))
                  + len(_REFDEF.findall(strip_code(f.read_text())))
                  for f in files)
    if broken:
        print(f"{len(broken)} broken intra-repo link(s) "
              f"across {len(files)} markdown files:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"all intra-repo links resolve "
          f"({len(files)} files, {n_links} link targets scanned)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
