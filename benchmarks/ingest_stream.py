"""Chunked streaming ingest vs load-then-verify, verification included.

The paper's ingest path pays the storage link once to move each volume and
then the host again to verify it (sha256 + fast QA) — two sequential costs
per byte. ``repro.core.stream`` chunks the transfer so the incremental
sha256 and the chunk-accumulating fused QA fold run *while* the next chunk
is still on the link (a prefetch thread keeps the link busy). This bench
measures exactly that overlap on one machine with the paper's 0.60 Gb/s
lab-network storage model:

* **load-then-verify arm** — each file's bytes cross the modelled link
  first (per-chunk sleep at 0.60 Gb/s), then the host hashes them and runs
  the one-shot QA+checksum fold. Verification is INCLUDED in the timing —
  this is the honest sequential baseline, not a strawman read-only loop.
* **chunked arm** — the same files, same modelled link, same verification
  work, but driven through ``stream_chunks`` with the prefetching reader:
  hash+fold of chunk *n* overlap the link time of chunk *n+1*.

Both arms produce the sha256 and the full QAStats for every file; the
bench asserts they are identical across arms (same bytes, same verdicts).

Acceptance gates (checked here and in CI; a regression fails loud):

* chunked-arm effective Gb/s (verification included) >= the
  load-then-verify arm's — overlap must never cost throughput;
* the chunked fold is bit-identical to the one-shot ``qa_stats`` kernel on
  an oracle sweep of shapes x chunk sizes (incl. chunk > volume and
  non-dividing tails, NaN/Inf), on both the host and device backends.

Writes ``benchmarks/out/ingest_stream.json`` (CI artifact; override with
``REPRO_BENCH_JSON``). Runs thread-pinned in a subprocess (see ``_pin``).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import tempfile
import time
from pathlib import Path

import numpy as np

from ._pin import run_pinned

N_FILES = 24
SHAPE = (64, 64, 64)                # 1 MiB float32 per volume (paper-scale:
                                    # link speed, not per-file overhead,
                                    # decides the comparison)
CHUNK_BYTES = 128 << 10             # several chunks per volume
PAPER_REFERENCE_GBPS = {"lab_network": 0.60, "cloud_storage": 0.33}
MODEL_STORAGE_GBPS = PAPER_REFERENCE_GBPS["lab_network"]

_INPROC_FLAG = "REPRO_INGEST_STREAM_BENCH_INPROC"
_JSON_OUT = Path(__file__).resolve().parent / "out" / "ingest_stream.json"


def _link_gbps(nbytes: int, seconds: float) -> float:
    return nbytes * 8 / seconds / 1e9 if seconds > 0 else 0.0


def _throttled_chunks(path: Path, chunk_bytes: int):
    """The modelled 0.60 Gb/s storage link: every chunk pays its wire time
    before it lands. Runs inside the prefetch thread in the chunked arm, so
    the sleep is exactly the window the consumer has to hash+fold."""
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk_bytes)
            if not b:
                return
            time.sleep(len(b) * 8 / (MODEL_STORAGE_GBPS * 1e9))
            yield b


def _oracle_sweep():
    """Bit-exactness gate: chunked fold == one-shot qa_stats across shapes,
    chunk sizes (incl. chunk > volume, non-dividing tails), NaN/Inf, both
    backends. Any mismatch raises — wrong-but-fast is a failure."""
    from repro.kernels.checksum import QAChecksumAccumulator, qa_stats
    rng = np.random.default_rng(11)
    cases = 0
    for shape in [(1,), (16, 16, 16), (33, 7), (1025,)]:
        vol = rng.normal(80, 25, shape).astype(np.float32)
        if vol.size > 4:
            vol.flat[1] = np.nan
            vol.flat[vol.size - 1] = np.inf
        ref = qa_stats(vol, interpret=True)
        data = vol.tobytes()
        for chunk in (7, 4096, 1 << 30):
            for backend in ("host", "device"):
                acc = QAChecksumAccumulator(vol.size, vol.dtype,
                                            backend=backend, interpret=True)
                for off in range(0, len(data), chunk):
                    acc.update(data[off:off + chunk])
                got = acc.finalize()
                if got != ref:
                    raise RuntimeError(
                        f"chunked fold diverged from one-shot kernel: "
                        f"shape={shape} chunk={chunk} backend={backend}: "
                        f"{got} != {ref}")
                cases += 1
    return cases


def _run_inproc():
    from repro.core.stream import _Prefetcher, stream_chunks
    from repro.kernels.checksum import QAChecksumAccumulator

    oracle_cases = _oracle_sweep()
    rows = [("ingest_stream_oracle_cases", oracle_cases,
             "chunked-fold vs one-shot kernel bit-exactness sweep (all ok)")]

    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        rng = np.random.default_rng(0)
        files = []
        for i in range(N_FILES):
            vol = rng.normal(100, 20, SHAPE).astype(np.float32)
            if i % 5 == 0:                       # QA work is not all-accept
                vol.flat[i] = np.nan
            p = td / f"vol-{i:03d}.npy"
            np.save(p, vol)
            files.append(p)
        total_bytes = sum(p.stat().st_size for p in files)

        # -- load-then-verify arm: link, THEN hash, THEN one-shot fold ------
        baseline = {}
        t0 = time.perf_counter()
        for p in files:
            data = b"".join(_throttled_chunks(p, CHUNK_BYTES))
            digest = hashlib.sha256(data).hexdigest()
            arr = np.load(io.BytesIO(data), allow_pickle=False)
            acc = QAChecksumAccumulator(arr.size, arr.dtype, backend="host")
            acc.update(arr.tobytes())
            baseline[p.name] = (digest, acc.finalize())
        base_s = time.perf_counter() - t0

        # -- chunked arm: identical link + verification, overlapped --------
        streamed = {}
        read_s = hash_s = 0.0
        t0 = time.perf_counter()
        for p in files:
            pf = _Prefetcher(_throttled_chunks(p, CHUNK_BYTES))
            _, digest, qa, rep = stream_chunks(
                pf, npy_qa=True, chunk_bytes=CHUNK_BYTES,
                qa_backend="host", prefetch=pf)
            streamed[p.name] = (digest, qa)
            read_s += rep.read_s
            hash_s += rep.hash_s
        stream_s = time.perf_counter() - t0

        if streamed != baseline:
            diff = [n for n in baseline if streamed.get(n) != baseline[n]]
            raise RuntimeError(
                f"chunked arm diverged from load-then-verify on {diff}")
        if any(qa is None for _, qa in streamed.values()):
            raise RuntimeError("chunked arm skipped QA on some file")

        base_gbps = round(_link_gbps(total_bytes, base_s), 3)
        stream_gbps = round(_link_gbps(total_bytes, stream_s), 3)
        overlap_s = round(base_s - stream_s, 3)
        rows += [
            ("ingest_stream_baseline_gbps", base_gbps,
             f"load-then-verify Gb/s (verification included) over the "
             f"{MODEL_STORAGE_GBPS} Gb/s-modelled link"),
            ("ingest_stream_chunked_gbps", stream_gbps,
             "chunked in-flight-verify Gb/s (verification included), "
             "same link model"),
            ("ingest_stream_overlap_saved_s", overlap_s,
             f"wall seconds the overlap pipeline saved on "
             f"{N_FILES} x {SHAPE} volumes"),
        ]

        # gate: overlap must never cost throughput
        if stream_gbps < base_gbps:
            raise RuntimeError(
                f"chunked ingest {stream_gbps} Gb/s fell below "
                f"load-then-verify {base_gbps} Gb/s — streaming regression")

    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "files": N_FILES, "shape": list(SHAPE), "chunk_bytes": CHUNK_BYTES,
        "total_bytes": total_bytes,
        "model_storage_gbps": MODEL_STORAGE_GBPS,
        "paper_reference_gbps": PAPER_REFERENCE_GBPS,
        "baseline": {"seconds": round(base_s, 3), "gbps": base_gbps},
        "chunked": {"seconds": round(stream_s, 3), "gbps": stream_gbps,
                    "read_s": round(read_s, 3), "hash_s": round(hash_s, 3)},
        "oracle_cases": oracle_cases,
        "gate": {"chunked_not_slower": True, "bit_exact_oracle": True,
                 "digests_identical_across_arms": True},
        "rows": [[n, v, d] for n, v, d in rows],
    }, indent=1))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.ingest_stream", "ingest_stream_",
                      _INPROC_FLAG, _run_inproc, timeout=900)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
