"""Shared cache-stat aggregation for the placement benchmarks
(``locality_throughput``, ``campaign_plan``): both gate on the same
hit-rate / bytes-from-storage accounting, so the aggregation lives once —
a change to ``ClusterRunner.stats.cache_by_node`` lands in both gates or
in neither."""
from __future__ import annotations


def cache_totals(runner) -> dict:
    """Sum the per-node cache counters of a finished ``ClusterRunner``."""
    totals: dict = {}
    for st in (runner.stats.cache_by_node or {}).values():
        for k, v in st.items():
            if isinstance(v, (int, float)):    # skip per-addr byte maps
                totals[k] = totals.get(k, 0) + v
    return totals


def hit_rate(totals: dict) -> float:
    lookups = totals.get("hits", 0) + totals.get("misses", 0)
    return totals.get("hits", 0) / lookups if lookups else 0.0
