"""Wall-clock step time of the reduced-config training step per family
(CPU — relative numbers; the TPU projection lives in the roofline table)."""
from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.data import make_lm_batches
from repro.train import OptConfig, init_train_state, make_train_step

ARCHS = ("llama3.2-1b", "rwkv6-1.6b", "zamba2-1.2b", "moonshot-v1-16b-a3b")


def run():
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, OptConfig()))
        batch = make_lm_batches(cfg, 2, 128, 1)[0]
        params, opt, m = step(params, opt, batch)       # compile + warm
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        n = 3
        for _ in range(n):
            params, opt, m = step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / n * 1e6
        rows.append((f"train_step_{arch}_us", round(us), "reduced config, B=2 S=128"))
    return rows
