"""Shared throughput-mode scaffolding for the executor benchmarks.

The sweep must execute with XLA/BLAS intra-op parallelism pinned to one
thread — so each unit's compute occupies one core and worker/node scaling,
not operator-level thread contention, is what gets measured — and the pin
flags must apply *before* jax initializes. ``run_pinned`` therefore re-execs
the bench module in a subprocess carrying the pin env plus an in-proc flag,
and parses the child's ``name,value,derived`` CSV rows back out. One copy of
the flags and the parser, shared by every bench that needs pinning.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path
from typing import Callable, List, Tuple

PIN_ENV = {
    "XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false "
                 "intra_op_parallelism_threads=1",
    "OMP_NUM_THREADS": "1",
    "OPENBLAS_NUM_THREADS": "1",
    "MKL_NUM_THREADS": "1",
}

Row = Tuple[str, float, str]


def run_pinned(module: str, prefix: str, inproc_flag: str,
               inproc: Callable[[], List[Row]],
               timeout: float = 1200) -> List[Row]:
    """Run ``inproc()`` inside a thread-pinned re-exec of ``module``.

    In the child (``inproc_flag`` set) this calls ``inproc`` directly; in the
    parent it spawns ``python -m module`` with the pin env and collects the
    child's stdout rows whose name starts with ``prefix``.
    """
    if os.environ.get(inproc_flag):
        return inproc()
    env = dict(os.environ, **PIN_ENV, **{inproc_flag: "1"})
    proc = subprocess.run(
        [sys.executable, "-m", module],
        env=env, cwd=Path(__file__).resolve().parents[1],
        capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"pinned bench subprocess failed:\n{proc.stderr}")
    rows: List[Row] = []
    for line in proc.stdout.splitlines():
        if line.startswith(prefix):
            name, value, derived = line.split(",", 2)
            rows.append((name, float(value), derived))
    return rows
