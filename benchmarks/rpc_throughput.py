"""Socket-transport throughput: cold vs warm per-host input cache, plus the
coordinator hot path at six-figure backlog depth.

The paper's cost case hinges on the storage->compute link (0.60 Gb/s lab
network vs 0.33 Gb/s cloud); the RPC cluster keeps that link off the
coordinator socket (control plane only) and shortens it with the per-host
content-addressed cache (``repro.dist.cache``). This bench measures the
data plane and the control plane:

* **Fetch stage, cold vs warm** (arm ``fetch``) — per-unit input
  fetch+verify latency and Gb/s through ``safe_load_unit_inputs`` with a
  fresh cache (miss: read shared storage, hash, insert) and a warm one
  (hit: read node-local blob, re-hash, skip storage + insert). Warm must be
  strictly below cold — this is an acceptance gate, checked in-process and
  recorded in the JSON. On one machine both "links" are the same disk, so
  the gap here is the cache's *overheadless* floor; on a real cluster the
  cold path crosses the network and the gap widens to the paper's
  0.60-vs-0.33 framing.
* **End-to-end over the wire** (arm ``e2e``) — a 32-unit run through
  ``ClusterRunner`` with ``transport="rpc"`` (every lease/complete/heartbeat
  is an RPC) plus one *separate-process* worker joined via
  ``python -m repro.dist.rpc work``, cold then warm cache. Reported as
  images/s and input-Gb/s; provenance ``cache_hit`` counts come along so the
  artifact shows the warm run really was served locally.
* **Coordinator hot path** (arm ``hotpath``) — a synthetic 100k-unit
  backlog (``REPRO_BENCH_BACKLOG_UNITS`` overrides) drained by four nodes
  through batched grants/completes while a heartbeat thread pushes summary
  deltas and measures its own latency. Two queue builds race: the shipped
  index-backed :class:`~repro.dist.queue.WorkQueue` and a reconstruction of
  the pre-index coordinator (Bloom re-probe per score, blind FIFO fill and
  blind tail-half steal past its 512-entry scan cap). The acceptance gate:
  the index-backed queue must grant strictly faster *and* hold heartbeat
  p99 latency strictly lower — the cap's placement blindness was the bug,
  but the fix has to pay for itself on the same lock. A socket micro-arm
  rides along, draining 2048 units per-op over JSON-lines vs batched over
  binary frames.

``REPRO_RPC_BENCH_ARMS`` (comma list, default ``fetch,e2e,hotpath``)
selects arms, so CI can split the data-plane and control-plane runs across
matrix entries. Runs in a thread-pinned subprocess like the other executor
benches (see ``_pin``); writes ``benchmarks/out/rpc_throughput.json`` (CI
artifact; override with ``REPRO_BENCH_JSON``).
"""
from __future__ import annotations

import json
import os
import shutil
import statistics
import subprocess
import sys
import tempfile
import threading
import time
from collections import deque
from pathlib import Path

from ._pin import run_pinned

N_SUBJECTS = 16
SESSIONS = 2                        # 32 units
SHAPE = (64, 64, 64)                # 1 MiB float32 input per unit
PIPELINE = "bias_correct"
FETCH_REPS = 5
# the paper's storage->compute link speeds (§3): the lab-network setup the
# cost argument depends on, and the cloud-storage baseline it beats. Keeping
# both in the artifact makes the repo's effective Gb/s trajectory comparable
# across PRs against a fixed yardstick.
PAPER_REFERENCE_GBPS = {"lab_network": 0.60, "cloud_storage": 0.33}

ARMS_ENV = "REPRO_RPC_BENCH_ARMS"
DEFAULT_ARMS = "fetch,e2e,hotpath"

HOTPATH_UNITS_ENV = "REPRO_BENCH_BACKLOG_UNITS"
HOTPATH_UNITS = 100_000
HOTPATH_NODES = 4
HOTPATH_BATCH = 32                  # grants/completes per round trip
HOTPATH_DEADLINE_S = 300.0          # hard stop per queue variant
WIRE_UNITS = 2048                   # socket micro-arm backlog

_INPROC_FLAG = "REPRO_RPC_BENCH_INPROC"
_JSON_OUT = Path(__file__).resolve().parent / "out" / "rpc_throughput.json"


def _median_fetch(units, root, cache):
    """Per-unit fetch+verify latency (s) and total bytes through the stage."""
    from repro.core.workflow import safe_load_unit_inputs
    lats = []
    nbytes = 0
    for u in units:
        t0 = time.perf_counter()
        loaded = safe_load_unit_inputs(u, root, cache=cache)
        lats.append(time.perf_counter() - t0)
        assert loaded is not None
        nbytes += sum(a.nbytes for a in loaded[0].values())
    return statistics.median(lats), nbytes, sum(lats)


def _spawn_worker(addr: str, data_root: Path, cache_dir: Path):
    env = dict(os.environ,
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""),
               REPRO_CACHE_DIR=str(cache_dir))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.dist.rpc", "work", "--addr", addr,
         "--pipeline", PIPELINE, "--data-root", str(data_root),
         "--node-id", "bench-ext"],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)


def _run_fetch(td: Path, ds, units, rows, report):
    from repro.dist import BlobServer, InputCache, PeerFabric

    # -- fetch stage: cold vs warm, interleaved medians ----------------------
    cold_meds, warm_meds = [], []
    gb = 0.0
    cold_total = warm_total = 0.0
    for rep in range(FETCH_REPS):
        cache = InputCache(td / f"cache-{rep}", max_bytes=1 << 30)
        cold, nbytes, cold_sum = _median_fetch(units, ds.root, cache)
        warm, _, warm_sum = _median_fetch(units, ds.root, cache)
        cold_meds.append(cold)
        warm_meds.append(warm)
        cold_total += cold_sum
        warm_total += warm_sum
        gb = nbytes * 8 / 1e9
    cold_ms = statistics.median(cold_meds) * 1e3
    warm_ms = statistics.median(warm_meds) * 1e3
    warm_below_cold = warm_ms < cold_ms
    rows.append(("rpc_fetch_unit_latency_cold_ms", round(cold_ms, 4),
                 f"median per-unit input fetch+verify, cache miss "
                 f"(median of {FETCH_REPS} reps)"))
    rows.append(("rpc_fetch_unit_latency_warm_ms", round(warm_ms, 4),
                 "as above on the warmed host cache"))
    rows.append(("rpc_fetch_gbps_cold",
                 round(gb * FETCH_REPS / cold_total, 3),
                 "input bits moved / cold fetch-stage seconds"))
    rows.append(("rpc_fetch_gbps_warm",
                 round(gb * FETCH_REPS / warm_total, 3),
                 "as above served from the host cache"))
    rows.append(("rpc_warm_below_cold", int(warm_below_cold),
                 "acceptance gate: warm unit latency strictly below cold"))

    # -- fetch stage, third arm: warm-from-peer ------------------------------
    # one host's cache holds every blob and serves it over the blob
    # fabric; a cold sibling fetches content-addressed from that peer
    # instead of reading shared storage. Cold-from-storage vs warm-local
    # vs warm-from-peer is the paper's 0.60/0.33 Gb/s framing with the
    # node-to-node link as the third path.
    peer_meds = []
    peer_total = 0.0
    peer_hits = peer_fallbacks = 0
    for rep in range(FETCH_REPS):
        serve = InputCache(td / f"peer-serve-{rep}", max_bytes=1 << 30)
        _median_fetch(units, ds.root, serve)     # warm the serving host
        with BlobServer(serve) as srv:
            fetcher = InputCache(td / f"peer-fetch-{rep}", max_bytes=1 << 30)
            fetcher.attach_fabric(PeerFabric(
                lambda ds_, _s=serve.summary, _a=srv.addr_str:
                    {d: [_a] for d in ds_ if d in _s}))
            peer, _, peer_sum = _median_fetch(units, ds.root, fetcher)
        fst = fetcher.stats()
        peer_hits += fst["peer_hits"]
        peer_fallbacks += fst["misses"] - fst["peer_hits"]
        peer_meds.append(peer)
        peer_total += peer_sum
    peer_ms = statistics.median(peer_meds) * 1e3
    rows.append(("rpc_fetch_unit_latency_peer_ms", round(peer_ms, 4),
                 "as cold, served from a warm peer over the blob fabric "
                 "instead of shared storage"))
    rows.append(("rpc_fetch_gbps_peer",
                 round(gb * FETCH_REPS / peer_total, 3),
                 f"input bits moved / peer fetch-stage seconds "
                 f"({peer_hits} peer hits, {peer_fallbacks} storage "
                 f"fallbacks); paper reference "
                 f"{PAPER_REFERENCE_GBPS['lab_network']} (lab) vs "
                 f"{PAPER_REFERENCE_GBPS['cloud_storage']} (cloud)"))
    report["fetch"] = {
        "cold_ms_median": cold_ms, "warm_ms_median": warm_ms,
        "peer_ms_median": peer_ms,
        "cold_ms_samples": [round(m * 1e3, 4) for m in cold_meds],
        "warm_ms_samples": [round(m * 1e3, 4) for m in warm_meds],
        "peer_ms_samples": [round(m * 1e3, 4) for m in peer_meds],
        "peer_hits": peer_hits, "peer_fallbacks": peer_fallbacks,
        "warm_below_cold": warm_below_cold,
    }
    return (None if warm_below_cold else
            f"warm-cache fetch latency {warm_ms:.3f}ms not below cold "
            f"{cold_ms:.3f}ms — cache regression")


def _run_e2e(td: Path, ds, pipe, rows, report):
    from repro.core import Provenance, query_available_work
    from repro.dist import ClusterRunner

    # local nodes talk to the coordinator over the socket transport; one
    # genuinely separate worker process joins the same queue
    deriv = Path(ds.root) / "derivatives"
    host_cache = td / "host-cache"
    ext_cache = td / "ext-cache"
    in_bits = SHAPE[0] * SHAPE[1] * SHAPE[2] * 4 * 8 * N_SUBJECTS * SESSIONS
    e2e = {}
    for phase in ("cold", "warm"):
        units_now, _ = query_available_work(ds, pipe)
        runner = ClusterRunner(pipe, ds.root, nodes=2, transport="rpc",
                               poll_s=0.03, cache_dir=host_cache)
        got = {}
        t = threading.Thread(
            target=lambda: got.update(r=runner.run(units_now)))
        t0 = time.time()
        t.start()
        while runner.server is None and t.is_alive():
            time.sleep(0.005)
        worker = (None if runner.server is None else
                  _spawn_worker(runner.server.addr_str, ds.root, ext_cache))
        t.join()
        dt = time.time() - t0
        if worker is not None:
            worker.wait(timeout=60)
        results = got.get("r", [])
        ok = sum(r.status == "ok" for r in results)
        hits = sum(1 for u in units_now
                   if (p := Provenance.load(Path(u.out_dir))) is not None
                   and p.cache_hit)
        # bytes served per link (coordinator-host cache counters; the
        # external worker's cache adds to the real saving but reports in
        # its own process) -> effective storage-link Gb/s vs the paper's
        cstats = runner.stats.cache or {}
        bfc = cstats.get("bytes_from_cache", 0)
        bfs = cstats.get("bytes_from_storage", 0)
        e2e[phase] = {"seconds": round(dt, 3), "ok": ok,
                      "units": len(units_now), "cache_hit_commits": hits,
                      "images_per_s": round(ok / dt, 3),
                      "gbps": round(in_bits / dt / 1e9, 3),
                      "bytes_from_cache": bfc,
                      "bytes_from_storage": bfs,
                      "storage_gbps": round(bfs * 8 / dt / 1e9, 3),
                      "remote_nodes": runner.stats.remote_nodes,
                      "processed": runner.stats.processed}
        rows.append((f"rpc_e2e_images_per_s_{phase}", e2e[phase]["images_per_s"],
                     f"{ok}/{len(units_now)} ok in {dt:.2f}s over socket "
                     f"transport, {hits} cache-hit commits"))
        rows.append((f"rpc_e2e_effective_gbps_{phase}",
                     e2e[phase]["gbps"],
                     f"input bits consumed / wall-clock "
                     f"({bfc} B from cache, {bfs} B from storage); paper "
                     f"reference {PAPER_REFERENCE_GBPS['lab_network']} "
                     f"(lab) vs {PAPER_REFERENCE_GBPS['cloud_storage']} "
                     f"(cloud)"))
        shutil.rmtree(deriv, ignore_errors=True)
    report["e2e"] = e2e


def _hotpath_units(n: int, pool: int):
    """Synthetic WorkUnits with manifest digests drawn from a shared pool:
    each digest recurs in ~4 units (once per access pattern), so summaries
    actually overlap the backlog the way a real campaign's inputs do."""
    from repro.core.query import WorkUnit
    mib = 1 << 20
    return [WorkUnit(
        dataset="hot", subject=f"s{i:06d}", session="01",
        pipeline=PIPELINE, pipeline_digest="bench",
        inputs={"T1w": f"in/{i}_a.nii", "T2w": f"in/{i}_b.nii"},
        out_dir=f"derivatives/{PIPELINE}/s{i:06d}/01",
        input_digests={"T1w": f"d{i % pool:08d}",
                       "T2w": f"d{(i * 7 + 3) % pool:08d}"},
        input_bytes={"T1w": mib, "T2w": mib}) for i in range(n)]


def _run_hotpath(rows, report):
    from repro.dist.cache import DigestSummary
    from repro.dist.placement import best_node, unit_local_bytes
    from repro.dist.queue import WorkQueue
    from repro.dist.rpc import QueueClient, QueueServer

    n = max(HOTPATH_NODES, int(os.environ.get(HOTPATH_UNITS_ENV,
                                              str(HOTPATH_UNITS))))
    pool = max(1, n // 2)
    units = _hotpath_units(n, pool)

    # the drain threads are CPU-bound pure Python; at the default 5ms GIL
    # switch interval the heartbeat's measured latency is mostly scheduler
    # handoff, not the lock holds the gate is about
    switch0 = sys.getswitchinterval()
    sys.setswitchinterval(0.0005)

    # each node's cache holds a contiguous quarter of the digest pool; the
    # wire carries the Bloom filter plus the exact digest list, exactly the
    # InputCache.summary_sync() shape
    wires = {}
    share = max(1, pool // HOTPATH_NODES)
    for j in range(HOTPATH_NODES):
        held = [f"d{d:08d}" for d in range(j * share,
                                           min(pool, (j + 1) * share))]
        summ = DigestSummary(m=1 << 16)
        for d in held:
            summ.add(d)
        wires[f"hp{j}"] = {"v": 1, "full": summ.to_wire(), "digests": held}

    class _CappedQueue(WorkQueue):
        """The pre-index coordinator, reconstructed for the baseline: every
        score is a live Bloom re-probe (:func:`unit_local_bytes`) and any
        backlog fill or steal past the old 512-entry scan cap degrades to
        the blind FIFO / tail-half shape it used to."""
        SCAN_CAP = 512

        def _local_bytes(self, idx, node_id):
            if not self.locality:
                return 0
            return unit_local_bytes(self.units[idx],
                                    self._summaries.get(node_id))

        def _best_node(self, idx, candidates):
            return best_node(self.units[idx], candidates,
                             self._summaries if self.locality else {},
                             {nd: len(q) for nd, q in self._queues.items()})

        def _fill_from_backlog(self, node_id):
            if len(self._backlog_seq) <= self.SCAN_CAP:
                return super()._fill_from_backlog(node_id)
            alive = max(1, sum(1 for nd in self._queues
                               if nd not in self._dead))
            k = max(1, len(self._backlog_seq) // alive)
            q = self._queues[node_id]
            for _ in range(k):
                idx = self._backlog_pop_fifo()
                if idx is None:
                    break
                q.append(idx)

        def _steal_into(self, thief):
            lens = {nd: len(q) for nd, q in self._queues.items()
                    if nd != thief and nd not in self._dead and len(q)}
            if not lens:
                return
            deepest = max(lens.values())
            tied = sorted(nd for nd, l in lens.items() if l == deepest)
            victim = tied[self._steal_rr % len(tied)]
            self._steal_rr += 1
            vq = self._queues[victim]
            k = max(1, len(vq) // 2)
            if ((self._node_scores(thief) or self._node_scores(victim))
                    and len(vq) <= self.SCAN_CAP):
                order = sorted(range(len(vq)),
                               key=lambda p: (self._local_bytes(vq[p], victim),
                                              -self._local_bytes(vq[p], thief),
                                              -p))
                take = set(order[:k])
                grabbed = [vq[p] for p in sorted(take)]
                self._queues[victim] = deque(idx for p, idx in enumerate(vq)
                                             if p not in take)
                self.locality_stats["steals_scored"] += 1
                self.locality_stats["stolen_local_bytes"] += \
                    sum(self._local_bytes(i, thief) for i in grabbed)
            else:
                grabbed = [vq.pop() for _ in range(k)]
                grabbed.reverse()
                self.locality_stats["steals_blind"] += 1
            self._queues[thief].extend(grabbed)
            self.steals[thief] += 1

    def drive(queue_cls):
        t0 = time.perf_counter()
        q = queue_cls(units, [f"hp{j}" for j in range(HOTPATH_NODES)],
                      partition="backlog", locality=True, lease_ttl_s=3600.0)
        build_ms = (time.perf_counter() - t0) * 1e3
        for nid, wire in wires.items():
            assert q.put_summary(nid, wire)
        # prime each node's backlog fill outside the clock: the fill is a
        # once-per-registration event in both variants, and the arm gates on
        # the steady-state grant path, not the registration burst
        primed = 0
        for j in range(HOTPATH_NODES):
            got = q.next_units(f"hp{j}", 1)
            q.complete_batch([{"idx": lease.unit_idx, "node_id": f"hp{j}",
                               "status": "ok"} for _u, lease in got])
            primed += len(got)
        stop = threading.Event()
        tail = threading.Event()
        granted = [0] * HOTPATH_NODES
        granted[0] = primed
        hb_lat = []

        def drain(j):
            # the pause between batches stands in for compute: without it
            # the four drains hold the lock back-to-back and the heartbeat
            # only ever measures total saturation, where any two
            # implementations converge. With it, heartbeat latency tracks
            # what one grant/complete batch holds the lock for — the
            # quantity the old scan cap existed to bound
            nid = f"hp{j}"
            while not stop.is_set():
                got = q.next_units(nid, HOTPATH_BATCH)
                if not got:
                    break
                q.complete_batch([{"idx": lease.unit_idx, "node_id": nid,
                                   "status": "ok"} for _u, lease in got])
                granted[j] += len(got)
                stop.wait(0.0005)

        def beat():
            # node-level liveness with a piggybacked summary delta (one
            # digest in, one out: a churning LRU cache); the latency a real
            # worker's heartbeat would see behind the grant lock. Samples
            # count only while every drain is busy (``tail`` unset): the
            # gate is about steady-state granting, not the end-of-queue
            # scramble where idle nodes churn steals in both variants
            i = 0
            while not stop.is_set():
                delta = {"v": 1, "add": [f"d{(i + 1) % pool:08d}"],
                         "drop": [f"d{i % pool:08d}"]}
                h0 = time.perf_counter()
                q.heartbeat("hp0", summary_delta=delta)
                if not tail.is_set():
                    hb_lat.append(time.perf_counter() - h0)
                i += 1
                stop.wait(0.0001)

        drains = [threading.Thread(target=drain, args=(j,), daemon=True)
                  for j in range(HOTPATH_NODES)]
        hb = threading.Thread(target=beat, daemon=True)
        t0 = time.perf_counter()
        for t in drains:
            t.start()
        hb.start()
        deadline = t0 + HOTPATH_DEADLINE_S
        while (all(t.is_alive() for t in drains)
               and time.perf_counter() < deadline):
            time.sleep(0.005)
        tail.set()                     # first node ran dry: steady state over
        while (any(t.is_alive() for t in drains)
               and time.perf_counter() < deadline):
            time.sleep(0.01)
        elapsed = time.perf_counter() - t0
        stop.set()
        for t in drains:
            t.join(timeout=30)
        hb.join(timeout=30)
        lat = sorted(hb_lat)
        p99_ms = (lat[int(0.99 * (len(lat) - 1))] * 1e3) if lat else 0.0
        ls = q.locality_stats
        return {"grants": sum(granted),
                "grants_per_s": round(sum(granted) / elapsed, 1),
                "hb_p99_ms": round(p99_ms, 4),
                "hb_samples": len(lat),
                "seconds": round(elapsed, 3),
                "build_ms": round(build_ms, 2),
                "scored_grants": ls["scored_grants"],
                "blind_grants": ls["blind_grants"],
                "warm_fraction": round(ls["local_bytes_granted"]
                                       / max(1, ls["input_bytes_granted"]), 4),
                "finished": q.finished()}

    try:
        capped = drive(_CappedQueue)
        indexed = drive(WorkQueue)
    finally:
        sys.setswitchinterval(switch0)
    for label, r in (("capped", capped), ("index", indexed)):
        rows.append((f"rpc_hotpath_grants_per_s_{label}", r["grants_per_s"],
                     f"{r['grants']}/{n} units granted+completed in "
                     f"{r['seconds']}s by {HOTPATH_NODES} nodes (batch "
                     f"{HOTPATH_BATCH}); queue build {r['build_ms']}ms"))
        rows.append((f"rpc_hotpath_hb_p99_ms_{label}", r["hb_p99_ms"],
                     f"p99 heartbeat+delta latency over {r['hb_samples']} "
                     f"beats behind the grant lock"))
        rows.append((f"rpc_hotpath_warm_fraction_{label}", r["warm_fraction"],
                     f"cache-local / total input bytes granted "
                     f"({r['scored_grants']} scored, {r['blind_grants']} "
                     f"blind grants) — the placement the cap was blind to"))
    hot_ok = (indexed["grants_per_s"] > capped["grants_per_s"]
              and indexed["hb_p99_ms"] < capped["hb_p99_ms"])
    rows.append(("rpc_hotpath_index_wins", int(hot_ok),
                 "acceptance gate: index-backed queue grants strictly "
                 "faster AND holds heartbeat p99 strictly lower than the "
                 "512-capped baseline"))
    report["hotpath"] = {"units": n, "nodes": HOTPATH_NODES,
                         "batch": HOTPATH_BATCH, "capped": capped,
                         "index": indexed, "index_wins": hot_ok}

    # -- socket micro-arm: per-op JSON-lines vs batched binary frames --------
    wunits = units[:WIRE_UNITS]
    wire = {}
    for mode in ("perop_jsonl", "batched_binary"):
        wq = WorkQueue(wunits, partition="backlog", locality=False,
                       lease_ttl_s=3600.0)
        with QueueServer(wq) as srv:
            cli = QueueClient(srv.address, binary=(mode == "batched_binary"))
            try:
                cli.register("w0")
                t0 = time.perf_counter()
                if mode == "batched_binary":
                    while True:
                        got = cli.next_units("w0", HOTPATH_BATCH)
                        if not got:
                            break
                        cli.complete_batch(
                            [{"idx": lease.unit_idx, "node_id": "w0",
                              "status": "ok"} for _u, lease in got])
                else:
                    while True:
                        one = cli.next_unit("w0")
                        if one is None:
                            break
                        cli.complete(one[1].unit_idx, "w0", "ok")
                dt = time.perf_counter() - t0
            finally:
                cli.close()
        wire[mode] = round(len(wunits) / dt, 1)
    rows.append(("rpc_wire_perop_jsonl_units_per_s", wire["perop_jsonl"],
                 f"{len(wunits)} units granted+completed per-op over "
                 f"JSON-lines (2 round trips per unit)"))
    rows.append(("rpc_wire_batched_binary_units_per_s",
                 wire["batched_binary"],
                 f"as above, batches of {HOTPATH_BATCH} over binary frames "
                 f"(2 round trips per {HOTPATH_BATCH} units)"))
    report["wire"] = wire
    return (None if hot_ok else
            f"index-backed hot path not strictly better: grants/s "
            f"{indexed['grants_per_s']} vs capped {capped['grants_per_s']}, "
            f"hb p99 {indexed['hb_p99_ms']}ms vs {capped['hb_p99_ms']}ms")


def _run_inproc():
    arms = {a.strip() for a in
            os.environ.get(ARMS_ENV, DEFAULT_ARMS).split(",") if a.strip()}
    rows = []
    report: dict = {"units": N_SUBJECTS * SESSIONS, "shape": list(SHAPE),
                    "arms": sorted(arms)}
    gate_errors = []
    if arms & {"fetch", "e2e"}:
        from repro.core import (builtin_pipelines, query_available_work,
                                synthesize_dataset)
        with tempfile.TemporaryDirectory() as td:
            td = Path(td)
            ds = synthesize_dataset(td / "ds", "rpcbench",
                                    n_subjects=N_SUBJECTS,
                                    sessions_per_subject=SESSIONS,
                                    shape=SHAPE)
            pipe = builtin_pipelines()[PIPELINE]
            units, _ = query_available_work(ds, pipe)
            if "fetch" in arms:
                err = _run_fetch(td, ds, units, rows, report)
                if err:
                    gate_errors.append(err)
            if "e2e" in arms:
                _run_e2e(td, ds, pipe, rows, report)
    if "hotpath" in arms:
        err = _run_hotpath(rows, report)
        if err:
            gate_errors.append(err)
    report["paper_reference_gbps"] = PAPER_REFERENCE_GBPS
    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    report["rows"] = [[n, v, d] for n, v, d in rows]
    out.write_text(json.dumps(report, indent=1))
    # gates fail *after* the JSON lands, so the artifact always shows the
    # numbers the failure is about
    if gate_errors:
        raise RuntimeError("; ".join(gate_errors))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.rpc_throughput", "rpc_",
                      _INPROC_FLAG, _run_inproc, timeout=1800)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
