"""Socket-transport throughput: cold vs warm per-host input cache.

The paper's cost case hinges on the storage->compute link (0.60 Gb/s lab
network vs 0.33 Gb/s cloud); the RPC cluster keeps that link off the
coordinator socket (control plane only) and shortens it with the per-host
content-addressed cache (``repro.dist.cache``). This bench measures both:

* **Fetch stage, cold vs warm** — per-unit input fetch+verify latency and
  Gb/s through ``safe_load_unit_inputs`` with a fresh cache (miss: read
  shared storage, hash, insert) and a warm one (hit: read node-local blob,
  re-hash, skip storage + insert). Warm must be strictly below cold — this
  is the acceptance gate, checked in-process and recorded in the JSON. On
  one machine both "links" are the same disk, so the gap here is the cache's
  *overheadless* floor; on a real cluster the cold path crosses the network
  and the gap widens to the paper's 0.60-vs-0.33 framing.
* **End-to-end over the wire** — a 32-unit run through ``ClusterRunner``
  with ``transport="rpc"`` (every lease/complete/heartbeat is a JSON-lines
  RPC) plus one *separate-process* worker joined via
  ``python -m repro.dist.rpc work``, cold then warm cache. Reported as
  images/s and input-Gb/s; provenance ``cache_hit`` counts come along so the
  artifact shows the warm run really was served locally.

Runs in a thread-pinned subprocess like the other executor benches (see
``_pin``); writes ``benchmarks/out/rpc_throughput.json`` (CI artifact;
override with ``REPRO_BENCH_JSON``).
"""
from __future__ import annotations

import json
import os
import shutil
import statistics
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

from ._pin import run_pinned

N_SUBJECTS = 16
SESSIONS = 2                        # 32 units
SHAPE = (64, 64, 64)                # 1 MiB float32 input per unit
PIPELINE = "bias_correct"
FETCH_REPS = 5
# the paper's storage->compute link speeds (§3): the lab-network setup the
# cost argument depends on, and the cloud-storage baseline it beats. Keeping
# both in the artifact makes the repo's effective Gb/s trajectory comparable
# across PRs against a fixed yardstick.
PAPER_REFERENCE_GBPS = {"lab_network": 0.60, "cloud_storage": 0.33}

_INPROC_FLAG = "REPRO_RPC_BENCH_INPROC"
_JSON_OUT = Path(__file__).resolve().parent / "out" / "rpc_throughput.json"


def _median_fetch(units, root, cache):
    """Per-unit fetch+verify latency (s) and total bytes through the stage."""
    from repro.core.workflow import safe_load_unit_inputs
    lats = []
    nbytes = 0
    for u in units:
        t0 = time.perf_counter()
        loaded = safe_load_unit_inputs(u, root, cache=cache)
        lats.append(time.perf_counter() - t0)
        assert loaded is not None
        nbytes += sum(a.nbytes for a in loaded[0].values())
    return statistics.median(lats), nbytes, sum(lats)


def _spawn_worker(addr: str, data_root: Path, cache_dir: Path):
    env = dict(os.environ,
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""),
               REPRO_CACHE_DIR=str(cache_dir))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.dist.rpc", "work", "--addr", addr,
         "--pipeline", PIPELINE, "--data-root", str(data_root),
         "--node-id", "bench-ext"],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)


def _run_inproc():
    from repro.core import (Provenance, builtin_pipelines,
                            query_available_work, synthesize_dataset)
    from repro.dist import ClusterRunner, InputCache
    rows = []
    report: dict = {"units": N_SUBJECTS * SESSIONS, "shape": list(SHAPE)}
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        ds = synthesize_dataset(td / "ds", "rpcbench", n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        units, _ = query_available_work(ds, pipe)
        deriv = Path(ds.root) / "derivatives"

        # -- fetch stage: cold vs warm, interleaved medians ------------------
        cold_meds, warm_meds = [], []
        gb = 0.0
        cold_total = warm_total = 0.0
        for rep in range(FETCH_REPS):
            cache = InputCache(td / f"cache-{rep}", max_bytes=1 << 30)
            cold, nbytes, cold_sum = _median_fetch(units, ds.root, cache)
            warm, _, warm_sum = _median_fetch(units, ds.root, cache)
            cold_meds.append(cold)
            warm_meds.append(warm)
            cold_total += cold_sum
            warm_total += warm_sum
            gb = nbytes * 8 / 1e9
        cold_ms = statistics.median(cold_meds) * 1e3
        warm_ms = statistics.median(warm_meds) * 1e3
        warm_below_cold = warm_ms < cold_ms
        rows.append(("rpc_fetch_unit_latency_cold_ms", round(cold_ms, 4),
                     f"median per-unit input fetch+verify, cache miss "
                     f"(median of {FETCH_REPS} reps)"))
        rows.append(("rpc_fetch_unit_latency_warm_ms", round(warm_ms, 4),
                     "as above on the warmed host cache"))
        rows.append(("rpc_fetch_gbps_cold",
                     round(gb * FETCH_REPS / cold_total, 3),
                     "input bits moved / cold fetch-stage seconds"))
        rows.append(("rpc_fetch_gbps_warm",
                     round(gb * FETCH_REPS / warm_total, 3),
                     "as above served from the host cache"))
        rows.append(("rpc_warm_below_cold", int(warm_below_cold),
                     "acceptance gate: warm unit latency strictly below cold"))

        # -- fetch stage, third arm: warm-from-peer --------------------------
        # one host's cache holds every blob and serves it over the blob
        # fabric; a cold sibling fetches content-addressed from that peer
        # instead of reading shared storage. Cold-from-storage vs warm-local
        # vs warm-from-peer is the paper's 0.60/0.33 Gb/s framing with the
        # node-to-node link as the third path.
        from repro.dist import BlobServer, InputCache as _Cache, PeerFabric
        peer_meds = []
        peer_total = 0.0
        peer_hits = peer_fallbacks = 0
        for rep in range(FETCH_REPS):
            serve = _Cache(td / f"peer-serve-{rep}", max_bytes=1 << 30)
            _median_fetch(units, ds.root, serve)     # warm the serving host
            with BlobServer(serve) as srv:
                fetcher = _Cache(td / f"peer-fetch-{rep}", max_bytes=1 << 30)
                fetcher.attach_fabric(PeerFabric(
                    lambda ds_, _s=serve.summary, _a=srv.addr_str:
                        {d: [_a] for d in ds_ if d in _s}))
                peer, _, peer_sum = _median_fetch(units, ds.root, fetcher)
            fst = fetcher.stats()
            peer_hits += fst["peer_hits"]
            peer_fallbacks += fst["misses"] - fst["peer_hits"]
            peer_meds.append(peer)
            peer_total += peer_sum
        peer_ms = statistics.median(peer_meds) * 1e3
        rows.append(("rpc_fetch_unit_latency_peer_ms", round(peer_ms, 4),
                     "as cold, served from a warm peer over the blob fabric "
                     "instead of shared storage"))
        rows.append(("rpc_fetch_gbps_peer",
                     round(gb * FETCH_REPS / peer_total, 3),
                     f"input bits moved / peer fetch-stage seconds "
                     f"({peer_hits} peer hits, {peer_fallbacks} storage "
                     f"fallbacks); paper reference "
                     f"{PAPER_REFERENCE_GBPS['lab_network']} (lab) vs "
                     f"{PAPER_REFERENCE_GBPS['cloud_storage']} (cloud)"))
        report["fetch"] = {
            "cold_ms_median": cold_ms, "warm_ms_median": warm_ms,
            "peer_ms_median": peer_ms,
            "cold_ms_samples": [round(m * 1e3, 4) for m in cold_meds],
            "warm_ms_samples": [round(m * 1e3, 4) for m in warm_meds],
            "peer_ms_samples": [round(m * 1e3, 4) for m in peer_meds],
            "peer_hits": peer_hits, "peer_fallbacks": peer_fallbacks,
            "warm_below_cold": warm_below_cold,
        }

        # -- end-to-end over the socket transport ---------------------------
        # local nodes talk JSON-lines to the coordinator; one genuinely
        # separate worker process joins the same queue
        host_cache = td / "host-cache"
        ext_cache = td / "ext-cache"
        in_bits = sum(SHAPE[0] * SHAPE[1] * SHAPE[2] * 4 * 8 for _ in units)
        e2e = {}
        for phase in ("cold", "warm"):
            units_now, _ = query_available_work(ds, pipe)
            runner = ClusterRunner(pipe, ds.root, nodes=2, transport="rpc",
                                   poll_s=0.03, cache_dir=host_cache)
            got = {}
            t = threading.Thread(
                target=lambda: got.update(r=runner.run(units_now)))
            t0 = time.time()
            t.start()
            while runner.server is None and t.is_alive():
                time.sleep(0.005)
            worker = (None if runner.server is None else
                      _spawn_worker(runner.server.addr_str, ds.root, ext_cache))
            t.join()
            dt = time.time() - t0
            if worker is not None:
                worker.wait(timeout=60)
            results = got.get("r", [])
            ok = sum(r.status == "ok" for r in results)
            hits = sum(1 for u in units_now
                       if (p := Provenance.load(Path(u.out_dir))) is not None
                       and p.cache_hit)
            # bytes served per link (coordinator-host cache counters; the
            # external worker's cache adds to the real saving but reports in
            # its own process) -> effective storage-link Gb/s vs the paper's
            cstats = runner.stats.cache or {}
            bfc = cstats.get("bytes_from_cache", 0)
            bfs = cstats.get("bytes_from_storage", 0)
            e2e[phase] = {"seconds": round(dt, 3), "ok": ok,
                          "units": len(units_now), "cache_hit_commits": hits,
                          "images_per_s": round(ok / dt, 3),
                          "gbps": round(in_bits / dt / 1e9, 3),
                          "bytes_from_cache": bfc,
                          "bytes_from_storage": bfs,
                          "storage_gbps": round(bfs * 8 / dt / 1e9, 3),
                          "remote_nodes": runner.stats.remote_nodes,
                          "processed": runner.stats.processed}
            rows.append((f"rpc_e2e_images_per_s_{phase}", e2e[phase]["images_per_s"],
                         f"{ok}/{len(units_now)} ok in {dt:.2f}s over socket "
                         f"transport, {hits} cache-hit commits"))
            rows.append((f"rpc_e2e_effective_gbps_{phase}",
                         e2e[phase]["gbps"],
                         f"input bits consumed / wall-clock "
                         f"({bfc} B from cache, {bfs} B from storage); paper "
                         f"reference {PAPER_REFERENCE_GBPS['lab_network']} "
                         f"(lab) vs {PAPER_REFERENCE_GBPS['cloud_storage']} "
                         f"(cloud)"))
            shutil.rmtree(deriv, ignore_errors=True)
        report["e2e"] = e2e
        report["paper_reference_gbps"] = PAPER_REFERENCE_GBPS
    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    report["rows"] = [[n, v, d] for n, v, d in rows]
    out.write_text(json.dumps(report, indent=1))
    if not warm_below_cold:
        raise RuntimeError(
            f"warm-cache fetch latency {warm_ms:.3f}ms not below cold "
            f"{cold_ms:.3f}ms — cache regression")
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.rpc_throughput", "rpc_",
                      _INPROC_FLAG, _run_inproc, timeout=1800)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
