"""Paper §2.4 analogue: per-image pipeline processing time on this host,
end-to-end through the workflow engine (query -> run -> provenance)."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core import (LocalRunner, builtin_pipelines, generate_jobs,
                        synthesize_dataset)


def run():
    rows = []
    with tempfile.TemporaryDirectory() as td:
        ds = synthesize_dataset(Path(td), "bench", n_subjects=2,
                                sessions_per_subject=1, shape=(16, 16, 16))
        for name in ("bias_correct", "segment_unest", "affine_register"):
            pipe = builtin_pipelines()[name]
            plan = generate_jobs(ds, pipe, Path(td) / "jobs" / name)
            t0 = time.time()
            results = LocalRunner(pipe, ds.root).run(plan.units)
            dt = time.time() - t0
            # speculative straggler duplicates are reported with
            # status="speculative" and must not inflate per-image counts;
            # dedupe by job_id as a second guard
            ok = len({r.unit.job_id for r in results if r.status == "ok"})
            rows.append((f"pipeline_{name}_s_per_image",
                         round(dt / max(ok, 1), 3),
                         f"{ok} images (paper FreeSurfer: 375.5 min/img at scale)"))
    return rows
