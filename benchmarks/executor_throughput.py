"""Paper Table-1 trajectory: data-plane throughput of the parallel executor.

Runs the same >=16-unit synthetic workload through ``LocalRunner`` at
workers in {1, 2, 4, 8} and reports wall-clock, images/s, and Gb/s of bytes
moved through the verified load -> compute -> committed-store path. The
paper's headline is 0.60 Gb/s storage<->compute with checksummed transfers;
this bench makes the executor's share of that measurable per host.

Throughput mode: the sweep executes in a subprocess with XLA/BLAS intra-op
parallelism pinned to one thread, so each unit's compute occupies one core
and worker scaling — not operator-level thread contention — is what gets
measured. Shared hosts drift 3-4x in effective CPU on second timescales, so
the sweep is INTERLEAVED and repeated ``REPS`` times with per-config MEDIANS
reported — medians (not minima) because parallel workers also hedge
per-core steal: a stalled core slows a serial sweep ~4x but a 4-worker sweep
only marginally, and that robustness is part of what the executor buys.
The serial baseline row (``serial_loop``) reproduces the SEED's data plane
faithfully: a plain ``for unit: ...`` loop (no prefetch, no workers) with the
seed's multi-pass integrity — ``sha256_file`` then ``np.load`` per input,
``np.save`` then ``sha256_file`` per output — so the speedup row measures
what this PR changed: concurrency plus bytes-hashed-per-byte-moved dropping
from ~3 to ~1.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

from ._pin import run_pinned

WORKER_SWEEP = (0, 1, 2, 4, 8)    # 0 = serial plain-loop baseline
N_SUBJECTS = 8
SESSIONS = 2                      # 8 x 2 = 16 units
SHAPE = (48, 48, 48)
PIPELINE = "bias_correct"
REPS = 5

_INPROC_FLAG = "REPRO_BENCH_INPROC"


def _unit_bytes(ds, units, results, ok_ids=None) -> int:
    """Bytes moved by the data plane: inputs read once + outputs committed."""
    total = 0
    if ok_ids is None:
        ok_ids = {r.unit.job_id for r in results if r.status == "ok"}
    for u in units:
        if u.job_id not in ok_ids:
            continue
        for rel in u.inputs.values():
            total += (Path(ds.root) / rel).stat().st_size
        out_dir = Path(u.out_dir)
        total += sum(p.stat().st_size for p in out_dir.glob("*.npy"))
    return total


def _seed_serial_unit(unit, pipe, data_root):
    """The seed's execution path: serial, with its hash/load double-reads."""
    import numpy as np
    from repro.core.integrity import sha256_file
    from repro.core.provenance import is_complete, make_provenance
    t0 = time.time()
    data_root = Path(data_root)
    out_dir = Path(unit.out_dir)
    if is_complete(out_dir, unit.pipeline_digest):
        return "skipped"
    inputs, in_sums = {}, {}
    for suffix, rel in unit.inputs.items():
        p = data_root / rel
        in_sums[rel] = sha256_file(p)          # pass 1: hash
        inputs[suffix] = np.load(p)            # pass 2: load
    outputs = pipe.run(inputs)
    out_sums = {}
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, arr in outputs.items():
        op = out_dir / f"sub-{unit.subject}_ses-{unit.session}_{name}.npy"
        np.save(op, arr)                       # write
        out_sums[op.name] = sha256_file(op)    # pass 3: re-read + hash
    make_provenance(unit.pipeline, unit.pipeline_digest, in_sums, out_sums,
                    t0).save(out_dir)
    return "ok"


def _run_inproc():
    from repro.core import (LocalRunner, builtin_pipelines,
                            query_available_work, synthesize_dataset)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        ds = synthesize_dataset(Path(td), "bench", n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        deriv = Path(ds.root) / "derivatives"

        # warm the jit caches so the serial baseline doesn't pay compile time
        units, _ = query_available_work(ds, pipe)
        LocalRunner(pipe, ds.root).run(units[:2])
        shutil.rmtree(deriv, ignore_errors=True)

        def measure(w):
            units, _ = query_available_work(ds, pipe)
            t0 = time.time()
            if w == 0:                      # the seed's serial data plane
                statuses = [_seed_serial_unit(u, pipe, ds.root) for u in units]
                dt = time.time() - t0
                ok = sum(s == "ok" for s in statuses)
                ok_ids = {u.job_id for u, s in zip(units, statuses) if s == "ok"}
                results = None
            else:
                results = LocalRunner(pipe, ds.root, workers=w).run(units)
                dt = time.time() - t0
                ok = sum(r.status == "ok" for r in results)
                ok_ids = None
            nbytes = _unit_bytes(ds, units, results, ok_ids=ok_ids)
            shutil.rmtree(deriv, ignore_errors=True)
            return dt, ok, len(units), nbytes

        samples = {w: [] for w in WORKER_SWEEP}
        for _ in range(REPS):
            for w in WORKER_SWEEP:
                samples[w].append(measure(w))
        med = {}
        for w in WORKER_SWEEP:
            ms = sorted(samples[w], key=lambda m: m[0])
            med[w] = ms[len(ms) // 2]
            dt, ok, n, nbytes = med[w]
            tag = "serial_loop" if w == 0 else f"w{w}"
            rows.append((f"executor_images_per_s_{tag}", round(ok / dt, 3),
                         f"{ok}/{n} units in {dt:.2f}s (median of {REPS})"))
            rows.append((f"executor_gbps_{tag}",
                         round(nbytes * 8 / dt / 1e9, 4),
                         f"{nbytes / 2**20:.1f} MiB verified load+commit "
                         f"(paper hot tier: 0.60 Gb/s)"))
        rows.append(("executor_speedup_w4_vs_serial",
                     round(med[0][0] / med[4][0], 3),
                     "median wall-clock: serial loop / workers=4"))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec in a pinned subprocess so
    the one-core-per-unit XLA flags apply before jax initializes — without
    leaking single-threaded compute into the other benchmarks (see ``_pin``)."""
    return run_pinned("benchmarks.executor_throughput", "executor_",
                      _INPROC_FLAG, _run_inproc)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
