"""Peer blob fabric vs storage-only fetching on a warm 4-node chaos run.

The paper's transfer ceiling is the shared-storage link: 0.60 Gb/s over the
lab network, 0.33 Gb/s from cloud storage. The peer fabric
(``repro.dist.blobserve``) routes a node's cache misses to whichever sibling
already holds the blob, so after a warm-up pass the storage link only
carries bytes no live peer has. This bench measures exactly that delta on
the 32-unit chaos schedule:

1. **Warm-up** — a locality-blind round-robin run over 4 nodes with one
   cache each (``cache_per_node``): every node ends up holding roughly its
   partition's input bytes. Cache dirs are snapshotted.
2. **Measured arms** — derivatives wiped, caches restored, and the same 32
   units re-run from a *rotated* placement (locality off, round-robin: most
   units land on a node that does NOT hold their bytes) with mid-run chaos
   (node-1 dies after 4 units — a serving peer going away mid-run): once
   with ``peer_fabric=False`` (every non-local fetch crosses storage, the
   PR 5 baseline) and once with ``peer_fabric=True`` (non-local fetches
   stream from the warm sibling, storage is the fallback).

To keep the comparison honest on one machine — where "storage" and "peer"
are the same local disk — the storage path is throttled through the
``InputCache._read_storage`` seam to the paper's 0.60 Gb/s in BOTH arms.
The peer path is measured as-is: that asymmetry is the point (peer traffic
rides the node-to-node link, not the storage choke point).

Acceptance gates (checked here and in CI; a regression fails loud):

* both arms complete all units ok;
* fabric-on records peer hits, and its **measured peer-link Gb/s strictly
  beats the measured storage-link Gb/s** (and the paper's 0.60 reference);
* fabric-on moves **strictly fewer bytes from storage** than the
  fabric-off baseline;
* every peer-path failure fell back (ok-count again) with the fallback
  counters visible in the stats.

Writes ``benchmarks/out/peer_fabric.json`` (CI artifact; override with
``REPRO_BENCH_JSON``). Runs thread-pinned in a subprocess (see ``_pin``).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

from ._pin import run_pinned
from ._stats import cache_totals as _cache_totals, hit_rate as _hit_rate

N_SUBJECTS = 16
SESSIONS = 2                        # 32 units
SHAPE = (64, 64, 64)                # 1 MiB float32 input per unit: large
                                    # enough that link speed, not per-fetch
                                    # overhead, decides the peer-vs-storage
                                    # comparison (the paper's inputs are MBs)
PIPELINE = "bias_correct"
NODES = 4
PAPER_REFERENCE_GBPS = {"lab_network": 0.60, "cloud_storage": 0.33}
MODEL_STORAGE_GBPS = PAPER_REFERENCE_GBPS["lab_network"]

_INPROC_FLAG = "REPRO_PEER_FABRIC_BENCH_INPROC"
_JSON_OUT = Path(__file__).resolve().parent / "out" / "peer_fabric.json"


def _link_gbps(nbytes: int, seconds: float) -> float:
    return nbytes * 8 / seconds / 1e9 if seconds > 0 else 0.0


def _run_inproc():
    from repro.core import (builtin_pipelines, query_available_work,
                            synthesize_dataset)
    from repro.dist import ClusterRunner, InputCache
    rows = []
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        ds = synthesize_dataset(td / "ds", "fabbench", n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        units, _ = query_available_work(ds, pipe)
        assert len(units) == N_SUBJECTS * SESSIONS
        deriv = Path(ds.root) / "derivatives"
        caches = td / "hosts"
        snapshot = td / "hosts-warm"

        # -- warm-up: populate per-node caches (unthrottled) -----------------
        warm = ClusterRunner(pipe, ds.root, nodes=NODES, locality=False,
                             cache_dir=caches, cache_per_node=True,
                             straggler_factor=100.0, poll_s=0.02)
        results = warm.run(units)
        ok = sum(r.status == "ok" for r in results)
        if ok != len(units):
            raise RuntimeError(f"warm-up incomplete: {ok}/{len(units)} ok")
        shutil.copytree(caches, snapshot)
        shutil.rmtree(deriv, ignore_errors=True)

        # rotate the per-node cache dirs by one: node-i now holds node-(i+1)'s
        # warm bytes, so under round-robin re-partition nearly every unit
        # lands on a node whose *sibling* (not itself) holds its inputs —
        # the shape where only the fabric can keep bytes off the storage link
        def restore_rotated():
            shutil.rmtree(caches, ignore_errors=True)
            caches.mkdir(parents=True)
            for i in range(NODES):
                shutil.copytree(snapshot / f"node-{(i + 1) % NODES}",
                                caches / f"node-{i}")

        # model the paper's storage link in both measured arms: every byte
        # crossing the shared-storage seam pays 0.60 Gb/s. The peer path is
        # deliberately NOT throttled — peer traffic rides the node-to-node
        # link, which is exactly the asymmetry the fabric exists to exploit.
        real_read = InputCache._read_storage

        def throttled_read(src):
            data = real_read(src)
            time.sleep(len(data) * 8 / (MODEL_STORAGE_GBPS * 1e9))
            return data

        def measure(peer_fabric: bool) -> dict:
            restore_rotated()
            units_now, _ = query_available_work(ds, pipe)
            runner = ClusterRunner(
                pipe, ds.root, nodes=NODES, locality=False,
                cache_dir=caches, cache_per_node=True,
                peer_fabric=peer_fabric,
                die_after={"node-1": 4}, lease_ttl_s=0.6, hb_interval_s=0.1,
                straggler_factor=100.0, poll_s=0.02)
            InputCache._read_storage = staticmethod(throttled_read)
            t0 = time.time()
            try:
                results = runner.run(units_now)
            finally:
                InputCache._read_storage = staticmethod(real_read)
            dt = time.time() - t0
            ok = sum(r.status == "ok" for r in results)
            if ok != len(units_now):
                raise RuntimeError(
                    f"peer_fabric={peer_fabric}: {ok}/{len(units_now)} ok")
            totals = _cache_totals(runner)
            shutil.rmtree(deriv, ignore_errors=True)
            return {
                "seconds": round(dt, 3), "ok": ok,
                "hit_rate": round(_hit_rate(totals), 4),
                "peer_hits": totals.get("peer_hits", 0),
                "bytes_from_cache": totals.get("bytes_from_cache", 0),
                "bytes_from_peer": totals.get("bytes_from_peer", 0),
                "bytes_from_storage": totals.get("bytes_from_storage", 0),
                "peer_gbps": round(_link_gbps(
                    totals.get("bytes_from_peer", 0),
                    totals.get("peer_seconds", 0.0)), 3),
                "storage_gbps": round(_link_gbps(
                    totals.get("bytes_from_storage", 0),
                    totals.get("storage_seconds", 0.0)), 3),
                "effective_gbps": round(
                    sum(u.total_input_bytes for u in units_now)
                    * 8 / dt / 1e9, 3),
                "fallbacks": {k: totals.get(k, 0) for k in (
                    "peer_false_positives", "peer_dead",
                    "peer_digest_mismatches", "peer_locate_failures")},
                "fabric": runner.stats.fabric,
                "peer_links": runner.stats.peer_links,
                "requeued": len(runner.stats.requeued),
            }

        off = measure(False)
        on = measure(True)

        for phase, m in (("off", off), ("on", on)):
            rows.append((f"peer_fabric_storage_bytes_{phase}",
                         m["bytes_from_storage"],
                         f"input bytes over the (0.60 Gb/s-modelled) storage "
                         f"link, fabric {phase}"))
            rows.append((f"peer_fabric_effective_gbps_{phase}",
                         m["effective_gbps"],
                         f"input bits consumed / wall-clock, fabric {phase}; "
                         f"paper reference "
                         f"{PAPER_REFERENCE_GBPS['lab_network']} (lab) vs "
                         f"{PAPER_REFERENCE_GBPS['cloud_storage']} (cloud)"))
        rows.append(("peer_fabric_peer_gbps", on["peer_gbps"],
                     f"measured node-to-node link Gb/s "
                     f"({on['bytes_from_peer']} B over "
                     f"{on['peer_hits']} peer hits)"))
        rows.append(("peer_fabric_storage_gbps", on["storage_gbps"],
                     "measured storage-link Gb/s under the 0.60 model "
                     "(fallback + unlocatable bytes)"))
        rows.append(("peer_fabric_storage_bytes_saved",
                     off["bytes_from_storage"] - on["bytes_from_storage"],
                     "bytes the fabric kept off the storage link on the "
                     "same warm rotated 32-unit chaos schedule"))

        # acceptance gates — a fabric that doesn't beat the storage path, or
        # that loses units when peers misbehave, must fail CI loudly
        if on["peer_hits"] <= 0:
            raise RuntimeError("fabric-on run recorded no peer hits")
        if on["bytes_from_storage"] >= off["bytes_from_storage"]:
            raise RuntimeError(
                f"fabric-on moved {on['bytes_from_storage']} bytes from "
                f"storage, not strictly below fabric-off "
                f"{off['bytes_from_storage']} — fabric regression")
        floor = max(on["storage_gbps"],
                    PAPER_REFERENCE_GBPS["lab_network"])
        if on["peer_gbps"] <= floor:
            raise RuntimeError(
                f"peer link {on['peer_gbps']} Gb/s does not beat the "
                f"storage path ({on['storage_gbps']} measured, "
                f"{PAPER_REFERENCE_GBPS['lab_network']} paper reference)")

    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "units": N_SUBJECTS * SESSIONS, "shape": list(SHAPE), "nodes": NODES,
        "chaos": {"die_after": {"node-1": 4}, "cache_rotation": 1},
        "model_storage_gbps": MODEL_STORAGE_GBPS,
        "paper_reference_gbps": PAPER_REFERENCE_GBPS,
        "fabric_off": off, "fabric_on": on,
        "gate": {"peer_hits_positive": True,
                 "storage_bytes_strictly_lower": True,
                 "peer_gbps_beats_storage": True},
        "rows": [[n, v, d] for n, v, d in rows],
    }, indent=1))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.peer_fabric", "peer_fabric_",
                      _INPROC_FLAG, _run_inproc, timeout=1800)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
