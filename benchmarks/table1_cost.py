"""Paper Table 1: cost/throughput comparison of HPC / cloud / local.

Reproduces the published numbers from the paper's own constants, and measures
this framework's simulated tiered-storage transfer path (bandwidth + latency
+ checksum overhead) the way the paper measured scp copies.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import TieredStore, paper_table1, cost_ratio_cloud_vs_hpc
from repro.core.storage import TIERS


def run():
    rows = []
    t = paper_table1()
    for env, d in t.items():
        rows.append((f"table1_cost_{env}_dollars", d["total_cost"],
                     f"paper: hpc=0.36 cloud=6.59 local=3.53"))
        rows.append((f"table1_throughput_{env}_gbps", d["throughput_gbps"],
                     "paper Table 1"))
    rows.append(("table1_cloud_over_hpc_ratio", round(cost_ratio_cloud_vs_hpc(), 2),
                 "paper claims ~20x"))

    # measured: checksummed transfer through the hot tier (1 GB file analogue,
    # scaled to 64 MB for CI wall-time; report simulated Gb/s incl. checksum)
    with tempfile.TemporaryDirectory() as td:
        store = TieredStore(Path(td) / "store")
        f = Path(td) / "blob.npy"
        np.save(f, np.random.default_rng(0).random((8, 1024, 1024), np.float32))
        t0 = time.time()
        n = 5
        for i in range(n):
            store.put(f, f"bench/blob{i}.npy", tier="hot")
        wall = time.time() - t0
        nbytes = f.stat().st_size * n
        rows.append(("measured_hot_put_gbps_wall", round(nbytes * 8 / wall / 1e9, 3),
                     "includes sha256 both ends"))
        rows.append(("simulated_hot_gbps", TIERS["hot"].bandwidth_gbps,
                     "tier model (paper 0.60)"))
    return rows
