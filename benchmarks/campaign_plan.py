"""Planned (admission-time locality) vs blind admission on a warm cluster.

``benchmarks/locality_throughput.py`` measures what *grant-time* scoring
buys once a queue is live; this bench measures the layer above it — the
campaign planner (``repro.core.campaign``) sharding the job array by data
placement *before* anything runs, the brainlife.io job-to-data move at the
batch-system layer. Setup mirrors the locality bench so numbers compose:

1. **Warm-up** — a locality-blind round-robin run over 4 nodes, each with
   its own cache dir (the multi-host shape in one process); cache dirs are
   snapshotted.
2. **Offline plan** — per-node digest summaries are harvested from the
   snapshot directories exactly as an HPC login node would
   (``summaries_from_cache_dirs``: no live coordinator anywhere), written
   to a summaries file, and fed to ``plan_campaign``. The resulting
   ``campaign.json`` is saved, reloaded, and replanned — byte-identical
   both ways, asserted here (the determinism/replayability contract).
3. **Measured runs** — derivatives wiped, caches restored, same 64 units,
   same mid-run chaos (node-1 dies after 4 units), and — crucially —
   **grant-time locality scoring OFF in both runs**, so the only difference
   is admission: *blind* drains an unpartitioned backlog (what a
   placement-blind job array degrades to), *planned* seeds each node's
   partition from its campaign shard.

Acceptance gate (checked here and in CI): planned admission must achieve a
**strictly higher cache hit-rate** and move **strictly fewer bytes from
storage** than blind admission. Artifacts land in ``benchmarks/out/``
(``campaign_plan.json`` + the plan itself as ``campaign.json``; CI uploads
both). Runs thread-pinned in a subprocess like the other executor benches;
override the bench artifact path with ``REPRO_BENCH_JSON``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

from ._pin import run_pinned
from ._stats import cache_totals as _cache_totals, hit_rate as _hit_rate

N_SUBJECTS = 32
SESSIONS = 2                        # 64 units
SHAPE = (32, 32, 32)                # 128 KiB float32 input per unit
PIPELINE = "bias_correct"
NODES = 4
CHAOS = {"node-1": 4}

_INPROC_FLAG = "REPRO_CAMPAIGN_BENCH_INPROC"
_OUT_DIR = Path(__file__).resolve().parent / "out"
_JSON_OUT = _OUT_DIR / "campaign_plan.json"
_PLAN_OUT = _OUT_DIR / "campaign.json"

def _run_inproc():
    from repro.core import (builtin_pipelines, query_available_work,
                            synthesize_dataset)
    from repro.core.campaign import CampaignPlan, Cohort, plan_campaign
    from repro.dist import ClusterRunner
    from repro.dist.cache import (load_summary_file, save_summary_file,
                                  summaries_from_cache_dirs)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        ds = synthesize_dataset(td / "ds", "campbench",
                                n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        units, excluded = query_available_work(ds, pipe)
        assert len(units) == N_SUBJECTS * SESSIONS
        deriv = Path(ds.root) / "derivatives"
        in_bits = sum(u.total_input_bytes for u in units) * 8
        caches = td / "hosts"
        snapshot = td / "hosts-warm"

        # -- warm-up: populate per-node caches, locality-blind ---------------
        warm = ClusterRunner(pipe, ds.root, nodes=NODES, locality=False,
                             cache_dir=caches, cache_per_node=True,
                             straggler_factor=100.0, poll_s=0.02)
        results = warm.run(units)
        ok = sum(r.status == "ok" for r in results)
        if ok != len(units):
            raise RuntimeError(f"warm-up incomplete: {ok}/{len(units)} ok")
        shutil.copytree(caches, snapshot)
        shutil.rmtree(deriv, ignore_errors=True)

        # -- offline planning: harvest -> file -> plan -> replay -------------
        summaries = summaries_from_cache_dirs(snapshot)
        assert sorted(summaries) == [f"node-{i}" for i in range(NODES)]
        sfile = save_summary_file(td / "summaries.json", summaries)
        status = {"disk_free_gb": 64.0}          # fixed: replay determinism
        cohort = Cohort(ds.name, pipe.name, pipe.digest(), units, excluded)
        plan = plan_campaign([cohort], load_summary_file(sfile),
                             status=status)
        replan = plan_campaign([cohort], load_summary_file(sfile),
                               status=status)
        if plan.to_json() != replan.to_json():
            raise RuntimeError("replanning from identical inputs is not "
                               "byte-identical — determinism regression")
        plan_path = plan.save(td / "campaign.json")
        if CampaignPlan.load(plan_path).to_json() != plan.to_json():
            raise RuntimeError("campaign.json load/save round-trip is not "
                               "byte-identical — replay regression")
        warm_shards = [s for s in plan.shards if s.node_id]
        assert sorted(plan.assigned_unit_ids()) == \
            sorted(u.job_id for u in units)

        # -- measured: same warm bytes, same chaos, admission blind/planned --
        def measure(seeded_plan) -> dict:
            shutil.rmtree(caches, ignore_errors=True)
            shutil.copytree(snapshot, caches)
            units_now, _ = query_available_work(ds, pipe)
            runner = ClusterRunner(
                pipe, ds.root, nodes=NODES, locality=False,
                partition="backlog" if seeded_plan is None else "round_robin",
                plan=seeded_plan, cache_dir=caches, cache_per_node=True,
                die_after=dict(CHAOS), lease_ttl_s=0.6, hb_interval_s=0.1,
                straggler_factor=100.0, poll_s=0.02)
            t0 = time.time()
            results = runner.run(units_now)
            dt = time.time() - t0
            ok = sum(r.status == "ok" for r in results)
            if ok != len(units_now):
                raise RuntimeError(
                    f"planned={seeded_plan is not None}: "
                    f"{ok}/{len(units_now)} ok")
            totals = _cache_totals(runner)
            shutil.rmtree(deriv, ignore_errors=True)
            return {
                "seconds": round(dt, 3), "ok": ok,
                "hits": totals.get("hits", 0),
                "misses": totals.get("misses", 0),
                "hit_rate": round(_hit_rate(totals), 4),
                "bytes_from_cache": totals.get("bytes_from_cache", 0),
                "bytes_from_storage": totals.get("bytes_from_storage", 0),
                "effective_gbps": round(in_bits / dt / 1e9, 3),
                "requeued": len(runner.stats.requeued),
                "steals": sum(runner.stats.steals.values()),
            }

        blind = measure(None)
        planned = measure(plan)

        for phase, m in (("blind", blind), ("planned", planned)):
            rows.append((f"campaign_hit_rate_{phase}", m["hit_rate"],
                         f"{m['hits']}/{m['hits'] + m['misses']} warm-cluster "
                         f"input fetches served node-local ({phase} admission)"))
            rows.append((f"campaign_storage_bytes_{phase}",
                         m["bytes_from_storage"],
                         f"input bytes moved from shared storage "
                         f"({phase} admission)"))
        saved = blind["bytes_from_storage"] - planned["bytes_from_storage"]
        rows.append(("campaign_storage_bytes_saved", saved,
                     "bytes admission-time planning kept off the storage "
                     "link on the same warm 64-unit chaos schedule, with "
                     "grant-time scoring disabled in both runs"))
        rows.append(("campaign_est_local_fraction",
                     round(plan.est_local_fraction(), 4),
                     f"planner's estimate; {len(warm_shards)} warm shards "
                     f"over {len(plan.nodes)} nodes"))

        # acceptance gate (CI runs this module; a regression must fail loud):
        # planned admission strictly beats blind on reuse and data movement
        if planned["hit_rate"] <= blind["hit_rate"]:
            raise RuntimeError(
                f"planned-admission hit rate {planned['hit_rate']} not "
                f"strictly above blind {blind['hit_rate']} — campaign "
                f"planner regression")
        if planned["bytes_from_storage"] >= blind["bytes_from_storage"]:
            raise RuntimeError(
                f"planned admission moved {planned['bytes_from_storage']} "
                f"bytes from storage, not strictly below blind "
                f"{blind['bytes_from_storage']} — campaign planner regression")

        plan_json = plan.to_json()

    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    # the plan itself is an artifact: auditors diff campaign.json across
    # runs to confirm identical world-state produced identical admission
    (out.parent / _PLAN_OUT.name).write_text(plan_json)
    out.write_text(json.dumps({
        "units": N_SUBJECTS * SESSIONS, "shape": list(SHAPE), "nodes": NODES,
        "chaos": {"die_after": CHAOS},
        "plan": {"inputs_hash": json.loads(plan_json)["inputs_hash"],
                 "shards": len(json.loads(plan_json)["shards"]),
                 "throttle": json.loads(plan_json)["throttle"]},
        "blind": blind, "planned": planned,
        "gate": {"hit_rate_strictly_higher": True,
                 "storage_bytes_strictly_lower": True,
                 "plan_replay_byte_identical": True},
        "rows": [[n, v, d] for n, v, d in rows],
    }, indent=1))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.campaign_plan", "campaign_",
                      _INPROC_FLAG, _run_inproc, timeout=1800)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
