"""Planned (admission-time locality) vs blind admission on a warm cluster.

``benchmarks/locality_throughput.py`` measures what *grant-time* scoring
buys once a queue is live; this bench measures the layer above it — the
campaign planner (``repro.core.campaign``) sharding the job array by data
placement *before* anything runs, the brainlife.io job-to-data move at the
batch-system layer. Setup mirrors the locality bench so numbers compose:

1. **Warm-up** — a locality-blind round-robin run over 4 nodes, each with
   its own cache dir (the multi-host shape in one process); cache dirs are
   snapshotted.
2. **Offline plan** — per-node digest summaries are harvested from the
   snapshot directories exactly as an HPC login node would
   (``summaries_from_cache_dirs``: no live coordinator anywhere), written
   to a summaries file, and fed to ``plan_campaign``. The resulting
   ``campaign.json`` is saved, reloaded, and replanned — byte-identical
   both ways, asserted here (the determinism/replayability contract).
3. **Measured runs** — derivatives wiped, caches restored, same 64 units,
   same mid-run chaos (node-1 dies after 4 units), and — crucially —
   **grant-time locality scoring OFF in both runs**, so the only difference
   is admission: *blind* drains an unpartitioned backlog (what a
   placement-blind job array degrades to), *planned* seeds each node's
   partition from its campaign shard.

Acceptance gate (checked here and in CI): planned admission must achieve a
**strictly higher cache hit-rate** and move **strictly fewer bytes from
storage** than blind admission. Artifacts land in ``benchmarks/out/``
(``campaign_plan.json`` + the plan itself as ``campaign.json``; CI uploads
both). Runs thread-pinned in a subprocess like the other executor benches;
override the bench artifact path with ``REPRO_BENCH_JSON``.

**Staged arm** (``REPRO_CAMPAIGN_BENCH_ARMS=staged``, its own CI matrix
row): the same contest on a two-stage dependency DAG — 64 ``bias_correct``
producers feeding 64 ``affine_register`` consumers whose inputs are the
producers' committed outputs. The probe run executes stage 1 with output
write-through *disabled*, so the snapshotted caches hold stage-1 inputs
only: stage-2 input digests are invisible to every harvested summary, and
the only way the planner can warm-place a consumer is **producer
placement** (admit the child to the shard where its parents' outputs will
land). Both measured runs then execute the full DAG with write-through on;
they differ only in admission. Gate: producer-placed admission strictly
beats placement-blind on hit-rate AND bytes-from-storage. Artifact:
``benchmarks/out/campaign_staged.json``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

from ._pin import run_pinned
from ._stats import cache_totals as _cache_totals, hit_rate as _hit_rate

N_SUBJECTS = 32
SESSIONS = 2                        # 64 units
SHAPE = (32, 32, 32)                # 128 KiB float32 input per unit
PIPELINE = "bias_correct"
NODES = 4
CHAOS = {"node-1": 4}

_INPROC_FLAG = "REPRO_CAMPAIGN_BENCH_INPROC"
_STAGED_FLAG = "REPRO_CAMPAIGN_BENCH_STAGED_INPROC"
ARMS_ENV = "REPRO_CAMPAIGN_BENCH_ARMS"
_OUT_DIR = Path(__file__).resolve().parent / "out"
_JSON_OUT = _OUT_DIR / "campaign_plan.json"
_PLAN_OUT = _OUT_DIR / "campaign.json"
_STAGED_OUT = _OUT_DIR / "campaign_staged.json"

def _run_inproc():
    from repro.core import (builtin_pipelines, query_available_work,
                            synthesize_dataset)
    from repro.core.campaign import CampaignPlan, Cohort, plan_campaign
    from repro.dist import ClusterRunner
    from repro.dist.cache import (load_summary_file, save_summary_file,
                                  summaries_from_cache_dirs)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        ds = synthesize_dataset(td / "ds", "campbench",
                                n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        units, excluded = query_available_work(ds, pipe)
        assert len(units) == N_SUBJECTS * SESSIONS
        deriv = Path(ds.root) / "derivatives"
        in_bits = sum(u.total_input_bytes for u in units) * 8
        caches = td / "hosts"
        snapshot = td / "hosts-warm"

        # -- warm-up: populate per-node caches, locality-blind ---------------
        warm = ClusterRunner(pipe, ds.root, nodes=NODES, locality=False,
                             cache_dir=caches, cache_per_node=True,
                             straggler_factor=100.0, poll_s=0.02)
        results = warm.run(units)
        ok = sum(r.status == "ok" for r in results)
        if ok != len(units):
            raise RuntimeError(f"warm-up incomplete: {ok}/{len(units)} ok")
        shutil.copytree(caches, snapshot)
        shutil.rmtree(deriv, ignore_errors=True)

        # -- offline planning: harvest -> file -> plan -> replay -------------
        summaries = summaries_from_cache_dirs(snapshot)
        assert sorted(summaries) == [f"node-{i}" for i in range(NODES)]
        sfile = save_summary_file(td / "summaries.json", summaries)
        status = {"disk_free_gb": 64.0}          # fixed: replay determinism
        cohort = Cohort(ds.name, pipe.name, pipe.digest(), units, excluded)
        plan = plan_campaign([cohort], load_summary_file(sfile),
                             status=status)
        replan = plan_campaign([cohort], load_summary_file(sfile),
                               status=status)
        if plan.to_json() != replan.to_json():
            raise RuntimeError("replanning from identical inputs is not "
                               "byte-identical — determinism regression")
        plan_path = plan.save(td / "campaign.json")
        if CampaignPlan.load(plan_path).to_json() != plan.to_json():
            raise RuntimeError("campaign.json load/save round-trip is not "
                               "byte-identical — replay regression")
        warm_shards = [s for s in plan.shards if s.node_id]
        assert sorted(plan.assigned_unit_ids()) == \
            sorted(u.job_id for u in units)

        # -- measured: same warm bytes, same chaos, admission blind/planned --
        def measure(seeded_plan) -> dict:
            shutil.rmtree(caches, ignore_errors=True)
            shutil.copytree(snapshot, caches)
            units_now, _ = query_available_work(ds, pipe)
            runner = ClusterRunner(
                pipe, ds.root, nodes=NODES, locality=False,
                partition="backlog" if seeded_plan is None else "round_robin",
                plan=seeded_plan, cache_dir=caches, cache_per_node=True,
                die_after=dict(CHAOS), lease_ttl_s=0.6, hb_interval_s=0.1,
                straggler_factor=100.0, poll_s=0.02)
            t0 = time.time()
            results = runner.run(units_now)
            dt = time.time() - t0
            ok = sum(r.status == "ok" for r in results)
            if ok != len(units_now):
                raise RuntimeError(
                    f"planned={seeded_plan is not None}: "
                    f"{ok}/{len(units_now)} ok")
            totals = _cache_totals(runner)
            shutil.rmtree(deriv, ignore_errors=True)
            return {
                "seconds": round(dt, 3), "ok": ok,
                "hits": totals.get("hits", 0),
                "misses": totals.get("misses", 0),
                "hit_rate": round(_hit_rate(totals), 4),
                "bytes_from_cache": totals.get("bytes_from_cache", 0),
                "bytes_from_storage": totals.get("bytes_from_storage", 0),
                "effective_gbps": round(in_bits / dt / 1e9, 3),
                "requeued": len(runner.stats.requeued),
                "steals": sum(runner.stats.steals.values()),
            }

        blind = measure(None)
        planned = measure(plan)

        for phase, m in (("blind", blind), ("planned", planned)):
            rows.append((f"campaign_hit_rate_{phase}", m["hit_rate"],
                         f"{m['hits']}/{m['hits'] + m['misses']} warm-cluster "
                         f"input fetches served node-local ({phase} admission)"))
            rows.append((f"campaign_storage_bytes_{phase}",
                         m["bytes_from_storage"],
                         f"input bytes moved from shared storage "
                         f"({phase} admission)"))
        saved = blind["bytes_from_storage"] - planned["bytes_from_storage"]
        rows.append(("campaign_storage_bytes_saved", saved,
                     "bytes admission-time planning kept off the storage "
                     "link on the same warm 64-unit chaos schedule, with "
                     "grant-time scoring disabled in both runs"))
        rows.append(("campaign_est_local_fraction",
                     round(plan.est_local_fraction(), 4),
                     f"planner's estimate; {len(warm_shards)} warm shards "
                     f"over {len(plan.nodes)} nodes"))

        # acceptance gate (CI runs this module; a regression must fail loud):
        # planned admission strictly beats blind on reuse and data movement
        if planned["hit_rate"] <= blind["hit_rate"]:
            raise RuntimeError(
                f"planned-admission hit rate {planned['hit_rate']} not "
                f"strictly above blind {blind['hit_rate']} — campaign "
                f"planner regression")
        if planned["bytes_from_storage"] >= blind["bytes_from_storage"]:
            raise RuntimeError(
                f"planned admission moved {planned['bytes_from_storage']} "
                f"bytes from storage, not strictly below blind "
                f"{blind['bytes_from_storage']} — campaign planner regression")

        plan_json = plan.to_json()

    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    # the plan itself is an artifact: auditors diff campaign.json across
    # runs to confirm identical world-state produced identical admission
    (out.parent / _PLAN_OUT.name).write_text(plan_json)
    out.write_text(json.dumps({
        "units": N_SUBJECTS * SESSIONS, "shape": list(SHAPE), "nodes": NODES,
        "chaos": {"die_after": CHAOS},
        "plan": {"inputs_hash": json.loads(plan_json)["inputs_hash"],
                 "shards": len(json.loads(plan_json)["shards"]),
                 "throttle": json.loads(plan_json)["throttle"]},
        "blind": blind, "planned": planned,
        "gate": {"hit_rate_strictly_higher": True,
                 "storage_bytes_strictly_lower": True,
                 "plan_replay_byte_identical": True},
        "rows": [[n, v, d] for n, v, d in rows],
    }, indent=1))
    return rows


def _run_staged_inproc():
    from repro.core import (Provenance, builtin_pipelines,
                            query_available_work, synthesize_dataset)
    from repro.core.campaign import Cohort, plan_campaign
    from repro.core.query import WorkUnit
    from repro.core.workflow import WRITE_THROUGH_ENV
    from repro.dist import ClusterRunner
    from repro.dist.cache import (load_summary_file, save_summary_file,
                                  summaries_from_cache_dirs)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        ds = synthesize_dataset(td / "ds", "stagedbench",
                                n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipes = builtin_pipelines()
        s1_pipe, s2_pipe = pipes["bias_correct"], pipes["affine_register"]
        s1, excluded = query_available_work(ds, s1_pipe)
        assert len(s1) == N_SUBJECTS * SESSIONS
        deriv = Path(ds.root) / "derivatives"
        caches = td / "hosts"
        snapshot = td / "hosts-warm"

        # -- probe: stage 1 only, output write-through OFF -------------------
        # The snapshotted caches hold stage-1 *inputs* only, so stage-2
        # digests (harvested below from provenance) are invisible to every
        # summary: warm placement of the consumers can come from producer
        # placement alone.
        os.environ[WRITE_THROUGH_ENV] = "0"
        try:
            probe = ClusterRunner(s1_pipe, ds.root, nodes=NODES,
                                  locality=False, cache_dir=caches,
                                  cache_per_node=True,
                                  straggler_factor=100.0, poll_s=0.02)
            results = probe.run(s1)
            ok = sum(r.status == "ok" for r in results)
            if ok != len(s1):
                raise RuntimeError(f"probe incomplete: {ok}/{len(s1)} ok")
        finally:
            os.environ.pop(WRITE_THROUGH_ENV, None)
        shutil.copytree(caches, snapshot)

        # -- stage 2 from committed provenance: outputs become inputs --------
        # deterministic pipelines => re-running stage 1 in the measured arms
        # reproduces these exact digests, so the plan stays valid
        s2 = []
        for u in s1:
            prov = Provenance.load(Path(u.out_dir))
            fname = f"sub-{u.subject}_ses-{u.session}_T1w_biascorr.npy"
            digest = prov.outputs[fname]
            path = Path(u.out_dir) / fname
            rel = str(path.relative_to(ds.root))
            s2.append(WorkUnit(
                dataset=u.dataset, subject=u.subject, session=u.session,
                pipeline=s2_pipe.name, pipeline_digest=s2_pipe.digest(),
                inputs={"T1w": rel},
                out_dir=str(Path(ds.root) / "derivatives" / s2_pipe.name /
                            f"sub-{u.subject}" / f"ses-{u.session}"),
                input_digests={"T1w": digest},
                input_bytes={"T1w": path.stat().st_size},
                depends_on=[u.job_id]))
        shutil.rmtree(deriv, ignore_errors=True)
        units = s1 + s2
        in_bits = sum(u.total_input_bytes for u in units) * 8

        # -- offline plan over the full DAG ----------------------------------
        summaries = summaries_from_cache_dirs(snapshot)
        sfile = save_summary_file(td / "summaries.json", summaries)
        decoded = load_summary_file(sfile)
        # the premise the arm rests on: no consumer digest is (even Bloom-)
        # visible in any harvested summary
        assert not any(u.input_digests["T1w"] in s
                       for u in s2 for s in decoded.values()), \
            "stage-2 digests leaked into the probe caches"
        status = {"disk_free_gb": 64.0}
        cohorts = [Cohort(ds.name, s1_pipe.name, s1_pipe.digest(), s1,
                          excluded),
                   Cohort(ds.name, s2_pipe.name, s2_pipe.digest(), s2, [])]
        plan = plan_campaign(cohorts, decoded, status=status)
        assert sorted(plan.assigned_unit_ids()) == \
            sorted(u.job_id for u in units)
        # producer placement engaged: consumers landed on warm shards even
        # though no summary knows their bytes
        node_of = {jid: s.node_id for s in plan.shards for jid in s.unit_ids}
        placed_warm = sum(1 for u in s2 if node_of[u.job_id] is not None)
        if not placed_warm:
            raise RuntimeError("no consumer was producer-placed — staged "
                               "planner regression")

        # -- measured: full DAG, write-through ON, blind vs planned ----------
        def measure(seeded_plan) -> dict:
            shutil.rmtree(caches, ignore_errors=True)
            shutil.copytree(snapshot, caches)
            runner = ClusterRunner(
                pipes, ds.root, nodes=NODES, locality=False,
                partition="backlog" if seeded_plan is None else "round_robin",
                plan=seeded_plan, cache_dir=caches, cache_per_node=True,
                die_after=dict(CHAOS), lease_ttl_s=0.6, hb_interval_s=0.1,
                straggler_factor=100.0, poll_s=0.02)
            t0 = time.time()
            results = runner.run(units)
            dt = time.time() - t0
            ok = sum(r.status == "ok" for r in results)
            if ok != len(units):
                raise RuntimeError(
                    f"staged planned={seeded_plan is not None}: "
                    f"{ok}/{len(units)} ok")
            totals = _cache_totals(runner)
            shutil.rmtree(deriv, ignore_errors=True)
            return {
                "seconds": round(dt, 3), "ok": ok,
                "hits": totals.get("hits", 0),
                "misses": totals.get("misses", 0),
                "hit_rate": round(_hit_rate(totals), 4),
                "bytes_from_cache": totals.get("bytes_from_cache", 0),
                "bytes_from_storage": totals.get("bytes_from_storage", 0),
                "effective_gbps": round(in_bits / dt / 1e9, 3),
                "requeued": len(runner.stats.requeued),
                "steals": sum(runner.stats.steals.values()),
            }

        blind = measure(None)
        planned = measure(plan)

        for phase, m in (("blind", blind), ("planned", planned)):
            rows.append((f"campaign_staged_hit_rate_{phase}", m["hit_rate"],
                         f"{m['hits']}/{m['hits'] + m['misses']} input "
                         f"fetches served node-local across the 2-stage DAG "
                         f"({phase} admission)"))
            rows.append((f"campaign_staged_storage_bytes_{phase}",
                         m["bytes_from_storage"],
                         f"input bytes moved from shared storage "
                         f"({phase} admission)"))
        saved = blind["bytes_from_storage"] - planned["bytes_from_storage"]
        rows.append(("campaign_staged_storage_bytes_saved", saved,
                     "bytes producer placement kept off the storage link on "
                     "the same 128-unit staged chaos schedule"))
        rows.append(("campaign_staged_consumers_placed", placed_warm,
                     f"of {len(s2)} consumers admitted to their producers' "
                     f"shard with zero summary visibility of their inputs"))

        # acceptance gate: producer placement strictly beats blind on both
        if planned["hit_rate"] <= blind["hit_rate"]:
            raise RuntimeError(
                f"staged planned hit rate {planned['hit_rate']} not "
                f"strictly above blind {blind['hit_rate']} — producer "
                f"placement regression")
        if planned["bytes_from_storage"] >= blind["bytes_from_storage"]:
            raise RuntimeError(
                f"staged planned moved {planned['bytes_from_storage']} "
                f"bytes from storage, not strictly below blind "
                f"{blind['bytes_from_storage']} — producer placement "
                f"regression")

        plan_json = plan.to_json()

    _STAGED_OUT.parent.mkdir(parents=True, exist_ok=True)
    _STAGED_OUT.write_text(json.dumps({
        "units": len(units), "stages": 2, "shape": list(SHAPE),
        "nodes": NODES, "chaos": {"die_after": CHAOS},
        "plan": {"inputs_hash": json.loads(plan_json)["inputs_hash"],
                 "shards": len(json.loads(plan_json)["shards"]),
                 "consumers_producer_placed": placed_warm},
        "blind": blind, "planned": planned,
        "gate": {"hit_rate_strictly_higher": True,
                 "storage_bytes_strictly_lower": True},
        "rows": [[n, v, d] for n, v, d in rows],
    }, indent=1))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``.

    ``REPRO_CAMPAIGN_BENCH_ARMS`` selects the arms (comma-separated:
    ``plan``, ``staged``; default ``plan``) — the staged arm runs in its own
    CI matrix row so a producer-placement regression fails a dedicated,
    artifact-uploading job."""
    if os.environ.get(_INPROC_FLAG):
        return _run_inproc()
    if os.environ.get(_STAGED_FLAG):
        return _run_staged_inproc()
    arms = [a.strip() for a in
            os.environ.get(ARMS_ENV, "plan").split(",") if a.strip()]
    rows = []
    if "plan" in arms:
        rows += run_pinned("benchmarks.campaign_plan", "campaign_",
                           _INPROC_FLAG, _run_inproc, timeout=1800)
    if "staged" in arms:
        rows += run_pinned("benchmarks.campaign_plan", "campaign_staged_",
                           _STAGED_FLAG, _run_staged_inproc, timeout=1800)
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
