# One function per paper table/figure. Prints ``name,value,derived`` CSV.
from __future__ import annotations

import sys
import traceback
from pathlib import Path


def main() -> None:
    # artifacts live under benchmarks/out/ — gitignored, so a fresh checkout
    # doesn't have it; recreate rather than making every bench defensive
    (Path(__file__).resolve().parent / "out").mkdir(parents=True,
                                                    exist_ok=True)
    from . import (campaign_plan, cluster_throughput, executor_throughput,
                   ingest_stream, kernel_bench, locality_throughput,
                   peer_fabric, pipeline_throughput, recovery, rpc_throughput,
                   table1_cost, train_step_bench)
    mods = [("table1_cost", table1_cost), ("pipeline_throughput", pipeline_throughput),
            ("executor_throughput", executor_throughput),
            ("cluster_throughput", cluster_throughput),
            ("rpc_throughput", rpc_throughput),
            ("locality_throughput", locality_throughput),
            ("peer_fabric", peer_fabric),
            ("ingest_stream", ingest_stream),
            ("campaign_plan", campaign_plan),
            ("recovery", recovery),
            ("train_step", train_step_bench), ("kernels", kernel_bench)]
    print("name,value,derived")
    failed = 0
    for name, mod in mods:
        try:
            for row in mod.run():
                n, v, d = row
                print(f"{n},{v},{str(d).replace(',', ';')}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}_FAILED,,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
