"""Coordinator crash-recovery: time-to-recover, time-to-resume, wasted work.

The journal (``repro.dist.journal``) exists so a coordinator crash costs a
campaign seconds, not the run — this bench puts a number on "seconds" and
gates the safety half. One journaled rpc-transport run over ``N_UNITS``
units and four worker nodes; a harness thread hard-kills and recovers the
coordinator (``ClusterRunner.restart_coordinator``) twice, at ~25% and ~50%
progress. Measured:

* ``recovery_recover_s`` — replaying snapshot + WAL tail into a fresh
  :class:`~repro.dist.queue.WorkQueue` (max over the restarts: the worst
  interruption an operator would see);
* ``recovery_downtime_s`` — crash to new server accepting (recover + rebind);
* ``recovery_resume_s`` — crash to the first *new* completion committed on
  the recovered incarnation: the workers' reconnect + re-register latency
  rides on top of replay here;
* ``recovery_wasted_units`` — duplicate executions (total results minus
  unit count): work the crash forced the cluster to redo. Leases granted a
  TTL of grace at recovery keep this near zero; it is reported, not gated,
  because a lease that genuinely straddles the kill is *supposed* to re-run.

Acceptance gate (CI): ``recovery_lost_units`` must be exactly 0 — every
unit ends with a committed status and an ok provenance on disk after two
coordinator deaths — and at least one restart must actually have happened.
Gates fail after the JSON lands, so the artifact always shows the numbers
the failure is about. Writes ``benchmarks/out/recovery.json``
(``REPRO_BENCH_JSON`` overrides).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from pathlib import Path

from ._pin import run_pinned

N_SUBJECTS = 12
SESSIONS = 2                        # 24 units
SHAPE = (48, 48, 48)
PIPELINE = "bias_correct"
NODES = 4
RESTARTS_AT = (0.25, 0.50)          # progress fractions to kill at

_INPROC_FLAG = "REPRO_RECOVERY_BENCH_INPROC"
_JSON_OUT = Path(__file__).resolve().parent / "out" / "recovery.json"


def _run_inproc():
    from repro.core import (Provenance, builtin_pipelines,
                            query_available_work, synthesize_dataset)
    from repro.dist import ClusterRunner

    rows = []
    report: dict = {"units": N_SUBJECTS * SESSIONS, "nodes": NODES,
                    "restarts_at": list(RESTARTS_AT)}
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        ds = synthesize_dataset(td / "ds", "recbench",
                                n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        units, _ = query_available_work(ds, pipe)
        runner = ClusterRunner(
            pipe, ds.root, nodes=NODES, transport="rpc",
            lease_ttl_s=2.0, hb_interval_s=0.1, poll_s=0.02,
            straggler_factor=100.0, journal_dir=td / "journal")

        restarts = []

        def harass():
            for frac in RESTARTS_AT:
                want = max(1, int(len(units) * frac))
                deadline = time.monotonic() + 60
                while time.monotonic() < deadline:
                    q = runner.queue
                    if (q is not None and runner.server is not None
                            and len(q.done_status()) >= want):
                        break
                    time.sleep(0.02)
                done_before = len(runner.queue.done_status())
                t_kill = time.monotonic()
                info = runner.restart_coordinator()
                if info is None:
                    return               # run finished first: stand down
                # resume = first completion the *new* incarnation commits
                q = runner.queue
                while (len(q.done_status()) <= done_before
                       and time.monotonic() - t_kill < 60):
                    time.sleep(0.01)
                info["resume_s"] = time.monotonic() - t_kill
                restarts.append(info)
                time.sleep(0.3)

        h = threading.Thread(target=harass, daemon=True)
        t0 = time.monotonic()
        h.start()
        results = runner.run(units)
        wall_s = time.monotonic() - t0
        h.join(timeout=10)

        committed = [r for r in results if r.status != "speculative"]
        ok = [r for r in committed if r.status == "ok"]
        provs_ok = sum(
            1 for u in units
            if (p := Provenance.load(Path(u.out_dir))) is not None
            and p.status == "ok")
        lost = len(units) - len(committed)
        wasted = len(results) - len(units)

        rows.append(("recovery_restarts", len(restarts),
                     "coordinator kills actually injected"))
        if restarts:
            rows.append(("recovery_recover_s",
                         round(max(r["recover_s"] for r in restarts), 4),
                         "max WAL replay -> live WorkQueue"))
            rows.append(("recovery_downtime_s",
                         round(max(r["total_s"] for r in restarts), 4),
                         "max crash -> new server accepting"))
            rows.append(("recovery_resume_s",
                         round(max(r["resume_s"] for r in restarts), 4),
                         "max crash -> first new completion"))
        rows.append(("recovery_wasted_units", wasted,
                     "duplicate executions forced by the kills"))
        rows.append(("recovery_lost_units", lost,
                     "units without a committed result (gate: 0)"))
        rows.append(("recovery_wall_s", round(wall_s, 3),
                     f"{len(units)} units, {len(restarts)} mid-run kills"))
        report["restarts"] = restarts
        report["ok_results"] = len(ok)
        report["ok_provenances"] = provs_ok

    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    report["rows"] = [[n, v, d] for n, v, d in rows]
    out.write_text(json.dumps(report, indent=1))
    # gates fail *after* the JSON lands
    gate_errors = []
    if lost != 0:
        gate_errors.append(f"{lost} unit(s) lost across coordinator kills")
    if provs_ok != len(units):
        gate_errors.append(f"{len(units) - provs_ok} unit(s) without an ok "
                           f"provenance on disk")
    if not restarts:
        gate_errors.append("no coordinator restart was injected (run "
                           "finished too fast to measure recovery)")
    if gate_errors:
        raise RuntimeError("; ".join(gate_errors))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.recovery", "recovery_",
                      _INPROC_FLAG, _run_inproc, timeout=900)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
