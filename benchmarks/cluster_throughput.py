"""Multi-node executor throughput vs the single-host baseline.

Runs a synthetic 64-unit dataset through ``LocalRunner(workers=1)`` (the
paper's serial burst path with pipelined prefetch) and through
``ClusterRunner`` at 2 and 4 in-process nodes, interleaved over ``REPS``
repetitions with per-config medians (shared hosts drift; see
``executor_throughput`` for the methodology notes). One extra 4-node row
re-runs the sweep with an injected node death mid-run — the lease-reaping
path — to show the throughput cost of losing a node is bounded by the
requeued units, not a stalled job.

Like ``executor_throughput``, the sweep executes in a subprocess with
XLA/BLAS intra-op parallelism pinned to one thread so node scaling — not
operator threading — is what gets measured. Writes the full sample set to
``benchmarks/out/cluster_throughput.json`` (CI uploads it as an artifact;
override the path with ``REPRO_BENCH_JSON``).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

from ._pin import run_pinned

N_SUBJECTS = 32
SESSIONS = 2                       # 32 x 2 = 64 units
SHAPE = (48, 48, 48)               # heavy enough that XLA compute (which
                                   # releases the GIL) dominates jax dispatch
PIPELINE = "bias_correct"
NODE_SWEEP = (2, 4)
REPS = 3

_INPROC_FLAG = "REPRO_CLUSTER_BENCH_INPROC"
_JSON_OUT = Path(__file__).resolve().parent / "out" / "cluster_throughput.json"


def _run_inproc():
    from repro.core import (LocalRunner, builtin_pipelines,
                            query_available_work, synthesize_dataset)
    from repro.dist import ClusterRunner
    rows = []
    samples: dict = {}
    with tempfile.TemporaryDirectory() as td:
        ds = synthesize_dataset(Path(td), "clbench", n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        deriv = Path(ds.root) / "derivatives"

        # warm jit caches so no config pays compile time
        units, _ = query_available_work(ds, pipe)
        LocalRunner(pipe, ds.root).run(units[:2])
        shutil.rmtree(deriv, ignore_errors=True)

        def measure(cfg):
            units, _ = query_available_work(ds, pipe)
            t0 = time.time()
            if cfg == "local_w1":
                results = LocalRunner(pipe, ds.root, workers=1).run(units)
            elif cfg == "nodes4_kill1":
                runner = ClusterRunner(pipe, ds.root, nodes=4,
                                       die_after={"node-1": 4},
                                       lease_ttl_s=0.6, hb_interval_s=0.1)
                results = runner.run(units)
            else:
                results = ClusterRunner(pipe, ds.root, nodes=int(cfg[5:])
                                        ).run(units)
            dt = time.time() - t0
            ok = sum(r.status == "ok" for r in results)
            shutil.rmtree(deriv, ignore_errors=True)
            return dt, ok, len(units)

        configs = ["local_w1"] + [f"nodes{n}" for n in NODE_SWEEP] + \
            ["nodes4_kill1"]
        samples = {c: [] for c in configs}
        for _ in range(REPS):
            for c in configs:
                samples[c].append(measure(c))
        med = {}
        for c in configs:
            ms = sorted(samples[c], key=lambda m: m[0])
            med[c] = ms[len(ms) // 2]
            dt, ok, n = med[c]
            rows.append((f"cluster_images_per_s_{c}", round(ok / dt, 3),
                         f"{ok}/{n} units in {dt:.2f}s (median of {REPS})"))
        rows.append(("cluster_speedup_nodes4_vs_local_w1",
                     round(med["local_w1"][0] / med["nodes4"][0], 3),
                     "median wall-clock: LocalRunner(workers=1) / 4 nodes"))
        rows.append(("cluster_speedup_nodes4_kill1_vs_local_w1",
                     round(med["local_w1"][0] / med["nodes4_kill1"][0], 3),
                     "as above with one node dying after 4 units"))
    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "units": N_SUBJECTS * SESSIONS, "shape": list(SHAPE), "reps": REPS,
        "samples_s": {c: [round(s[0], 4) for s in samples[c]]
                      for c in samples},
        "rows": [[n, v, d] for n, v, d in rows],
    }, indent=1))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.cluster_throughput", "cluster_",
                      _INPROC_FLAG, _run_inproc, timeout=1800)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
