"""Kernel oracles vs XLA-path wall time (CPU; interpret-mode kernels are not
timed — they are correctness artifacts. The XLA chunked paths ARE the
production CPU fallback)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref
from repro.kernels.rwkv6 import wkv6_ref
from repro.models.rwkv6 import wkv_chunked
from repro.models.layers import attention


def _time(f, *args, n=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, H, KV, S, D = 1, 4, 2, 1024, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    chunked = jax.jit(lambda q, k, v: attention(q, k, v, chunk=256))
    rows.append(("attn_chunked_xla_us", round(_time(chunked, q, k, v)),
                 f"B{B} H{H} S{S} chunked"))
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    full = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    rows.append(("attn_full_ref_us", round(_time(full, qt, kt, vt)),
                 "materialized S^2 oracle"))

    r = jax.random.normal(key, (1, 64, 2, 64))      # (B,S,H,dh) model layout
    kk = jax.random.normal(key, (1, 64, 2, 64))
    vv = jax.random.normal(key, (1, 64, 2, 64))
    lw = -jnp.exp(jax.random.normal(key, (1, 64, 2, 64)) * 0.3 - 2)
    u = jax.random.normal(key, (2, 64)) * 0.3
    st = jnp.zeros((1, 2, 64, 64))
    ch = jax.jit(lambda *a: wkv_chunked(*a, 32)[0])
    rows.append(("rwkv6_chunked_xla_us", round(_time(ch, r, kk, vv, lw, u, st)),
                 "chunk=32"))
    tr = lambda a: jnp.transpose(a, (0, 2, 1, 3))
    ref = jax.jit(lambda r_, k_, v_, l_, u_: wkv6_ref(tr(r_), tr(k_), tr(v_), tr(l_), u_))
    rows.append(("rwkv6_exact_scan_us", round(_time(ref, r, kk, vv, lw, u)),
                 "sequential oracle"))
    return rows
