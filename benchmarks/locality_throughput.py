"""Locality-aware placement vs locality-blind scheduling on a warm cluster.

The paper's headline perf claim is data-movement efficiency (0.60 Gb/s
storage->compute on the lab network vs 0.33 Gb/s from cloud storage). PR 3's
per-host input cache only helped when a unit *happened* to land where its
inputs were already warm; this bench measures what coordinator-side
digest-summary placement (``docs/cluster.md`` placement policy) buys on the
64-unit chaos schedule:

1. **Warm-up** — a locality-blind round-robin run over 4 nodes, each with
   its *own* cache dir (``cache_per_node``: the multi-host shape in one
   process). Each node ends up holding roughly its partition's input bytes.
   The cache dirs are snapshotted.
2. **Measured runs** — derivatives wiped, caches restored from the
   snapshot, and the same 64 units re-run twice from an unpartitioned
   backlog with mid-run chaos (one node dies after 4 units): once with
   ``locality=False`` (blind FIFO fills/steals — a unit lands wherever)
   and once with ``locality=True`` (grants/fills/steals/requeues scored
   against the per-node digest summaries).

Same seed, same chaos, same warm bytes — the only difference is whether the
coordinator *uses* the summaries. The acceptance gate (checked here and in
CI): locality-on must achieve a **strictly higher cache hit-rate** and move
**strictly fewer bytes from storage** than locality-off. The JSON artifact
(``benchmarks/out/locality_throughput.json``; CI uploads it) reports
hit-rates, bytes from cache vs storage, effective and storage-link Gb/s, and
the paper's 0.60/0.33 Gb/s reference for cross-PR trajectory.

Runs thread-pinned in a subprocess like the other executor benches
(see ``_pin``); override the artifact path with ``REPRO_BENCH_JSON``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

from ._pin import run_pinned
from ._stats import cache_totals as _cache_totals, hit_rate as _hit_rate

N_SUBJECTS = 32
SESSIONS = 2                        # 64 units
SHAPE = (32, 32, 32)                # 128 KiB float32 input per unit
PIPELINE = "bias_correct"
NODES = 4
PAPER_REFERENCE_GBPS = {"lab_network": 0.60, "cloud_storage": 0.33}

_INPROC_FLAG = "REPRO_LOCALITY_BENCH_INPROC"
_JSON_OUT = Path(__file__).resolve().parent / "out" / "locality_throughput.json"

def _run_inproc():
    from repro.core import (builtin_pipelines, query_available_work,
                            synthesize_dataset)
    from repro.dist import ClusterRunner
    rows = []
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        ds = synthesize_dataset(td / "ds", "locbench", n_subjects=N_SUBJECTS,
                                sessions_per_subject=SESSIONS, shape=SHAPE)
        pipe = builtin_pipelines()[PIPELINE]
        units, _ = query_available_work(ds, pipe)
        assert len(units) == N_SUBJECTS * SESSIONS
        deriv = Path(ds.root) / "derivatives"
        in_bits = sum(u.total_input_bytes for u in units) * 8
        caches = td / "hosts"
        snapshot = td / "hosts-warm"

        # -- warm-up: populate per-node caches, locality-blind ---------------
        warm = ClusterRunner(pipe, ds.root, nodes=NODES, locality=False,
                             cache_dir=caches, cache_per_node=True,
                             straggler_factor=100.0, poll_s=0.02)
        results = warm.run(units)
        ok = sum(r.status == "ok" for r in results)
        if ok != len(units):
            raise RuntimeError(f"warm-up incomplete: {ok}/{len(units)} ok")
        shutil.copytree(caches, snapshot)
        shutil.rmtree(deriv, ignore_errors=True)

        # -- measured: same warm bytes, same chaos, scoring on/off -----------
        def measure(locality: bool) -> dict:
            shutil.rmtree(caches, ignore_errors=True)
            shutil.copytree(snapshot, caches)
            units_now, _ = query_available_work(ds, pipe)
            runner = ClusterRunner(
                pipe, ds.root, nodes=NODES, locality=locality,
                partition="backlog", cache_dir=caches, cache_per_node=True,
                die_after={"node-1": 4}, lease_ttl_s=0.6, hb_interval_s=0.1,
                straggler_factor=100.0, poll_s=0.02)
            t0 = time.time()
            results = runner.run(units_now)
            dt = time.time() - t0
            ok = sum(r.status == "ok" for r in results)
            if ok != len(units_now):
                raise RuntimeError(
                    f"locality={locality}: {ok}/{len(units_now)} ok")
            totals = _cache_totals(runner)
            shutil.rmtree(deriv, ignore_errors=True)
            return {
                "seconds": round(dt, 3), "ok": ok,
                "hits": totals.get("hits", 0),
                "misses": totals.get("misses", 0),
                "hit_rate": round(_hit_rate(totals), 4),
                "bytes_from_cache": totals.get("bytes_from_cache", 0),
                "bytes_from_storage": totals.get("bytes_from_storage", 0),
                "effective_gbps": round(in_bits / dt / 1e9, 3),
                "storage_gbps": round(
                    totals.get("bytes_from_storage", 0) * 8 / dt / 1e9, 3),
                "locality_counters": runner.stats.locality,
                "requeued": len(runner.stats.requeued),
                "steals": sum(runner.stats.steals.values()),
            }

        off = measure(False)
        on = measure(True)

        for phase, m in (("off", off), ("on", on)):
            rows.append((f"locality_hit_rate_{phase}", m["hit_rate"],
                         f"{m['hits']}/{m['hits'] + m['misses']} warm-cluster "
                         f"input fetches served node-local (locality {phase})"))
            rows.append((f"locality_storage_bytes_{phase}",
                         m["bytes_from_storage"],
                         f"input bytes moved from shared storage "
                         f"(locality {phase})"))
            rows.append((f"locality_effective_gbps_{phase}",
                         m["effective_gbps"],
                         f"input bits consumed / wall-clock; paper reference "
                         f"{PAPER_REFERENCE_GBPS['lab_network']} (lab) vs "
                         f"{PAPER_REFERENCE_GBPS['cloud_storage']} (cloud)"))
        saved = off["bytes_from_storage"] - on["bytes_from_storage"]
        rows.append(("locality_storage_bytes_saved", saved,
                     "bytes locality-aware placement kept off the storage "
                     "link on the same warm 64-unit chaos schedule"))

        # acceptance gate (CI runs this module; a regression must fail loud):
        # strictly better reuse, strictly less data movement
        if on["hit_rate"] <= off["hit_rate"]:
            raise RuntimeError(
                f"locality-on hit rate {on['hit_rate']} not strictly above "
                f"locality-off {off['hit_rate']} — placement regression")
        if on["bytes_from_storage"] >= off["bytes_from_storage"]:
            raise RuntimeError(
                f"locality-on moved {on['bytes_from_storage']} bytes from "
                f"storage, not strictly below locality-off "
                f"{off['bytes_from_storage']} — placement regression")

    out = Path(os.environ.get("REPRO_BENCH_JSON", _JSON_OUT))
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "units": N_SUBJECTS * SESSIONS, "shape": list(SHAPE), "nodes": NODES,
        "chaos": {"die_after": {"node-1": 4}},
        "paper_reference_gbps": PAPER_REFERENCE_GBPS,
        "locality_off": off, "locality_on": on,
        "gate": {"hit_rate_strictly_higher": True,
                 "storage_bytes_strictly_lower": True},
        "rows": [[n, v, d] for n, v, d in rows],
    }, indent=1))
    return rows


def run():
    """Benchmark entry (benchmarks.run): re-exec pinned — see ``_pin``."""
    return run_pinned("benchmarks.locality_throughput", "locality_",
                      _INPROC_FLAG, _run_inproc, timeout=1800)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(c) for c in row))
