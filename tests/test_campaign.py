"""Campaign planner: admission-time locality (``repro.core.campaign``) —
the shared grant/admission scorer, deterministic replayable plans,
per-shard script generation, queue seeding, and the planner's guarantees
under arbitrary cohorts/summaries (hypothesis)."""
import dataclasses
import json
import re
import shutil
from pathlib import Path

import pytest

from repro.core import builtin_pipelines, query_available_work, synthesize_dataset
from repro.core.campaign import (CAMPAIGN_VERSION, CampaignPlan, Cohort,
                                 admission_throttle, cohort_from_query,
                                 plan_campaign, summaries_from_queue)
from repro.core.query import Exclusion, load_units
from repro.core.workflow import generate_jobs
from repro.dist import ClusterRunner, DigestSummary, WorkQueue
from repro.dist.cache import (SUMMARY_WIRE_VERSION, load_summary_file,
                              save_summary_file, summaries_from_cache_dirs)


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path / "ds", "campds", n_subjects=8,
                              sessions_per_subject=2, shape=(10, 10, 10))


def _cohort(dataset):
    return cohort_from_query(dataset, builtin_pipelines()["bias_correct"])


def _summary_for(units):
    s = DigestSummary()
    for u in units:
        for d in u.input_digests.values():
            s.add(d)
    return s


# ---------------------------------------------------------------------------
# one scorer, two schedulers (the no-drift acceptance criterion)
# ---------------------------------------------------------------------------

def test_grant_and_admission_share_one_scorer_object():
    """Both call sites must resolve to the *same function object* in
    ``repro.dist.placement`` — duplicated scoring logic is how admission
    and grant ranking drift apart."""
    from repro.core import campaign as admission_site
    from repro.dist import placement
    from repro.dist import queue as grant_site
    assert grant_site.unit_local_bytes is placement.unit_local_bytes
    assert admission_site.unit_local_bytes is placement.unit_local_bytes
    assert grant_site.best_node is placement.best_node
    assert admission_site.best_node is placement.best_node


def test_grant_score_equals_admission_score(dataset):
    """The number a shard records is the number the queue leases with."""
    cohort = _cohort(dataset)
    units = cohort.units
    summ = {"a": _summary_for(units[:3])}
    plan = plan_campaign([cohort], summ)
    warm = next(s for s in plan.shards if s.node_id == "a")
    q = WorkQueue(units, ["a"], partition="backlog")
    q.put_summary("a", {"v": SUMMARY_WIRE_VERSION,
                        "full": summ["a"].to_wire()})
    granted_local = 0
    for _ in range(len(warm.unit_ids)):
        unit, lease = q.next_unit("a")
        assert unit.job_id in warm.unit_ids
        granted_local += lease.local_bytes
    assert granted_local == warm.est_local_bytes


# ---------------------------------------------------------------------------
# planner semantics
# ---------------------------------------------------------------------------

def test_plan_routes_units_to_warm_nodes_and_colds_the_rest(dataset):
    cohort = _cohort(dataset)
    units = cohort.units
    summaries = {"node-a": _summary_for(units[:5]),
                 "node-b": _summary_for(units[5:9])}
    plan = plan_campaign([cohort], summaries)
    assert plan.nodes == ["node-a", "node-b"]
    by_node = {s.node_id: s for s in plan.shards}
    assert set(by_node["node-a"].unit_ids) == {u.job_id for u in units[:5]}
    assert set(by_node["node-b"].unit_ids) == {u.job_id for u in units[5:9]}
    assert set(by_node[None].unit_ids) == {u.job_id for u in units[9:]}
    assert by_node[None].est_local_bytes == 0
    assert by_node["node-a"].est_local_bytes == \
        sum(u.total_input_bytes for u in units[:5])
    assert 0.0 < plan.est_local_fraction() < 1.0
    # every admitted unit exactly once
    assigned = plan.assigned_unit_ids()
    assert sorted(assigned) == sorted(u.job_id for u in units)


def test_plan_without_summaries_degrades_to_one_blind_shard(dataset):
    cohort = _cohort(dataset)
    plan = plan_campaign([cohort])
    assert plan.nodes == []
    assert len(plan.shards) == 1 and plan.shards[0].node_id is None
    assert plan.shards[0].unit_ids == [u.job_id for u in cohort.units]
    assert plan.est_local_fraction() == 0.0


def test_plan_admits_each_unit_once_across_overlapping_cohorts(dataset):
    cohort = _cohort(dataset)
    twin = dataclasses.replace(cohort)           # same dataset re-submitted
    plan = plan_campaign([cohort, twin], {"n0": _summary_for(cohort.units)})
    assigned = plan.assigned_unit_ids()
    assert sorted(assigned) == sorted(u.job_id for u in cohort.units)
    assert plan.cohorts[0]["admitted"] == len(cohort.units)
    assert plan.cohorts[1]["admitted"] == 0      # all duplicates


def test_plan_never_assigns_an_excluded_unit(dataset):
    cohort = _cohort(dataset)
    # poison the cohort: first two admitted sessions also appear excluded
    # (a planner must re-check, not trust the caller's disjointness)
    poisoned = dataclasses.replace(
        cohort, excluded=cohort.excluded + [
            Exclusion(u.subject, u.session, "late exclusion")
            for u in cohort.units[:2]])
    plan = plan_campaign([poisoned], {"n0": _summary_for(cohort.units)})
    assigned = set(plan.assigned_unit_ids())
    for u in cohort.units[:2]:
        assert u.job_id not in assigned
    assert sorted(assigned) == sorted(u.job_id for u in cohort.units[2:])
    # and the exclusions are recorded, with reasons, in the artifact
    reasons = {(e["subject"], e["session"]): e["reason"]
               for e in plan.excluded}
    assert reasons[(cohort.units[0].subject,
                    cohort.units[0].session)] == "late exclusion"


def test_max_shard_units_splits_arrays_deterministically(dataset):
    cohort = _cohort(dataset)
    plan = plan_campaign([cohort], {"n0": _summary_for(cohort.units)},
                         max_shard_units=3)
    warm = [s for s in plan.shards if s.node_id == "n0"]
    assert len(warm) == (len(cohort.units) + 2) // 3
    assert all(len(s.unit_ids) <= 3 for s in warm)
    assert [s.shard_id for s in plan.shards] == \
        [f"shard-{i:03d}" for i in range(len(plan.shards))]
    joined = [j for s in warm for j in s.unit_ids]
    assert sorted(joined) == sorted(u.job_id for u in cohort.units)


def test_admission_throttle_caps_on_free_disk():
    # plenty of disk: requested throttle stands
    assert admission_throttle({"disk_free_gb": 1024.0}, 1 << 20, 100) == 100
    # 1 GiB free, 64 MiB units, 4x footprint -> 4 concurrent tasks
    assert admission_throttle({"disk_free_gb": 1.0}, 64 << 20, 100) == 4
    # never below one, never crashes on degenerate inputs
    assert admission_throttle({"disk_free_gb": 0.001}, 1 << 30, 100) == 1
    assert admission_throttle({}, 1 << 30, 100) == 100
    assert admission_throttle(None, 0, 7) == 7


def test_campaign_version_mismatch_rejected(tmp_path, dataset):
    from repro.core.campaign import as_plan
    plan = plan_campaign([_cohort(dataset)])
    p = plan.save(tmp_path / "campaign.json")
    d = json.loads(p.read_text())
    d["version"] = CAMPAIGN_VERSION + 1
    p.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="campaign version"):
        CampaignPlan.load(p)
    # the pre-parsed-dict intake must reject the same artifact identically,
    # not quietly misread a future plan
    with pytest.raises(ValueError, match="campaign version"):
        as_plan(d)
    with pytest.raises(TypeError):
        as_plan(42)


# ---------------------------------------------------------------------------
# determinism / replayability
# ---------------------------------------------------------------------------

def test_plan_is_deterministic_and_byte_replayable(dataset, tmp_path):
    cohort = _cohort(dataset)
    summ = {"n0": _summary_for(cohort.units[:4]),
            "n1": _summary_for(cohort.units[4:])}
    status = {"disk_free_gb": 10.0, "load_1m": 0.5}
    a = plan_campaign([cohort], summ, status=status)
    b = plan_campaign([cohort], summ, status=status)
    assert a.to_json() == b.to_json()
    p = a.save(tmp_path / "campaign.json")
    assert CampaignPlan.load(p).to_json() == a.to_json()
    assert CampaignPlan.load(p).save(tmp_path / "again.json").read_bytes() \
        == p.read_bytes()
    # a different world-state is visible in the stamp
    c = plan_campaign([cohort], summ, status={"disk_free_gb": 11.0})
    assert c.inputs_hash != a.inputs_hash


def test_summary_file_roundtrip_plans_identically(dataset, tmp_path):
    cohort = _cohort(dataset)
    summ = {"n0": _summary_for(cohort.units)}
    direct = plan_campaign([cohort], summ)
    via_file = plan_campaign(
        [cohort], load_summary_file(save_summary_file(tmp_path / "s.json",
                                                      summ)))
    assert via_file.to_json() == direct.to_json()
    # the planner also takes the path itself
    via_path = plan_campaign([cohort], tmp_path / "s.json")
    assert via_path.to_json() == direct.to_json()


# ---------------------------------------------------------------------------
# generate_jobs campaign mode (per-shard SLURM arrays)
# ---------------------------------------------------------------------------

def test_generate_jobs_campaign_mode_writes_shards_and_plan(dataset, tmp_path):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    summ = {"host-a": _summary_for(units[:6]), "host-b": _summary_for(units[6:])}
    sfile = save_summary_file(tmp_path / "summaries.json", summ)
    jp = generate_jobs(dataset, pipe, tmp_path / "jobs", summaries=sfile)
    assert jp.slurm_script is None               # sharded, not monolithic
    assert jp.campaign_file and Path(jp.campaign_file).exists()
    plan = CampaignPlan.load(jp.campaign_file)
    assert len(jp.shard_scripts) == len(plan.shards) == 2
    covered = []
    # the campaign-level throttle budget is split across the emitted
    # arrays, so submitting every shard at once cannot multiply it back up
    per_shard = plan.throttle // len(jp.shard_scripts)
    for sf, script in zip(jp.shard_units_files, jp.shard_scripts):
        shard_units = load_units(sf)
        covered.extend(u.job_id for u in shard_units)
        text = Path(script).read_text()
        assert f"--array=0-{len(shard_units) - 1}%{per_shard}" in text
        # every path the script references exists at submit time
        for raw in re.findall(r"(/[^\s\\$]+)", text):
            target = Path(raw.split("%")[0].rstrip("/"))
            assert target.exists(), f"{script} references missing {target}"
    assert sorted(covered) == sorted(u.job_id for u in units)
    # warm shards pinned to their host, cold shard untargeted
    texts = [Path(s).read_text() for s in jp.shard_scripts]
    assert any("--nodelist=host-a" in t for t in texts)
    assert any("--nodelist=host-b" in t for t in texts)


def test_generate_jobs_accepts_prebuilt_plan(dataset, tmp_path):
    pipe = builtin_pipelines()["bias_correct"]
    cohort = cohort_from_query(dataset, pipe)
    plan = plan_campaign([cohort], {"h": _summary_for(cohort.units)})
    jp = generate_jobs(dataset, pipe, tmp_path / "jobs", campaign=plan)
    assert Path(jp.campaign_file).read_text() == plan.to_json()
    assert len(jp.shard_scripts) == len(plan.shards)
    # the replay path: resubmitting an audited campaign.json, no re-plan
    saved = plan.save(tmp_path / "audited.json")
    jp2 = generate_jobs(dataset, pipe, tmp_path / "jobs2", campaign=saved)
    assert Path(jp2.campaign_file).read_text() == plan.to_json()
    assert [Path(s).name for s in jp2.shard_scripts] == \
        [Path(s).name for s in jp.shard_scripts]


def test_generate_jobs_schedules_units_a_stale_plan_missed(dataset, tmp_path):
    """Fail-soft parity with queue seeding: sessions admitted after planning
    (or dropped by a stale plan) must still get a script — in an untargeted
    catch-all shard — never be silently unscheduled."""
    pipe = builtin_pipelines()["bias_correct"]
    cohort = cohort_from_query(dataset, pipe)
    stale = plan_campaign(                       # plan covers only 4 units
        [dataclasses.replace(cohort, units=cohort.units[:4])],
        {"h": _summary_for(cohort.units[:4])})
    jp = generate_jobs(dataset, pipe, tmp_path / "jobs", campaign=stale)
    covered = [u.job_id for sf in jp.shard_units_files
               for u in load_units(sf)]
    assert sorted(covered) == sorted(u.job_id for u in cohort.units)
    assert len(covered) == len(set(covered))     # still exactly once
    catchall = [s for s in jp.shard_scripts if "shard-uncovered" in s]
    assert len(catchall) == 1
    text = Path(catchall[0]).read_text()
    assert "--nodelist" not in text              # untargeted: cold by nature
    assert f"--array=0-{len(cohort.units) - 4 - 1}%" in text


# ---------------------------------------------------------------------------
# queue seeding: the cluster starts on the planned partitions
# ---------------------------------------------------------------------------

def test_workqueue_seeds_partitions_from_plan(dataset):
    cohort = _cohort(dataset)
    units = cohort.units
    plan = plan_campaign([cohort], {"node-0": _summary_for(units[:5]),
                                    "node-1": _summary_for(units[5:])})
    q = WorkQueue(units, ["node-0", "node-1"], plan=plan)
    depths = q.queue_depths()
    assert depths == {"node-0": 5, "node-1": 11}
    # grants drain the node's own seeded shard — no backlog fill, no steal
    got = {q.next_unit("node-0")[0].job_id for _ in range(5)}
    assert got == {u.job_id for u in units[:5]}
    assert sum(q.steals.values()) == 0


def test_workqueue_seeds_from_parsed_campaign_json(dataset, tmp_path):
    """The loaded-from-disk JSON shape (plain dicts) and a campaign.json
    path both seed identically — the offline HPC path never holds live
    Shard objects."""
    cohort = _cohort(dataset)
    units = cohort.units
    plan = plan_campaign([cohort], {"node-0": _summary_for(units)})
    path = plan.save(tmp_path / "c.json")
    raw = json.loads(path.read_text())
    q = WorkQueue(units, ["node-0", "node-1"], plan=raw)
    assert q.queue_depths() == {"node-0": len(units), "node-1": 0}
    q2 = WorkQueue(units, ["node-0", "node-1"], plan=path)
    assert q2.queue_depths() == {"node-0": len(units), "node-1": 0}
    # a path to a future-version plan fails loud, not silently-backlogged
    raw["version"] += 1
    path.write_text(json.dumps(raw))
    with pytest.raises(ValueError, match="campaign version"):
        WorkQueue(units, ["node-0"], plan=path)


def test_workqueue_plan_fail_soft(dataset):
    """Stale plans degrade, never break: unknown unit ids are ignored,
    shards for absent nodes and unplanned units go to the backlog."""
    cohort = _cohort(dataset)
    units = cohort.units
    plan = plan_campaign([cohort], {"gone-node": _summary_for(units[:3])})
    ghost = dataclasses.replace(
        plan.shards[0], unit_ids=plan.shards[0].unit_ids + ["no_such_job"])
    plan = dataclasses.replace(plan, shards=[ghost] + plan.shards[1:])
    q = WorkQueue(units[:10], ["node-0"], plan=plan)
    # 3 planned-for-gone-node + 7 cold/unplanned -> all 10 via backlog
    assert q.queue_depths() == {"node-0": 0}
    drained = set()
    while True:
        nxt = q.next_unit("node-0")
        if nxt is None:
            break
        drained.add(nxt[0].job_id)
    assert drained == {u.job_id for u in units[:10]}


def test_cluster_runner_plan_starts_warm_end_to_end(dataset, tmp_path):
    """Warm per-node caches -> offline summary harvest -> plan -> a planned
    run (grant-time scoring OFF) still lands units on their warm hosts."""
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    kw = dict(nodes=3, poll_s=0.02, cache_dir=tmp_path / "hosts",
              cache_per_node=True, straggler_factor=100.0)
    warm = ClusterRunner(pipe, dataset.root, locality=False, **kw)
    assert sum(r.status == "ok" for r in warm.run(units)) == len(units)
    shutil.rmtree(Path(dataset.root) / "derivatives")

    summaries = summaries_from_cache_dirs(tmp_path / "hosts")
    assert sorted(summaries) == ["node-0", "node-1", "node-2"]
    cohort = cohort_from_query(dataset, pipe)
    plan = plan_campaign([cohort], summaries)
    assert all(s.node_id for s in plan.shards)   # everything found a warm host

    runner = ClusterRunner(pipe, dataset.root, locality=False, plan=plan, **kw)
    results = runner.run(cohort.units)
    assert sum(r.status == "ok" for r in results) == len(cohort.units)
    totals = {}
    for st in runner.stats.cache_by_node.values():
        for k, v in st.items():
            if isinstance(v, (int, float)):     # skip peer_bytes_by_addr
                totals[k] = totals.get(k, 0) + v
    # the seeded partitions put most units back on their warm host even
    # with all grant-time scoring disabled (stealing may move a few)
    assert totals["hits"] > totals["misses"]


# ---------------------------------------------------------------------------
# pulling summaries from a live coordinator (in-process and over rpc)
# ---------------------------------------------------------------------------

def test_summaries_from_live_queue_and_over_rpc(dataset):
    from repro.dist import QueueClient, QueueServer
    cohort = _cohort(dataset)
    units = cohort.units
    q = WorkQueue(units, ["a", "b"])
    q.put_summary("a", {"v": SUMMARY_WIRE_VERSION,
                        "full": _summary_for(units[:4]).to_wire()})
    direct = summaries_from_queue(q)
    assert set(direct) == {"a"}
    with QueueServer(q) as srv:
        over_client = summaries_from_queue(QueueClient(srv.address))
        over_addr = summaries_from_queue(srv.addr_str)
    assert over_client == over_addr == direct
    plan = plan_campaign([cohort], direct)
    by_node = {s.node_id: s for s in plan.shards}
    assert set(by_node["a"].unit_ids) == {u.job_id for u in units[:4]}
    # a dead node's summary is not offered to the planner
    q.mark_dead("a")
    assert summaries_from_queue(q) == {}


# ---------------------------------------------------------------------------
# the planner invariant, deterministic grid (body in campaign_invariant.py;
# the hypothesis property driving the same body with random cohorts and
# summary states lives in test_property.py, the repo's hypothesis home, so
# environments without hypothesis skip only it, not this sweep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_nodes,warm_frac,max_shard",
                         [(0, 0.0, None), (1, 1.0, None), (2, 0.5, None),
                          (3, 0.5, 2), (2, 1.0, 1)])
def test_campaign_invariant_grid(dataset, n_nodes, warm_frac, max_shard):
    from campaign_invariant import check_campaign_invariant
    cohort = _cohort(dataset)
    twin = dataclasses.replace(                  # overlap + a late exclusion
        cohort, excluded=cohort.excluded +
        [Exclusion(cohort.units[0].subject, cohort.units[0].session, "late")])
    warm = cohort.units[:int(len(cohort.units) * warm_frac)]
    per_node = (len(warm) // n_nodes + 1) if n_nodes else 0
    summaries = {f"n{i}": _summary_for(warm[i * per_node:(i + 1) * per_node])
                 for i in range(n_nodes)}
    check_campaign_invariant([cohort, twin], summaries,
                             status={"disk_free_gb": 8.0},
                             max_shard_units=max_shard)
