"""Networked WorkQueue transport + per-host input cache: protocol unit tests
(renew vs reap, register/backlog, JSON-lines framing), cache behaviour under
size pressure, and the ISSUE acceptance run — a 64-unit chaos schedule over
the socket transport with a worker in a genuinely separate process."""
import json
import os
import socket
import subprocess
import sys
import threading
import time
from collections import Counter
from pathlib import Path

import numpy as np
import pytest

from conftest import wait_until

from repro.core import (Provenance, builtin_pipelines, query_available_work,
                        synthesize_dataset)
from repro.core.workflow import load_unit_inputs
from repro.dist import (ClusterRunner, InputCache, QueueClient, QueueServer,
                        WorkQueue, cache_from_env)

REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path / "ds", "rpcds", n_subjects=4,
                              sessions_per_subject=2, shape=(10, 10, 10))


def _work(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    return pipe, units


# ---------------------------------------------------------------------------
# input cache
# ---------------------------------------------------------------------------

def test_cache_hit_returns_identical_digest_and_bytes(dataset, tmp_path):
    pipe, units = _work(dataset)
    cache = InputCache(tmp_path / "cache", max_bytes=1 << 30)
    i1, sums1, hit1, hb1, *_ = load_unit_inputs(units[0], dataset.root,
                                                cache=cache)
    i2, sums2, hit2, hb2, *_ = load_unit_inputs(units[0], dataset.root,
                                                cache=cache)
    assert (hit1, hit2) == (False, True)
    assert sums1 == sums2                       # provenance-identical digests
    for k in i1:
        assert np.array_equal(i1[k], i2[k])
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1


def test_cache_eviction_under_size_pressure(dataset, tmp_path):
    pipe, units = _work(dataset)
    one_input = (Path(dataset.root) / units[0].inputs["T1w"]).stat().st_size
    # room for roughly two blobs: filling with 8 units must evict
    cache = InputCache(tmp_path / "cache", max_bytes=int(one_input * 2.5))
    for u in units:
        load_unit_inputs(u, dataset.root, cache=cache)
    st = cache.stats()
    assert st["evictions"] >= len(units) - 3
    assert st["bytes"] <= int(one_input * 2.5)
    assert cache.blob_count() <= 2
    # evicted entries re-fetch (miss), survivors still hit
    _, _, hit_last, *_ = load_unit_inputs(units[-1], dataset.root, cache=cache)
    _, _, hit_first, *_ = load_unit_inputs(units[0], dataset.root, cache=cache)
    assert hit_last is True                     # most recent blob survived
    assert hit_first is False                   # LRU victim re-fetched


def test_cache_lru_order_touch_on_hit(tmp_path, dataset):
    pipe, units = _work(dataset)
    one = (Path(dataset.root) / units[0].inputs["T1w"]).stat().st_size
    cache = InputCache(tmp_path / "c", max_bytes=int(one * 2.5))
    load_unit_inputs(units[0], dataset.root, cache=cache)
    load_unit_inputs(units[1], dataset.root, cache=cache)
    load_unit_inputs(units[0], dataset.root, cache=cache)   # touch 0
    load_unit_inputs(units[2], dataset.root, cache=cache)   # evicts 1, not 0
    assert load_unit_inputs(units[0], dataset.root, cache=cache)[2] is True
    assert load_unit_inputs(units[1], dataset.root, cache=cache)[2] is False


def test_cache_oversize_input_passes_through_without_wiping(dataset, tmp_path):
    """An input bigger than the whole budget is served but never inserted —
    inserting it would evict every warm blob for a blob that is itself
    immediately evicted."""
    pipe, units = _work(dataset)
    one = (Path(dataset.root) / units[0].inputs["T1w"]).stat().st_size
    cache = InputCache(tmp_path / "c", max_bytes=one + 1)   # fits exactly one
    load_unit_inputs(units[0], dataset.root, cache=cache)   # warm blob
    big = tmp_path / "big.npy"
    np.save(big, np.zeros(one, dtype=np.float64))           # > max_bytes
    arr, digest, origin, nbytes, _ = cache.fetch_array(big)
    assert origin == "storage" and arr.nbytes > cache.max_bytes
    st = cache.stats()
    assert st["evictions"] == 0 and st["blobs"] == 1        # warm blob intact
    assert load_unit_inputs(units[0], dataset.root, cache=cache)[2] is True


def test_cache_corrupt_blob_degrades_to_miss(dataset, tmp_path):
    pipe, units = _work(dataset)
    cache = InputCache(tmp_path / "cache")
    _, sums, *_ = load_unit_inputs(units[0], dataset.root, cache=cache)
    digest = next(iter(sums.values()))
    (cache.blob_dir / digest).write_bytes(b"garbage")
    arr, sums2, hit, *_ = load_unit_inputs(units[0], dataset.root, cache=cache)
    assert hit is False                          # verified hit failed -> miss
    assert sums2 == sums                         # refetched, digest intact


def test_cache_persists_across_instances(dataset, tmp_path):
    pipe, units = _work(dataset)
    c1 = InputCache(tmp_path / "cache")
    load_unit_inputs(units[0], dataset.root, cache=c1)
    c2 = InputCache(tmp_path / "cache")          # restarted worker
    _, _, hit, *_ = load_unit_inputs(units[0], dataset.root, cache=c2)
    assert hit is True


def test_cache_source_change_is_not_served_stale(dataset, tmp_path):
    pipe, units = _work(dataset)
    cache = InputCache(tmp_path / "cache")
    src = Path(dataset.root) / units[0].inputs["T1w"]
    _, sums1, *_ = load_unit_inputs(units[0], dataset.root, cache=cache)
    arr = np.load(src) + 1.0
    np.save(src, arr)                            # source mutated in place
    os.utime(src, ns=(1, 1))                     # force a new mtime key too
    _, sums2, hit, *_ = load_unit_inputs(units[0], dataset.root, cache=cache)
    assert hit is False
    assert sums1 != sums2                        # new content, new digest


def test_cache_from_env(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_DIR", raising=False)
    assert cache_from_env() is None
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "envcache"))
    monkeypatch.setenv("REPRO_CACHE_MAX_MB", "2")
    cache = cache_from_env()
    assert cache is not None and cache.max_bytes == 2 * 2**20


# ---------------------------------------------------------------------------
# renew / register / backlog (queue-level, no sockets)
# ---------------------------------------------------------------------------

def test_renew_refreshes_valid_lease_only(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b"])
    unit, lease = q.next_unit("a")
    assert q.renew(lease.unit_idx, "a", lease.epoch) is True
    assert q.renew(lease.unit_idx, "b", lease.epoch) is False   # wrong holder
    assert q.renew(lease.unit_idx, "a", lease.epoch + 7) is False
    q.complete(lease.unit_idx, "a", "ok")
    assert q.renew(lease.unit_idx, "a", lease.epoch) is False   # retired
    # the retired-unit rejection is routine (renew raced its own completion)
    # and stays out of the WAN-health counter; the two stale ones count
    assert q.renew_rejections == 2


def test_renew_racing_reap_is_rejected_after_epoch_bump(dataset):
    """The WAN failure ISSUE names: a node's lease is reaped and re-granted
    (epoch bump) while its renew is in flight — the stale renewal must be
    rejected and the exactly-one-retirement invariant preserved."""
    t = {"now": 0.0}
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b"], lease_ttl_s=1.0, now=lambda: t["now"])
    unit, lease = q.next_unit("a")
    t["now"] = 1.5
    q.heartbeat("b")
    assert lease.unit_idx in q.reap()            # a reaped, unit requeued
    # the re-grant bumps the epoch; a's in-flight renew names the old one
    got = None
    while got is None or got[1].unit_idx != lease.unit_idx:
        got = q.next_unit("b")
    assert got[1].epoch == lease.epoch + 1
    assert q.renew(lease.unit_idx, "a", lease.epoch) is False
    # and the zombie's completion is ignored: b's grant is authoritative
    q.complete(lease.unit_idx, "a", "failed")
    assert q.pending() == len(units)
    q.complete(lease.unit_idx, "b", "ok")
    assert q.done_status()[lease.unit_idx] == "ok"


def test_renew_twin_lease(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    twin = q.speculate(lease.unit_idx, "b")
    assert q.renew(twin.unit_idx, "b", twin.epoch) is True
    assert q.renew(twin.unit_idx, "b", twin.epoch - 1) is False


def test_register_joins_and_dead_id_stays_dead(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    assert q.register("late") is True
    assert "late" in q.alive_nodes()
    got = q.next_unit("late")                    # steals from a's deque
    assert got is not None
    q.mark_dead("late")
    assert q.register("late") is False


def test_zero_node_queue_holds_backlog_until_register(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units)                         # no nodes yet
    assert q.pending() == len(units)
    assert q.register("w0")
    leased = [q.next_unit("w0") for _ in range(len(units))]
    assert all(l is not None for l in leased)
    assert q.next_unit("w0") is None             # drained
    # second registrant steals from the first's deque next time around
    assert q.register("w1")


def test_unknown_node_fails_soft(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    assert q.next_unit("ghost") is None
    q.heartbeat("ghost")                         # dropped, not auto-joined
    assert "ghost" not in q.alive_nodes()
    assert q.reap() == []


# ---------------------------------------------------------------------------
# socket transport
# ---------------------------------------------------------------------------

def test_rpc_roundtrip_matches_inprocess_surface(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        unit, lease = c.next_unit("a")
        assert unit.job_id == q.units[lease.unit_idx].job_id
        assert lease.epoch == 1 and not lease.speculative
        c.mark_started(lease.unit_idx)
        assert c.renew(lease.unit_idx, "a", lease.epoch) is True
        twin = c.speculate(lease.unit_idx, "b")
        assert twin is not None and twin.speculative
        c.complete(lease.unit_idx, "a", "ok",
                   meta={"seconds": 0.25, "attempts": 1, "error": None})
        snap = c.results_snapshot()
        assert snap["primaries"][lease.unit_idx]["seconds"] == 0.25
        assert c.done_status() == q.done_status()
        assert c.pending() == len(units) - 1
        assert c.queue_depths() == q.queue_depths()
        assert c.active_leases() == q.active_leases()
        assert c.alive_nodes() == q.alive_nodes()
        assert isinstance(c.steals, dict) and isinstance(c.requeues, list)
        c.close()


def test_rpc_unknown_method_and_bad_params_report_not_crash(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        with pytest.raises(RuntimeError, match="unknown method"):
            c._call("shutdown")
        with pytest.raises(RuntimeError, match="TypeError"):
            c._call("next_unit", nonsense=1)
        # the connection survives an errored request
        assert c.next_unit("a") is not None
        c.close()


def test_rpc_dropped_connection_raises_connection_error(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    srv = QueueServer(q).start()
    c = QueueClient(srv.address)
    assert c.finished() is False
    srv.stop()
    with pytest.raises(ConnectionError):
        for _ in range(10):                      # buffered writes may need >1
            c.heartbeat("a")
            time.sleep(0.05)
    c.close()


def test_cluster_rpc_transport_completes_and_caches(dataset, tmp_path):
    """ClusterRunner + Node run unchanged over the socket: same results,
    provenance carries node ids, and a warm re-run commits cache hits."""
    pipe, units = _work(dataset)
    runner = ClusterRunner(pipe, dataset.root, nodes=2, transport="rpc",
                           poll_s=0.03, cache_dir=tmp_path / "host-cache")
    results = runner.run(units)
    assert sum(r.status == "ok" for r in results) == len(units)
    assert runner.stats.cache is not None
    assert runner.stats.cache["misses"] >= 1
    # wipe derivatives, keep the cache: the re-run is all hits
    import shutil
    shutil.rmtree(Path(dataset.root) / "derivatives")
    units2, _ = query_available_work(dataset, pipe)
    runner2 = ClusterRunner(pipe, dataset.root, nodes=2, transport="rpc",
                            poll_s=0.03, cache_dir=tmp_path / "host-cache")
    results2 = runner2.run(units2)
    assert sum(r.status == "ok" for r in results2) == len(units2)
    hit_commits = [Provenance.load(Path(u.out_dir)).cache_hit for u in units2]
    assert any(hit_commits)
    assert runner2.stats.cache["hits"] >= 1


# ---------------------------------------------------------------------------
# peer-fabric version skew, both directions
# ---------------------------------------------------------------------------

def test_new_client_downgrades_blob_addr_against_pre_fabric_server(dataset):
    """New worker vs old coordinator: a server whose queue predates
    ``blob_addr`` rejects it with a TypeError; the client sheds that one
    param and keeps its summary — fabric-invisible, still locality-aware."""
    pipe, units = _work(dataset)

    class _PreFabricQueue(WorkQueue):
        def register(self, node_id, summary=None):
            return super().register(node_id, summary=summary)

        def heartbeat(self, node_id, summary_delta=None):
            return super().heartbeat(node_id, summary_delta=summary_delta)

    q = _PreFabricQueue(units, [])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        wire = {"v": 1, "full": {"v": 1, "m": 8, "k": 2, "n": 1,
                                 "nz": [[0, 1]]}}
        assert c.register("w", summary=wire, blob_addr="wh:9") is True
        assert c._fabric_ok is False
        assert c._summaries_ok is True               # only one rung shed
        assert "w" in q.stats_snapshot()["summary_nodes"]
        c.heartbeat("w", blob_addr="wh:9")           # silently bare now
        assert c.next_unit("w") is not None          # scheduling unaffected
        c.close()


def test_new_client_downgrades_stepwise_against_ancient_server(dataset):
    """A coordinator that predates summaries AND the fabric: the client
    sheds blob_addr first, then the summary, and still registers."""
    pipe, units = _work(dataset)

    class _AncientQueue(WorkQueue):
        def register(self, node_id):
            return super().register(node_id)

    q = _AncientQueue(units, [])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        wire = {"v": 1, "full": {"v": 1, "m": 8, "k": 2, "n": 1,
                                 "nz": [[0, 1]]}}
        assert c.register("w", summary=wire, blob_addr="wh:9") is True
        assert c._fabric_ok is False and c._summaries_ok is False
        assert c.next_unit("w") is not None
        c.close()


def test_locate_blobs_returns_empty_against_pre_fabric_server(
        dataset, monkeypatch):
    """New fetcher vs old coordinator: ``locate_blobs`` degrades to ``{}``
    on the first "unknown method" (the pre-fabric behaviour: go read shared
    storage) and never pays a doomed RPC again."""
    from repro.dist import rpc as rpc_mod
    pipe, units = _work(dataset)
    monkeypatch.setattr(rpc_mod, "_METHODS",
                        rpc_mod._METHODS - {"locate_blobs"})
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        assert c.locate_blobs(["d" * 64], node_id="a") == {}
        assert c._fabric_ok is False
        assert c.locate_blobs(["d" * 64]) == {}      # no second wire call
        # the downgrade also stops blob_addr advertisements cold
        assert c.register("w", blob_addr="wh:1") is True
        assert q.stats_snapshot()["fabric_nodes"] == []
        c.close()


def test_old_worker_is_fabric_invisible_on_new_coordinator(dataset):
    """Old worker vs new coordinator: a client that never sends blob_addr
    (the pre-fabric wire, byte for byte) is simply never routed to —
    everything else it does is untouched."""
    pipe, units = _work(dataset)
    q = WorkQueue(units, [])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        assert c._call("register", node_id="oldw") is True   # bare old wire
        c._call("heartbeat", node_id="oldw")
        assert q.stats_snapshot()["fabric_nodes"] == []
        assert q.locate_blobs(["d" * 64]) == {}
        assert c.next_unit("oldw") is not None
        c.close()


# ---------------------------------------------------------------------------
# invariant under transport / cache / renewal harassment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transport,cache,harass,locality,peers", [
    ("rpc", False, False, False, False),
    ("rpc", True, False, False, False),
    ("local", True, True, False, False),
    ("local", False, False, True, False),   # locality harassment mode
    ("rpc", False, True, True, False),      # both harassers over the socket
    ("local", False, False, False, True),   # peer-fabric harassment mode
    ("rpc", False, True, False, True),      # hostile peers over the socket
])
def test_cluster_invariant_over_transport(transport, cache, harass, locality,
                                          peers):
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(2, 2, 3, True, 1, transport=transport,
                            cache=cache, harass_renew=harass,
                            harass_locality=locality, harass_peers=peers)


# ---------------------------------------------------------------------------
# acceptance: 64-unit chaos over the socket with a separate worker process
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_acceptance_64_units_chaos_over_socket_with_worker_process(tmp_path):
    """ISSUE acceptance: ClusterRunner completes a 64-unit chaos run over the
    socket transport with >=1 node in a separate OS process — one local node
    dies mid-run, one unit straggles into a twin — and every unit ends with
    exactly one ok provenance."""
    ds = synthesize_dataset(tmp_path / "ds", "acc-rpc", n_subjects=32,
                            sessions_per_subject=2, shape=(8, 8, 8))
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(ds, pipe)
    assert len(units) == 64
    slow_id = units[5].job_id
    slept = {"n": 0}
    lock = threading.Lock()

    def chaos(unit, attempt):
        # hold local nodes back (bounded per unit) until the external
        # process has registered — a cold python booting jax takes seconds
        # on a loaded box, and the batched grant path drains 64 tiny units
        # faster than that — so it provably commits real work; unit 5
        # straggles once
        deadline = time.time() + 1.0
        while time.time() < deadline:
            srv = runner.server
            if srv is not None and "ext-0" in srv.queue.alive_nodes():
                break
            time.sleep(0.05)
        time.sleep(0.01)
        if unit.job_id == slow_id:
            with lock:
                first = slept["n"] == 0
                slept["n"] += 1
            if first:
                # straggle until anyone (twin, or the external worker)
                # commits the unit — bounded, not a fixed window
                wait_until(lambda: 5 in runner.server.queue.done_status(),
                           timeout=30, desc="unit 5 to be committed past "
                                            "the straggling primary")

    runner = ClusterRunner(pipe, ds.root, nodes=2, transport="rpc",
                           fault_hook=chaos, die_after={"node-1": 3},
                           lease_ttl_s=0.6, hb_interval_s=0.1,
                           straggler_factor=2.5, straggler_min_s=0.3,
                           poll_s=0.03, cache_dir=tmp_path / "host-cache")
    got = {}
    t = threading.Thread(target=lambda: got.update(r=runner.run(units)))
    t.start()
    deadline = time.time() + 30
    while runner.server is None and t.is_alive() and time.time() < deadline:
        time.sleep(0.01)
    assert runner.server is not None
    env = dict(os.environ,
               PYTHONPATH=str(REPO_ROOT / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""),
               REPRO_CACHE_DIR=str(tmp_path / "ext-cache"))
    worker = subprocess.Popen(
        [sys.executable, "-m", "repro.dist.rpc", "work",
         "--addr", runner.server.addr_str, "--pipeline", "bias_correct",
         "--data-root", str(ds.root), "--node-id", "ext-0"],
        env=env, cwd=REPO_ROOT, text=True,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    t.join(timeout=300)
    wout, _ = worker.communicate(timeout=60)
    assert not t.is_alive(), "coordinator did not finish"
    results = got["r"]
    by_status = Counter(r.status for r in results)
    assert by_status["ok"] == 64
    ok_ids = [r.unit.job_id for r in results if r.status == "ok"]
    assert len(ok_ids) == len(set(ok_ids))
    # exactly one committed ok provenance per unit
    provs = [Provenance.load(Path(u.out_dir)) for u in units]
    assert all(p is not None and p.status == "ok"
               and p.pipeline_digest == pipe.digest() for p in provs)
    # the chaos happened: node death observed, and the external process
    # registered and committed work of its own
    assert "node-1" in runner.stats.dead_nodes
    assert "ext-0" in runner.stats.remote_nodes, wout
    ext_commits = [p for p in provs if p.node_id == "ext-0"]
    assert len(ext_commits) >= 1, (runner.stats.processed, wout)
    assert worker.returncode in (0, 3), wout


# ---------------------------------------------------------------------------
# frame caps + binary framing
# ---------------------------------------------------------------------------

def test_oversize_jsonl_request_rejected_then_connection_closed(dataset):
    """A request line past MAX_FRAME_BYTES used to balloon the server's
    memory via unbounded readline; now it gets one ProtocolError reply and
    the connection closes (the stream cannot be resynchronized)."""
    from repro.dist import rpc as rpc_mod
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        s = socket.create_connection(srv.address, timeout=30)
        with s:
            s.sendall(b"{" + b"x" * (rpc_mod.MAX_FRAME_BYTES + 16))
            f = s.makefile("rb")
            resp = json.loads(f.readline())
            assert resp["ok"] is False and resp["id"] is None
            assert "ProtocolError" in resp["error"]
            assert f.readline() == b""           # server hung up
        # the server survives: a fresh client still gets work
        c = QueueClient(srv.address)
        assert c.next_unit("a") is not None
        c.close()


def test_oversize_binary_length_prefix_rejected(dataset):
    from repro.dist import rpc as rpc_mod
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        s = socket.create_connection(srv.address, timeout=30)
        with s:
            n = rpc_mod.MAX_FRAME_BYTES + 1
            s.sendall(rpc_mod._FRAME_MAGIC + n.to_bytes(4, "big"))
            f = s.makefile("rb")
            assert f.read(1) == rpc_mod._FRAME_MAGIC
            rlen = int.from_bytes(f.read(4), "big")
            resp = json.loads(f.read(rlen))
            assert resp["ok"] is False and "ProtocolError" in resp["error"]
            assert f.read(1) == b""              # server hung up


def test_client_poisons_on_oversize_response(dataset):
    """A server reply past the cap must not be buffered to completion: the
    client raises ConnectionError and every later call fails fast."""
    from repro.dist import rpc as rpc_mod
    srv = socket.create_server(("127.0.0.1", 0))
    addr = srv.getsockname()

    def fake_server():
        conn, _ = srv.accept()
        with conn:
            conn.makefile("rb").readline()       # consume the request
            conn.sendall(b"x" * (rpc_mod.MAX_FRAME_BYTES + 16))
    t = threading.Thread(target=fake_server, daemon=True)
    t.start()
    c = QueueClient(addr)
    with pytest.raises(ConnectionError, match="exceeds frame cap"):
        c.finished()
    with pytest.raises(ConnectionError, match="is down"):
        c.pending()
    t.join(timeout=30)
    srv.close()


def test_client_upgrades_to_binary_after_first_response(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        assert c._binary is False                # negotiated, never assumed
        assert c.finished() is False             # JSON-lines, sees "bin": 1
        assert c._binary is True
        unit, lease = c.next_unit("a")           # binary-framed round trip
        assert unit.job_id == q.units[lease.unit_idx].job_id
        c.complete(lease.unit_idx, "a", "ok")
        assert c.done_status()[lease.unit_idx] == "ok"
        c.close()


def test_binary_false_pins_client_to_jsonlines(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address, binary=False)
        for _ in range(3):
            assert c.finished() is False
        assert c._binary is False                # old-client wire, unchanged
        assert c.next_unit("a") is not None
        c.close()


# ---------------------------------------------------------------------------
# batched rpcs + version skew, both directions
# ---------------------------------------------------------------------------

def test_batched_grant_renew_complete_roundtrip(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        got = c.next_units("a", 5)
        assert len(got) == 5 and c._batched_ok is True
        leases = [[lease.unit_idx, lease.epoch] for _u, lease in got]
        assert c.renew_batch("a", leases) == [True] * 5
        stale = [[leases[0][0], leases[0][1] + 7]] + leases[1:]
        assert c.renew_batch("a", stale) == [False] + [True] * 4
        c.complete_batch([{"idx": i, "node_id": "a", "status": "ok",
                           "meta": {"seconds": 0.5}} for i, _e in leases])
        snap = c.results_snapshot()
        assert all(snap["primaries"][i]["seconds"] == 0.5
                   for i, _e in leases)
        # a short batch means what a None from next_unit means
        rest = c.next_units("a", 10_000)
        assert len(rest) == len(units) - 5
        c.close()


def test_renew_batch_applies_summary_delta_once(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        got = c.next_units("a", 2)
        leases = [[lease.unit_idx, lease.epoch] for _u, lease in got]
        digs = list(units[0].input_digests.values())
        assert c.renew_batch("a", leases, summary_delta={
            "v": 1, "add": digs, "drop": []}) == [True, True]
        assert "a" in c.summaries_snapshot()
        c.close()
    # delta lands exactly once: each digest adds one copy, so one discard
    # per digest empties the summary again
    for d in digs:
        q._summaries["a"].discard(d)
    assert len(q._summaries["a"]) == 0


def test_new_client_sheds_batching_against_pre_batch_server(
        dataset, monkeypatch):
    """New worker vs old coordinator: the batched methods aren't in the
    server's allowlist, so the first call reports "unknown method"; the
    client downgrades to per-op for good and the run proceeds."""
    from repro.dist import rpc as rpc_mod
    monkeypatch.setattr(
        rpc_mod, "_METHODS",
        frozenset(rpc_mod._METHODS
                  - {"next_units", "complete_batch", "renew_batch"}))
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        got = c.next_units("a", 4)
        assert len(got) == 4 and c._batched_ok is False
        leases = [[lease.unit_idx, lease.epoch] for _u, lease in got]
        digs = list(units[0].input_digests.values())
        verdicts = c.renew_batch("a", leases,
                                 summary_delta={"v": 1, "add": digs,
                                                "drop": []})
        assert verdicts == [True] * 4
        c.complete_batch([{"idx": i, "node_id": "a", "status": "ok"}
                          for i, _e in leases])
        assert sum(1 for s in c.done_status().values() if s == "ok") == 4
        c.close()
    for d in digs:                               # the piggyback landed once
        q._summaries["a"].discard(d)
    assert len(q._summaries["a"]) == 0


# ---------------------------------------------------------------------------
# reconnect + incarnation: clients ride out a coordinator restart
# ---------------------------------------------------------------------------

def test_client_reconnects_across_server_restart_on_same_port(dataset):
    pipe, units = _work(dataset)
    q1 = WorkQueue(units, [])
    srv1 = QueueServer(q1).start()
    host, port = srv1.address
    c = QueueClient(srv1.address)
    assert c.register("a") is True
    unit, lease = c.next_unit("a")
    srv1.crash()                         # no goodbye frames

    q2 = WorkQueue(units, [])
    srv2 = QueueServer(q2, host, port).start()
    try:
        # the next call redials transparently; the replayed register means
        # the brand-new queue already knows node "a" when the call lands
        assert c.pending() == len(units)
        assert "a" in q2.alive_nodes()
        u2, l2 = c.next_unit("a")
        c.complete(l2.unit_idx, "a", "ok")
        assert q2.done_status()[l2.unit_idx] == "ok"
        c.close()
    finally:
        srv2.stop()


def test_restart_hook_fires_on_incarnation_change(dataset):
    pipe, units = _work(dataset)
    q1 = WorkQueue(units, ["a"])
    srv1 = QueueServer(q1).start()
    host, port = srv1.address
    c = QueueClient(srv1.address)
    fired = []
    c.add_restart_hook(lambda: fired.append(c._incarnation))
    assert c.finished() is False
    assert fired == []                   # first incarnation is not a restart
    inc1 = c._incarnation
    assert inc1 == srv1.incarnation
    srv1.crash()
    srv2 = QueueServer(WorkQueue(units, ["a"]), host, port).start()
    try:
        c.pending()
        assert fired == [srv2.incarnation] and inc1 != srv2.incarnation
        c.pending()
        assert len(fired) == 1           # once per restart, not per call
        c.close()
    finally:
        srv2.stop()


def test_client_against_pre_incarnation_server(dataset):
    """Version skew: a server that never stamps ``inc`` (an old build) must
    leave a reconnect-capable client fully functional, hooks silent."""
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        srv._srv.incarnation = None      # simulate the old wire
        c = QueueClient(srv.address)
        fired = []
        c.add_restart_hook(lambda: fired.append(1))
        assert c.finished() is False
        unit, lease = c.next_unit("a")
        c.complete(lease.unit_idx, "a", "ok")
        assert c.done_status()[lease.unit_idx] == "ok"
        assert c._incarnation is None and fired == []
        c.close()


def test_reconnect_false_preserves_poison_semantics(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    srv = QueueServer(q).start()
    c = QueueClient(srv.address, reconnect=False)
    assert c.finished() is False
    srv.crash()
    with pytest.raises(ConnectionError):
        c.pending()
    # poisoned: fails fast forever, even if a server comes back
    with pytest.raises(ConnectionError, match="is down"):
        c.pending()
    c.close()


def test_reconnect_gives_up_after_window(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    srv = QueueServer(q).start()
    c = QueueClient(srv.address, reconnect_window_s=0.5, backoff_s=0.05)
    assert c.finished() is False
    srv.crash()                          # nothing ever comes back up
    t0 = time.monotonic()
    with pytest.raises(ConnectionError, match="gave up"):
        c.pending()
    assert 0.3 <= time.monotonic() - t0 < 10.0
    with pytest.raises(ConnectionError, match="is down"):
        c.pending()                      # window exhausted -> poisoned
    c.close()


def test_server_stop_is_idempotent_and_drains_inflight(dataset):
    pipe, units = _work(dataset)

    class SlowQueue(WorkQueue):
        def pending(self):
            time.sleep(0.4)              # a handler mid-call during stop()
            return super().pending()

    q = SlowQueue(units, ["a"])
    srv = QueueServer(q, drain_s=5.0).start()
    c = QueueClient(srv.address)
    assert c.finished() is False
    res = {}

    def call():
        try:
            res["pending"] = c.pending()
        except ConnectionError as e:
            res["error"] = e
    t = threading.Thread(target=call, daemon=True)
    t.start()
    time.sleep(0.1)                      # let the call reach the handler
    srv.stop()
    srv.stop()                           # second stop: no-op, no exception
    t.join(timeout=10)
    # the drain let the in-flight reply escape before the socket died
    assert res.get("pending") == len(units), res
    c.close()
    srv.crash()                          # after stop: still a no-op
