"""Streaming chunked ingest (repro.core.stream): bit-exact chunked QA fold,
in-flight digests, pipeline fallbacks, and the data-plane wiring
(InputCache.fetch_array, load_unit_inputs, provenance, ingest)."""
import hashlib
import io
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core import stream as stream_mod
from repro.core.stream import (StreamReport, bytes_chunks, stream_chunks,
                               stream_enabled, stream_chunk_bytes,
                               stream_file, stream_load_npy,
                               stream_verify_bytes, _Prefetcher)
from repro.core.integrity import sha256_load_array
from repro.kernels.checksum import (ACCUMULATOR_DTYPES, QAChecksumAccumulator,
                                    qa_stats)

RNG = np.random.default_rng(7)


def _volume(shape, dtype):
    if np.issubdtype(np.dtype(dtype), np.integer):
        info = np.iinfo(dtype)
        return RNG.integers(info.min, min(info.max, 1 << 30), shape,
                            dtype=dtype, endpoint=False)
    return (RNG.normal(50, 30, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# chunked fold == one-shot kernel, bit-exact
# ---------------------------------------------------------------------------
# Deterministic sweep of the invariant (the hypothesis property in
# test_property.py draws random shapes/chunkings where hypothesis is
# installed; this sweep pins the hard cases everywhere).

@pytest.mark.parametrize("shape", [(1,), (33,), (16, 16, 16), (37, 41),
                                   (5, 3, 7), (1023,), (1025,), (0,)])
@pytest.mark.parametrize("chunk", [1, 7, 64, 1000, 4096, 1 << 30])
def test_chunked_fold_bit_exact_f32(shape, chunk):
    vol = _volume(shape, np.float32)
    if vol.size > 4:
        vol.flat[1] = np.nan
        vol.flat[3] = np.inf
        vol.flat[vol.size - 1] = -np.inf
    acc = QAChecksumAccumulator(vol.size, vol.dtype, interpret=True)
    data = vol.tobytes()
    for off in range(0, len(data), chunk):
        acc.update(data[off:off + chunk])
    assert acc.finalize() == qa_stats(vol, interpret=True)


@pytest.mark.parametrize("dtype", ACCUMULATOR_DTYPES)
def test_chunked_fold_bit_exact_dtype_sweep(dtype):
    vol = _volume((11, 13), np.dtype(dtype))
    if np.issubdtype(vol.dtype, np.floating):
        vol.flat[5] = np.nan
    # a chunk size that never aligns with block or item boundaries
    for chunk in (3, 997):
        acc = QAChecksumAccumulator(vol.size, vol.dtype, interpret=True)
        data = vol.tobytes()
        for off in range(0, len(data), chunk):
            acc.update(data[off:off + chunk])
        assert acc.finalize() == qa_stats(vol, interpret=True)


def test_chunked_fold_host_backend_bit_exact():
    vol = _volume((29, 31), np.float32)
    vol.flat[17] = np.inf
    ref = qa_stats(vol, interpret=True)
    for chunk in (1, 123, 1 << 20):
        acc = QAChecksumAccumulator(vol.size, vol.dtype, backend="host")
        data = vol.tobytes()
        for off in range(0, len(data), chunk):
            acc.update(data[off:off + chunk])
        assert acc.finalize() == ref


def test_accumulator_rejects_overrun_and_truncation():
    vol = _volume((8,), np.float32)
    acc = QAChecksumAccumulator(vol.size, vol.dtype, backend="host")
    with pytest.raises(ValueError):
        acc.update(vol.tobytes() + b"x")
    acc = QAChecksumAccumulator(vol.size, vol.dtype, backend="host")
    acc.update(vol.tobytes()[:-1])
    with pytest.raises(ValueError, match="truncated"):
        acc.finalize()
    # finalized accumulators refuse further updates
    acc = QAChecksumAccumulator(vol.size, vol.dtype, backend="host")
    acc.update(vol.tobytes())
    acc.finalize()
    with pytest.raises(RuntimeError):
        acc.update(b"")


# ---------------------------------------------------------------------------
# the stream pipeline: digests, QA, fallbacks
# ---------------------------------------------------------------------------

def test_stream_load_npy_digest_matches_resident_path(tmp_path):
    vol = _volume((16, 16, 16), np.float32)
    p = tmp_path / "v.npy"
    np.save(p, vol)
    arr, digest, qa, rep = stream_load_npy(p, chunk_bytes=4096,
                                           device_qa=True)
    ref_arr, ref_digest = sha256_load_array(p)
    assert digest == ref_digest
    assert np.array_equal(arr, ref_arr)
    assert qa == qa_stats(vol, interpret=True)
    assert rep.nbytes == p.stat().st_size
    assert rep.chunks == -(-rep.nbytes // 4096)


def test_stream_non_npy_bytes_degrade_to_hash_only():
    blob = b"definitely not an npy file" * 100
    digest, qa, rep = stream_verify_bytes(blob, chunk_bytes=64)
    assert qa is None
    assert digest == hashlib.sha256(blob).hexdigest()
    assert rep.nbytes == len(blob)


def test_stream_fortran_and_truncated_degrade_to_hash_only(tmp_path):
    f = np.asfortranarray(_volume((6, 7), np.float32))
    p = tmp_path / "f.npy"
    np.save(p, f)
    arr, digest, qa, _ = stream_load_npy(p, device_qa=True)
    assert qa is None and np.array_equal(arr, f)
    assert digest == hashlib.sha256(p.read_bytes()).hexdigest()
    # truncated payload: digest of the bytes that arrived, QA refused
    raw = p.read_bytes()[:-5]
    digest, qa, _ = stream_verify_bytes(raw, chunk_bytes=16)
    assert qa is None and digest == hashlib.sha256(raw).hexdigest()


def test_stream_header_split_across_tiny_chunks(tmp_path):
    vol = _volume((33,), np.float32)
    p = tmp_path / "v.npy"
    np.save(p, vol)
    raw = p.read_bytes()
    data, digest, qa, rep = stream_chunks(bytes_chunks(raw, 7), npy_qa=True,
                                          chunk_bytes=7)
    assert data == raw
    assert digest == hashlib.sha256(raw).hexdigest()
    assert qa == qa_stats(vol, interpret=True)


def test_prefetcher_propagates_source_errors():
    def boom():
        yield b"ok"
        raise OSError("link died")
    pf = _Prefetcher(boom())
    with pytest.raises(OSError, match="link died"):
        list(pf)


def test_stream_report_merge_and_overlap():
    a = StreamReport(nbytes=10, chunks=2, chunk_bytes=5, read_s=1.0,
                     hash_s=0.5, device_s=0.25, wall_s=1.2)
    b = StreamReport(nbytes=6, chunks=1, chunk_bytes=6, read_s=0.5,
                     hash_s=0.25, device_s=0.0, wall_s=0.5)
    assert a.overlap_s == pytest.approx(0.55)
    a.merge(b)
    assert a.nbytes == 16 and a.chunks == 3 and a.files == 2
    assert a.chunk_bytes == 6
    d = a.to_dict()
    assert d["overlap_s"] == pytest.approx(a.overlap_s)
    assert StreamReport.from_dict(d).nbytes == 16


def test_stream_knobs(monkeypatch):
    monkeypatch.delenv(stream_mod.STREAM_ENV, raising=False)
    assert stream_enabled()
    monkeypatch.setenv(stream_mod.STREAM_ENV, "0")
    assert not stream_enabled()
    monkeypatch.setenv(stream_mod.CHUNK_MB_ENV, "2")
    assert stream_chunk_bytes() == 2 << 20
    monkeypatch.setenv(stream_mod.CHUNK_MB_ENV, "0.001")   # floored
    assert stream_chunk_bytes() == stream_mod.MIN_CHUNK_BYTES
    monkeypatch.setenv(stream_mod.CHUNK_MB_ENV, "junk")
    assert stream_chunk_bytes() == stream_mod.DEFAULT_CHUNK_BYTES


# ---------------------------------------------------------------------------
# data-plane wiring: InputCache, load_unit_inputs, provenance
# ---------------------------------------------------------------------------

from repro.dist.cache import InputCache  # noqa: E402


def test_fetch_array_streams_storage_misses(tmp_path):
    vol = _volume((16, 16, 16), np.float32)
    src = tmp_path / "v.npy"
    np.save(src, vol)
    cache = InputCache(tmp_path / "c")
    arr, digest, origin, nbytes, info = cache.fetch_array(src)
    assert origin == "storage"
    assert digest == hashlib.sha256(src.read_bytes()).hexdigest()
    assert info is not None and info["nbytes"] == nbytes
    st = cache.stats()
    assert st["stream_fetches"] == 1 and st["stream_bytes"] == nbytes
    assert st["stream_chunks"] >= 1
    # the hit path serves resident bytes: no stream report
    _, d2, origin2, _, info2 = cache.fetch_array(src)
    assert (origin2, info2) == ("cache", None) and d2 == digest


def test_fetch_array_streaming_disabled_is_identical(tmp_path, monkeypatch):
    vol = _volume((8, 8, 8), np.float32)
    src = tmp_path / "v.npy"
    np.save(src, vol)
    monkeypatch.setenv(stream_mod.STREAM_ENV, "0")
    cold = InputCache(tmp_path / "off")
    arr, digest, origin, nbytes, info = cold.fetch_array(src)
    assert info is None and cold.stats()["stream_fetches"] == 0
    monkeypatch.delenv(stream_mod.STREAM_ENV)
    warm = InputCache(tmp_path / "on")
    arr2, digest2, *_ = warm.fetch_array(src)
    assert digest2 == digest and np.array_equal(arr2, arr)


def test_fetch_array_respects_read_storage_seam(tmp_path, monkeypatch):
    """Benchmarks model the storage link by monkeypatching _read_storage;
    the chunked reader must route through the override, not around it."""
    vol = _volume((8, 8), np.float32)
    src = tmp_path / "v.npy"
    np.save(src, vol)
    calls = []

    def tracked(path):
        calls.append(Path(path))
        return Path(path).read_bytes()

    monkeypatch.setattr(InputCache, "_read_storage", staticmethod(tracked))
    cache = InputCache(tmp_path / "c")
    _, digest, origin, _, info = cache.fetch_array(src)
    assert origin == "storage" and calls == [src]
    assert digest == hashlib.sha256(src.read_bytes()).hexdigest()
    assert info is not None            # still chunk-hashed after the seam


def test_load_unit_inputs_aggregates_stream_reports(tmp_path):
    from repro.core.workflow import load_unit_inputs
    from repro.core.query import WorkUnit
    roots = tmp_path / "data"
    rels = {}
    for name in ("T1w", "T2w"):
        rel = f"sub-001/{name}.npy"
        (roots / "sub-001").mkdir(parents=True, exist_ok=True)
        np.save(roots / rel, _volume((8, 8, 8), np.float32))
        rels[name] = rel
    unit = WorkUnit(dataset="ds", subject="001", session="01",
                    pipeline="p", pipeline_digest="d",
                    inputs=rels, out_dir=str(tmp_path / "out"))
    # cache-less path streams straight off storage
    _, sums, _, _, _, stream = load_unit_inputs(unit, roots)
    assert stream is not None and stream["files"] == 2
    for rel, digest in sums.items():
        assert digest == hashlib.sha256((roots / rel).read_bytes()).hexdigest()
    # cache path: misses stream, aggregate report matches per-file bytes
    cache = InputCache(tmp_path / "c")
    _, sums2, _, _, _, stream2 = load_unit_inputs(unit, roots, cache=cache)
    assert sums2 == sums
    assert stream2 is not None and stream2["files"] == 2
    assert stream2["nbytes"] == sum(
        (roots / r).stat().st_size for r in rels.values())
    # all-resident second pass: nothing streamed
    _, _, hit, _, _, stream3 = load_unit_inputs(unit, roots, cache=cache)
    assert hit is True and stream3 is None


def test_run_unit_stamps_stream_provenance(tmp_path):
    from repro.core.pipelines import builtin_pipelines
    from repro.core.provenance import Provenance
    from repro.core.query import WorkUnit
    from repro.core.workflow import run_unit
    pipe = builtin_pipelines()["bias_correct"]
    root = tmp_path / "data"
    rel = "sub-001/T1w.npy"
    (root / "sub-001").mkdir(parents=True)
    np.save(root / rel, np.abs(_volume((8, 8, 8), np.float32)))
    unit = WorkUnit(dataset="ds", subject="001", session="01",
                    pipeline="bias_correct", pipeline_digest=pipe.digest(),
                    inputs={"T1w": rel}, out_dir=str(tmp_path / "out"))
    res = run_unit(unit, pipe, root)
    assert res.status == "ok"
    prov = Provenance.load(tmp_path / "out")
    assert prov is not None and prov.stream is not None
    assert prov.stream["nbytes"] == (root / rel).stat().st_size
    assert prov.stream["overlap_s"] >= 0.0
