"""Data pipeline determinism/resume + training loop convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import IntegrityError
from repro.data import DataPipeline, ShardedTokenSource, make_lm_batches
from repro.train import OptConfig, init_train_state, make_train_step, lr_schedule


def test_sharded_source_integrity(tmp_path):
    src = ShardedTokenSource.synthesize(tmp_path / "d", n_shards=2,
                                        tokens_per_shard=4096)
    arr = src.load_shard(0)
    assert arr.dtype == np.int32
    # corrupt a shard -> loud failure
    p = tmp_path / "d" / src.shards[1].path
    bad = np.load(p)
    bad[0] ^= 1
    np.save(p, bad)
    with pytest.raises(IntegrityError):
        src.load_shard(1)


def test_pipeline_deterministic_and_resumable(tmp_path):
    src = ShardedTokenSource.synthesize(tmp_path / "d", n_shards=2,
                                        tokens_per_shard=16384)
    pipe = DataPipeline(src, batch=4, seq_len=128, seed=7)
    b5a = pipe.batch_at(5)
    pipe2 = DataPipeline(src, batch=4, seq_len=128, seed=7)
    b5b = pipe2.batch_at(5)
    assert np.array_equal(b5a["tokens"], b5b["tokens"])   # restart-safe
    assert not np.array_equal(pipe.batch_at(5)["tokens"],
                              pipe.batch_at(6)["tokens"])
    # targets are next-token shifted
    assert np.array_equal(b5a["tokens"][:, 1:], b5a["targets"][:, :-1])


def test_pipeline_dp_slices_partition(tmp_path):
    src = ShardedTokenSource.synthesize(tmp_path / "d")
    full = DataPipeline(src, batch=4, seq_len=64, seed=1).batch_at(0)
    parts = [DataPipeline(src, batch=4, seq_len=64, seed=1,
                          dp_rank=r, dp_size=2).batch_at(0) for r in range(2)]
    recon = np.concatenate([p["tokens"] for p in parts])
    assert np.array_equal(recon, full["tokens"])


def test_prefetch_iterator(tmp_path):
    src = ShardedTokenSource.synthesize(tmp_path / "d")
    pipe = DataPipeline(src, batch=2, seq_len=32, seed=0)
    it = pipe.iter_from(3)
    first = next(it)
    assert np.array_equal(first["tokens"], pipe.batch_at(3)["tokens"])
    next(it)


def test_lr_schedule_shape():
    opt = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(opt, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]                   # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]                 # decay
    assert lrs[4] >= opt.lr * opt.min_lr_ratio * 0.99


def test_training_reduces_loss():
    """Tiny model overfits a repeated batch — the optimizer works e2e."""
    cfg = get_config("llama3.2-1b").reduced(n_layers=2, vocab_size=128)
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(
        cfg, OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                       weight_decay=0.0)))
    batch = make_lm_batches(cfg, 4, 64, 1, seed=3)[0]
    losses = []
    for _ in range(30):
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.6, losses[::6]
    assert np.isfinite(losses[-1])
    assert float(m["grad_norm"]) > 0


def test_moe_training_step_and_aux_loss():
    cfg = get_config("moonshot-v1-16b-a3b").reduced(n_layers=2, vocab_size=128)
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3)))
    batch = make_lm_batches(cfg, 2, 64, 1)[0]
    params, opt_state, m = step_fn(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["aux_loss"]) > 0.5     # load-balance loss near E*1/E*1 = 1
