"""Multi-node work-stealing executor: queue/lease protocol unit tests plus
end-to-end cluster runs with stealing, node death, cross-node speculation,
and the exactly-one-ok-provenance invariant.

CI matrix knobs: ``REPRO_CLUSTER_NODES`` scales the node count of the
plain completion run, and ``REPRO_FAULT_INJECT=1`` widens the deterministic
invariant sweep with extra chaos combinations."""
import os
import threading
import time
from collections import Counter
from pathlib import Path

import pytest

from conftest import wait_until

N_NODES = max(2, int(os.environ.get("REPRO_CLUSTER_NODES", "4")))
FAULT_INJECT = os.environ.get("REPRO_FAULT_INJECT", "0") == "1"

from repro.core import (LocalRunner, Provenance, builtin_pipelines,
                        is_complete, query_available_work, synthesize_dataset)
from repro.core.workflow import StragglerDetector
from repro.dist import ClusterRunner, WorkQueue


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path, "clds", n_subjects=8,
                              sessions_per_subject=2, shape=(10, 10, 10))


def _work(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    return pipe, units


def _ok_provenances(units, digest):
    """Committed ok provenance records across the units' output dirs."""
    provs = []
    for u in units:
        p = Provenance.load(Path(u.out_dir))
        if p is not None and p.status == "ok" and p.pipeline_digest == digest:
            provs.append(p)
    return provs


# ---------------------------------------------------------------------------
# queue / lease protocol
# ---------------------------------------------------------------------------

def _queue(dataset, node_ids, **kw):
    pipe, units = _work(dataset)
    return WorkQueue(units, node_ids, **kw), units


def test_round_robin_partition_is_balanced(dataset):
    q, units = _queue(dataset, ["a", "b", "c"])
    depths = q.queue_depths()
    assert sum(depths.values()) == len(units) == 16
    assert max(depths.values()) - min(depths.values()) <= 1


def test_lease_epoch_bumps_on_every_grant(dataset):
    q, units = _queue(dataset, ["a", "b"])
    unit, lease = q.next_unit("a")
    assert lease.epoch == 1 and lease.node_id == "a"
    # node dies; reap requeues; re-grant bumps the epoch
    q.mark_dead("a")
    assert lease.unit_idx in q.requeues
    got = None
    while got is None or got[1].unit_idx != lease.unit_idx:
        got = q.next_unit("b")
    assert got[1].epoch == 2


def test_idle_node_steals_tail_of_longest_queue(dataset):
    q, units = _queue(dataset, ["busy", "idle"])
    # drain idle's own partition without completing busy's
    own = q.queue_depths()["idle"]
    for _ in range(own):
        q.next_unit("idle")
    before = q.queue_depths()["busy"]
    assert q.next_unit("idle") is not None          # forced to steal
    assert q.steals["idle"] == 1
    # stole half the victim's tail, then leased one of them
    assert q.queue_depths()["busy"] == before - max(1, before // 2)


def test_dead_node_queued_units_redistribute_to_alive(dataset):
    q, units = _queue(dataset, ["a", "b"])
    orphaned = q.queue_depths()["a"]
    q.mark_dead("a")
    assert q.queue_depths()["a"] == 0
    assert q.queue_depths()["b"] == len(units)
    assert len(q.requeues) == orphaned
    assert q.next_unit("a") is None                 # dead node gets nothing


def test_reap_requeues_leases_after_heartbeat_expiry(dataset):
    t = {"now": 0.0}
    q, units = _queue(dataset, ["a", "b"], lease_ttl_s=1.0,
                      now=lambda: t["now"])
    unit, lease = q.next_unit("a")
    t["now"] = 0.9
    q.heartbeat("b")
    assert q.reap() == []                           # within ttl: nothing
    t["now"] = 1.1
    assert lease.unit_idx in q.reap()               # a silent past ttl
    assert "a" not in q.alive_nodes() and "b" in q.alive_nodes()


def test_reap_expires_unrenewed_lease_on_live_node(dataset):
    """The lost-grant case: a grant whose reply never reached the node
    (connection dropped mid-reply and the reconnect replay drew a fresh
    lease, or a coordinator crash right after journaling it). The node
    keeps heartbeating but never renews the orphaned lease, so reap()
    must reclaim it lease-by-lease — without that the unit stays leased
    forever and the campaign never finishes."""
    t = {"now": 0.0}
    q, units = _queue(dataset, ["a", "b"], lease_ttl_s=1.0,
                      now=lambda: t["now"])
    unit, lease = q.next_unit("a")
    t["now"] = 0.9
    q.heartbeat("a")
    q.heartbeat("b")
    assert q.reap() == []                    # within ttl: nothing
    t["now"] = 1.1
    q.heartbeat("a")
    q.heartbeat("b")
    assert q.reap() == [lease.unit_idx]      # orphan reclaimed...
    assert set(q.alive_nodes()) == {"a", "b"}   # ...both nodes stay alive
    # the unit is grantable again, at a higher epoch
    got = None
    while got is None or got[1].unit_idx != lease.unit_idx:
        got = q.next_unit("b")
    assert got[1].epoch == lease.epoch + 1
    # the old holder (had the grant actually arrived late) renews into a
    # rejection, exactly like any reaped lease
    assert q.renew(lease.unit_idx, "a", lease.epoch) is False


def test_renewed_lease_never_expires_on_live_node(dataset):
    t = {"now": 0.0}
    q, units = _queue(dataset, ["a", "b"], lease_ttl_s=1.0,
                      now=lambda: t["now"])
    unit, lease = q.next_unit("a")
    t["now"] = 0.9
    q.heartbeat("a")
    q.heartbeat("b")
    assert q.renew(lease.unit_idx, "a", lease.epoch)
    t["now"] = 1.8                           # grant is stale, renewal is not
    q.heartbeat("a")
    q.heartbeat("b")
    assert q.reap() == []
    q.complete(lease.unit_idx, "a", "ok")
    assert q.done_status()[lease.unit_idx] == "ok"


def test_expired_lease_late_completion_stays_exactly_once(dataset):
    """Expiry doesn't eagerly bump the epoch, so a holder whose grant
    merely arrived late can still report; the re-run's duplicate lands in
    the dup log — exactly one primary record either way."""
    t = {"now": 0.0}
    q, units = _queue(dataset, ["a", "b"], lease_ttl_s=1.0,
                      now=lambda: t["now"])
    unit, lease = q.next_unit("a")
    idx = lease.unit_idx
    t["now"] = 1.1
    q.heartbeat("a")
    q.heartbeat("b")
    assert q.reap() == [idx]
    q.complete(idx, "a", "ok", meta={"seconds": 0.1, "status": "ok"})
    q.complete(idx, "b", "ok", meta={"seconds": 0.2, "status": "ok"})
    snap = q.results_snapshot()
    assert snap["primaries"][idx]["node_id"] == "a"
    assert [d["idx"] for d in snap["duplicates"]] == [idx]


def test_expired_twin_settles_deferred_primary_failure(dataset):
    """A delivered twin whose reply was lost in flight (b's client redialed
    and never learned of the lease) must not wedge a unit whose primary
    already failed and was only waiting on the twin."""
    t = {"now": 0.0}
    q, units = _queue(dataset, ["a", "b"], lease_ttl_s=1.0,
                      now=lambda: t["now"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    assert q.speculate(lease.unit_idx, "b") is not None
    got = q.next_unit("b")                        # delivery; reply then lost
    assert got[1].speculative and got[1].unit_idx == lease.unit_idx
    q.complete(lease.unit_idx, "a", "failed")     # deferred: twin racing
    assert lease.unit_idx not in q.done_status()
    t["now"] = 1.1
    q.heartbeat("a")
    q.heartbeat("b")
    q.reap()                                      # b never renews the twin
    assert q.done_status()[lease.unit_idx] == "failed"


def test_queued_undelivered_twin_does_not_expire(dataset):
    """A twin still sitting in its target's speculative queue was never on
    the wire, so nothing can have been lost: expiry must leave it alone —
    the target (busy with a long unit) picks it up whenever it next polls,
    and delivery restarts the expiry clock."""
    t = {"now": 0.0}
    q, units = _queue(dataset, ["a", "b"], lease_ttl_s=1.0,
                      now=lambda: t["now"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    twin = q.speculate(lease.unit_idx, "b")
    assert twin is not None
    t["now"] = 1.5                                # b busy: hasn't polled yet
    q.heartbeat("a")
    q.heartbeat("b")
    assert q.renew(lease.unit_idx, "a", lease.epoch)   # primary stays renewed
    assert q.reap() == []
    got = q.next_unit("b")                        # late pickup still works
    assert got[1].speculative and got[1].unit_idx == lease.unit_idx
    # the clock restarted at delivery: one TTL from now, not from grant
    t["now"] = 2.4
    q.heartbeat("a")
    q.heartbeat("b")
    assert q.renew(lease.unit_idx, "a", lease.epoch)
    assert q.reap() == []
    q.complete(lease.unit_idx, "b", "ok", speculative=True)
    assert q.done_status()[lease.unit_idx] == "ok"


def test_speculate_rejects_same_node_and_double_twin(dataset):
    q, units = _queue(dataset, ["a", "b"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    assert q.speculate(lease.unit_idx, "a") is None      # same node: no
    twin = q.speculate(lease.unit_idx, "b")
    assert twin is not None and twin.speculative
    assert q.speculate(lease.unit_idx, "b") is None      # one twin max


def test_failed_twin_does_not_retire_unit(dataset):
    q, units = _queue(dataset, ["a", "b"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    q.speculate(lease.unit_idx, "b")
    q.complete(lease.unit_idx, "b", "failed", speculative=True)
    assert q.pending() == len(units)                # primary still owns it
    q.complete(lease.unit_idx, "a", "ok")
    assert q.pending() == len(units) - 1


def test_failed_primary_defers_to_inflight_twin(dataset):
    """A terminal primary failure must not retire a unit whose twin is still
    racing — the twin's ok saves it."""
    q, units = _queue(dataset, ["a", "b"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    assert q.speculate(lease.unit_idx, "b") is not None
    q.complete(lease.unit_idx, "a", "failed")
    assert q.pending() == len(units)                 # deferred, not retired
    q.complete(lease.unit_idx, "b", "ok", speculative=True)
    assert q.done_status()[lease.unit_idx] == "ok"


def test_failed_primary_settles_when_twin_also_fails(dataset):
    q, units = _queue(dataset, ["a", "b"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    q.speculate(lease.unit_idx, "b")
    q.complete(lease.unit_idx, "a", "failed")
    q.complete(lease.unit_idx, "b", "failed", speculative=True)
    assert q.done_status()[lease.unit_idx] == "failed"


def test_failed_primary_settles_when_twin_node_dies(dataset):
    q, units = _queue(dataset, ["a", "b"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    q.speculate(lease.unit_idx, "b")
    q.complete(lease.unit_idx, "a", "failed")
    q.mark_dead("b")                                 # twin evaporates
    assert q.done_status()[lease.unit_idx] == "failed"


def test_dead_node_completion_is_ignored(dataset):
    q, units = _queue(dataset, ["a", "b"])
    unit, lease = q.next_unit("a")
    q.mark_dead("a")
    q.complete(lease.unit_idx, "a", "failed")       # zombie report: ignored
    assert q.pending() == len(units)


def test_active_leases_feed_lease_aware_query(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b"])
    unit, lease = q.next_unit("a")
    leases = q.active_leases()
    assert leases[unit.job_id] == "a"
    work, excluded = query_available_work(dataset, pipe, leases=leases)
    assert len(work) == len(units) - 1
    assert any(e.reason == "leased by a" for e in excluded)
    assert all(u.job_id != unit.job_id for u in work)


def test_straggler_detector_needs_samples_then_thresholds():
    d = StragglerDetector(factor=2.0, min_s=0.1, min_samples=4)
    assert not d.is_straggler(100.0)                # no median yet
    for s in (0.1, 0.1, 0.1, 0.1):
        d.observe(s)
    assert d.median() == pytest.approx(0.1)
    assert not d.is_straggler(0.15)                 # under factor x median
    assert d.is_straggler(0.25)
    assert not StragglerDetector(2.0, min_s=1.0).is_straggler(0.5)


# ---------------------------------------------------------------------------
# end-to-end cluster runs
# ---------------------------------------------------------------------------

def test_cluster_completes_all_units(dataset):
    pipe, units = _work(dataset)
    runner = ClusterRunner(pipe, dataset.root, nodes=N_NODES)
    results = runner.run(units)
    ok = [r for r in results if r.status == "ok"]
    assert len(ok) == len(units) == 16
    assert len(_ok_provenances(units, pipe.digest())) == len(units)
    work2, _ = query_available_work(dataset, pipe)
    assert work2 == []                               # idempotent re-query


def test_cluster_single_node_and_empty_list(dataset):
    pipe, units = _work(dataset)
    assert ClusterRunner(pipe, dataset.root, nodes=1).run([]) == []
    results = ClusterRunner(pipe, dataset.root, nodes=1).run(units[:3])
    assert sorted(r.status for r in results) == ["ok"] * 3


def test_work_stealing_rebalances_slow_node(dataset):
    pipe, units = _work(dataset)

    def slow_node0(unit, attempt):
        if threading.current_thread().name == "node-0":
            time.sleep(0.15)

    runner = ClusterRunner(pipe, dataset.root, nodes=3,
                           fault_hook=slow_node0, straggler_factor=100.0)
    results = runner.run(units)
    assert sum(r.status == "ok" for r in results) == len(units)
    st = runner.stats
    assert sum(st.steals.values()) >= 1              # fast nodes stole
    fair = len(units) / 3
    assert st.processed["node-0"] < fair             # slow node did less
    assert sum(st.processed.values()) >= len(units)


def test_dead_node_units_requeued_and_completed(dataset):
    pipe, units = _work(dataset)
    runner = ClusterRunner(pipe, dataset.root, nodes=3,
                           die_after={"node-1": 1},
                           lease_ttl_s=0.5, hb_interval_s=0.1)
    results = runner.run(units)
    assert sum(r.status == "ok" for r in results) == len(units)
    st = runner.stats
    assert "node-1" in st.dead_nodes
    assert len(st.requeued) >= 1                     # leases came back
    provs = _ok_provenances(units, pipe.digest())
    assert len(provs) == len(units)
    # requeued units (leased or queued on the dead node) commit elsewhere;
    # the epoch>=2 re-grant itself is covered by the queue-level lease test
    requeued_ids = {units[i].job_id for i in st.requeued}
    for u in units:
        prov = Provenance.load(Path(u.out_dir))
        if u.job_id in requeued_ids and prov.node_id:
            assert prov.node_id != "node-1"
            assert prov.lease_epoch >= 1


def test_all_nodes_dead_raises(dataset):
    pipe, units = _work(dataset)
    runner = ClusterRunner(pipe, dataset.root, nodes=2,
                           die_after={"node-0": 1, "node-1": 1},
                           lease_ttl_s=0.4, hb_interval_s=0.1)
    with pytest.raises(RuntimeError, match="dead|without a result"):
        runner.run(units)


def test_long_unit_is_not_mistaken_for_dead_node(dataset):
    """Heartbeats are decoupled from compute: a unit running far past the
    lease ttl must not get its node reaped."""
    pipe, units = _work(dataset)
    slow_id = units[0].job_id
    done = threading.Event()

    def slow(unit, attempt):
        if unit.job_id == slow_id and not done.is_set():
            done.set()
            # hold well past the lease ttl; bail out the moment the node is
            # (wrongly) reaped so the asserts below fail with the evidence
            # instead of sleeping through a fixed window
            t0 = time.monotonic()
            wait_until(lambda: time.monotonic() - t0 > 2.5 * 0.4
                       or runner.queue.requeues,
                       timeout=10, desc="lease ttl to elapse mid-compute")

    runner = ClusterRunner(pipe, dataset.root, nodes=2, fault_hook=slow,
                           lease_ttl_s=0.4, hb_interval_s=0.1,
                           straggler_factor=100.0)
    results = runner.run(units)
    assert sum(r.status == "ok" for r in results) == len(units)
    assert runner.stats.dead_nodes == []
    assert runner.stats.requeued == []


def test_cross_node_speculative_twin_exactly_one_ok(dataset):
    pipe, units = _work(dataset)
    slow_id = units[0].job_id
    slept = {"n": 0}
    lock = threading.Lock()

    def slow_once(unit, attempt):
        if unit.job_id == slow_id:
            with lock:
                first = slept["n"] == 0
                slept["n"] += 1
            if first:
                # the primary holds until its cross-node twin has retired
                # the unit — deterministic "twin wins" instead of a fixed
                # sleep racing the straggler detector on a loaded box
                wait_until(lambda: 0 in runner.queue.done_status(),
                           timeout=30, desc="speculative twin to commit")

    runner = ClusterRunner(pipe, dataset.root, nodes=2, fault_hook=slow_once,
                           straggler_factor=1.5, straggler_min_s=0.15,
                           poll_s=0.03)
    results = runner.run(units)
    by_status = Counter(r.status for r in results)
    assert by_status["ok"] == len(units)
    assert by_status.get("failed", 0) == 0
    assert runner.stats.speculated >= 1
    ok_ids = [r.unit.job_id for r in results if r.status == "ok"]
    assert len(ok_ids) == len(set(ok_ids))           # no double-counted unit
    assert len(_ok_provenances(units, pipe.digest())) == len(units)
    # the twin was launched cross-node, so duplicates surface as speculative
    assert by_status.get("speculative", 0) >= 1


def test_counts_exact_under_retry_plus_node_death(dataset):
    pipe, units = _work(dataset)
    lock = threading.Lock()
    fails = {"n": 0}

    def flaky(unit, attempt):
        if attempt == 1:
            with lock:
                fails["n"] += 1
            raise RuntimeError("injected transient failure")

    runner = ClusterRunner(pipe, dataset.root, nodes=3, max_retries=2,
                           fault_hook=flaky, die_after={"node-2": 2},
                           lease_ttl_s=0.5, hb_interval_s=0.1,
                           straggler_factor=100.0)
    results = runner.run(units)
    ok = [r for r in results if r.status == "ok"]
    assert len(ok) == len(units)                     # exact, despite chaos
    assert all(r.attempts >= 2 for r in ok)
    assert len(_ok_provenances(units, pipe.digest())) == len(units)


def test_poison_unit_fails_terminally_without_blocking_rest(dataset):
    pipe, units = _work(dataset)
    poison = units[3].job_id

    def kill_unit(unit, attempt):
        if unit.job_id == poison:
            raise ValueError("corrupted volume")

    runner = ClusterRunner(pipe, dataset.root, nodes=2, max_retries=1,
                           fault_hook=kill_unit, straggler_factor=100.0)
    results = runner.run(units)
    by_id = {r.unit.job_id: r for r in results
             if r.status in ("ok", "failed")}
    assert by_id[poison].status == "failed"
    assert sum(r.status == "ok" for r in results) == len(units) - 1
    prov = Provenance.load(Path(units[3].out_dir))
    assert prov.status == "failed" and "corrupted volume" in prov.error


def test_cluster_matches_local_runner_outputs(tmp_path):
    """Same units, same pipeline: the cluster commits bit-identical outputs
    (checksum maps in provenance) as the single-host runner."""
    pipe = builtin_pipelines()["bias_correct"]
    ds_a = synthesize_dataset(tmp_path / "a", "detds", n_subjects=3,
                              sessions_per_subject=2, shape=(10, 10, 10))
    ds_b = synthesize_dataset(tmp_path / "b", "detds", n_subjects=3,
                              sessions_per_subject=2, shape=(10, 10, 10))
    units_a, _ = query_available_work(ds_a, pipe)
    units_b, _ = query_available_work(ds_b, pipe)
    LocalRunner(pipe, ds_a.root, workers=2).run(units_a)
    ClusterRunner(pipe, ds_b.root, nodes=3).run(units_b)
    for ua, ub in zip(units_a, units_b):
        pa = Provenance.load(Path(ua.out_dir))
        pb = Provenance.load(Path(ub.out_dir))
        assert pa.outputs == pb.outputs              # same bytes committed
        assert set(pa.inputs.values()) == set(pb.inputs.values())


def test_provenance_carries_node_id_and_epoch(dataset):
    pipe, units = _work(dataset)
    runner = ClusterRunner(pipe, dataset.root, nodes=3)
    runner.run(units)
    node_ids = set(runner.node_ids())
    seen_nodes = set()
    for prov in _ok_provenances(units, pipe.digest()):
        assert prov.node_id in node_ids
        assert prov.lease_epoch >= 1
        seen_nodes.add(prov.node_id)
    assert len(seen_nodes) > 1                       # genuinely parallel


def test_local_runner_provenance_keeps_single_host_defaults(dataset):
    """The cluster fields default clean on the single-host path."""
    pipe, units = _work(dataset)
    LocalRunner(pipe, dataset.root).run(units[:1])
    prov = Provenance.load(Path(units[0].out_dir))
    assert prov.node_id == "" and prov.lease_epoch == 0


@pytest.mark.parametrize("n_subjects,sessions,nodes,flaky,die", [
    (2, 2, 3, True, 1),       # transient faults + node death, 3 nodes
    (1, 1, 2, False, 0),      # single unit, one node dies
    (3, 1, 1, True, 0),       # single node, retries only
] + ([
    (4, 2, N_NODES, True, 2),     # wider chaos under REPRO_FAULT_INJECT=1
    (2, 1, N_NODES, True, 0),
    (4, 1, 2, True, 1),
] if FAULT_INJECT else []))
def test_cluster_invariant_fixed_grid(n_subjects, sessions, nodes, flaky, die):
    """Deterministic slice of the hypothesis property in test_property.py
    (which only runs where hypothesis is installed): exactly one committed ok
    provenance per unit, no torn files ever visible."""
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(n_subjects, sessions, nodes, flaky, die)


@pytest.mark.slow
def test_acceptance_64_units_death_plus_speculation(tmp_path):
    """ISSUE acceptance: 4 nodes, 64 units, one injected node death plus a
    straggler twin — exactly 64 committed ok provenances."""
    ds = synthesize_dataset(tmp_path, "acc64", n_subjects=32,
                            sessions_per_subject=2, shape=(8, 8, 8))
    pipe, units = _work(ds)
    assert len(units) == 64
    slow_id = units[5].job_id
    slept = {"n": 0}
    lock = threading.Lock()

    def chaos(unit, attempt):
        if unit.job_id == slow_id:
            with lock:
                first = slept["n"] == 0
                slept["n"] += 1
            if first:
                # straggle until the twin commits the unit (bounded), not
                # for a fixed window the detector might overrun
                wait_until(lambda: 5 in runner.queue.done_status(),
                           timeout=30, desc="speculative twin to commit")

    runner = ClusterRunner(pipe, ds.root, nodes=4, fault_hook=chaos,
                           die_after={"node-3": 3},
                           lease_ttl_s=0.5, hb_interval_s=0.1,
                           straggler_factor=2.0, straggler_min_s=0.2)
    results = runner.run(units)
    assert sum(r.status == "ok" for r in results) == 64
    provs = _ok_provenances(units, pipe.digest())
    assert len(provs) == 64                          # exactly one ok each
    assert "node-3" in runner.stats.dead_nodes
    assert is_complete(Path(units[5].out_dir), pipe.digest())
