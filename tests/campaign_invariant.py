"""Shared body of the campaign-planner invariant, used by the hypothesis
property (``test_property.py``: random cohorts/summaries) and a
deterministic grid in ``test_campaign.py`` — the same split as
``cluster_invariant.py``, so the invariant still runs where hypothesis is
absent."""
import tempfile
from pathlib import Path


def reference_admitted(cohorts):
    """Independent model of admission: first cohort to admit a job_id wins;
    a session its own cohort excluded is never admitted by that cohort."""
    admitted, seen = [], set()
    for c in cohorts:
        excl = {(e.subject, e.session) for e in c.excluded}
        for u in c.units:
            if (u.subject, u.session) in excl or u.job_id in seen:
                continue
            seen.add(u.job_id)
            admitted.append(u.job_id)
    return admitted


def check_campaign_invariant(cohorts, summaries, throttle=100, status=None,
                             max_shard_units=None):
    """For the given cohorts and summary state: every admitted unit is
    assigned to exactly one shard, no excluded unit is ever assigned, the
    plan is structurally sound (no empty shards, submittable throttle, warm
    shards only name summary-backed nodes), and replanning — in memory and
    through a serialized ``campaign.json`` — is byte-identical.

    DAG cohorts additionally check **producer placement**: a child whose
    parents were all planned onto one node, and whose own input digests are
    invisible to every *real* summary (they are predicted parent outputs,
    not yet on any disk), must be planned onto that same node — its
    parents' placement *is* its locality. Children whose parents went cold
    carry no prediction and must stay cold like any blind unit."""
    from repro.core.campaign import (CampaignPlan, _normalize_summaries,
                                     plan_campaign)

    plan = plan_campaign(cohorts, summaries, throttle=throttle,
                         status=status, max_shard_units=max_shard_units)
    assigned = plan.assigned_unit_ids()
    # exactly once, and exactly the reference admission set
    assert len(assigned) == len(set(assigned))
    assert sorted(assigned) == sorted(reference_admitted(cohorts))
    # structural sanity
    assert all(s.unit_ids for s in plan.shards)
    assert plan.throttle >= 1
    assert all(s.node_id is None or s.node_id in plan.nodes
               for s in plan.shards)
    if max_shard_units:
        assert all(len(s.unit_ids) <= max_shard_units for s in plan.shards)
    # producer placement, against an independent reading of the inputs
    decoded = _normalize_summaries(summaries)
    units_by_id = {}
    for c in cohorts:
        for u in c.units:
            units_by_id.setdefault(u.job_id, u)
    node_of = {jid: s.node_id for s in plan.shards for jid in s.unit_ids}
    for jid in assigned:
        u = units_by_id[jid]
        deps = [d for d in (getattr(u, "depends_on", None) or ())
                if d in node_of]
        digests = set((u.input_digests or {}).values())
        scoreable = sum((u.input_bytes or {}).get(s, 0)
                        for s in (u.input_digests or {}))
        if not deps or not digests or scoreable <= 0:
            continue          # nothing to score: cold is the right answer
        if any(d in s for d in digests for s in decoded.values()):
            continue          # real warmth somewhere may legitimately win
        parent_nodes = {node_of[d] for d in deps}
        if parent_nodes == {None}:
            # parents went cold: no prediction, the child must stay blind
            assert node_of[jid] is None, \
                f"{jid} warm-placed with cold parents"
        elif len(parent_nodes) == 1:
            (pn,) = parent_nodes
            assert node_of[jid] == pn, \
                (f"{jid} planned on {node_of[jid]}, parents' outputs land "
                 f"on {pn}")
    # determinism + byte-identical replay through disk
    again = plan_campaign(cohorts, summaries, throttle=throttle,
                          status=status, max_shard_units=max_shard_units)
    assert again.to_json() == plan.to_json()
    with tempfile.TemporaryDirectory() as td:
        p = plan.save(Path(td) / "campaign.json")
        assert CampaignPlan.load(p).to_json() == plan.to_json()
    return plan
