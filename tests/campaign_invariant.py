"""Shared body of the campaign-planner invariant, used by the hypothesis
property (``test_property.py``: random cohorts/summaries) and a
deterministic grid in ``test_campaign.py`` — the same split as
``cluster_invariant.py``, so the invariant still runs where hypothesis is
absent."""
import tempfile
from pathlib import Path


def reference_admitted(cohorts):
    """Independent model of admission: first cohort to admit a job_id wins;
    a session its own cohort excluded is never admitted by that cohort."""
    admitted, seen = [], set()
    for c in cohorts:
        excl = {(e.subject, e.session) for e in c.excluded}
        for u in c.units:
            if (u.subject, u.session) in excl or u.job_id in seen:
                continue
            seen.add(u.job_id)
            admitted.append(u.job_id)
    return admitted


def check_campaign_invariant(cohorts, summaries, throttle=100, status=None,
                             max_shard_units=None):
    """For the given cohorts and summary state: every admitted unit is
    assigned to exactly one shard, no excluded unit is ever assigned, the
    plan is structurally sound (no empty shards, submittable throttle, warm
    shards only name summary-backed nodes), and replanning — in memory and
    through a serialized ``campaign.json`` — is byte-identical."""
    from repro.core.campaign import CampaignPlan, plan_campaign

    plan = plan_campaign(cohorts, summaries, throttle=throttle,
                         status=status, max_shard_units=max_shard_units)
    assigned = plan.assigned_unit_ids()
    # exactly once, and exactly the reference admission set
    assert len(assigned) == len(set(assigned))
    assert sorted(assigned) == sorted(reference_admitted(cohorts))
    # structural sanity
    assert all(s.unit_ids for s in plan.shards)
    assert plan.throttle >= 1
    assert all(s.node_id is None or s.node_id in plan.nodes
               for s in plan.shards)
    if max_shard_units:
        assert all(len(s.unit_ids) <= max_shard_units for s in plan.shards)
    # determinism + byte-identical replay through disk
    again = plan_campaign(cohorts, summaries, throttle=throttle,
                          status=status, max_shard_units=max_shard_units)
    assert again.to_json() == plan.to_json()
    with tempfile.TemporaryDirectory() as td:
        p = plan.save(Path(td) / "campaign.json")
        assert CampaignPlan.load(p).to_json() == plan.to_json()
    return plan
