"""End-to-end behaviour of the whole system: the paper's processing loop
driving JAX pipelines, then the training stack consuming the same substrate
(manifest -> data -> train -> checkpoint -> restart)."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (LocalRunner, TieredStore, builtin_pipelines,
                        generate_jobs, query_available_work, synthesize_dataset)
from repro.ckpt import CheckpointManager, restore_checkpoint
from repro.data import DataPipeline, ShardedTokenSource
from repro.train import OptConfig, init_train_state, make_train_step


def test_paper_workflow_end_to_end(tmp_path):
    """Fig. 3 loop: archive -> query -> job array -> containerized run ->
    derivatives + provenance -> cold archival -> idempotent re-query."""
    ds = synthesize_dataset(tmp_path / "archive", "MASIVar-mini",
                            n_subjects=2, sessions_per_subject=2,
                            shape=(12, 12, 12))
    store = TieredStore(tmp_path / "tiers")
    pipes = builtin_pipelines()

    for name in ("bias_correct", "segment_unest"):
        pipe = pipes[name]
        plan = generate_jobs(ds, pipe, tmp_path / "jobs" / name)
        assert Path(plan.slurm_script).exists()
        results = LocalRunner(pipe, ds.root).run(plan.units)
        assert all(r.status in ("ok", "skipped") for r in results)

    # derivatives exist in BIDS-style layout with provenance
    deriv = Path(ds.root) / "derivatives" / "bias_correct"
    outs = list(deriv.rglob("*_T1w_biascorr.npy"))
    assert len(outs) == 4
    provs = list(deriv.rglob("provenance.json"))
    assert len(provs) == 4
    prov = json.loads(provs[0].read_text())
    assert prov["pipeline_digest"] == pipes["bias_correct"].digest()

    # nightly archival of one derivative to the cold tier
    store.put(outs[0], f"derivatives/{outs[0].name}", tier="hot")
    store.archive_to_cold(f"derivatives/{outs[0].name}")
    assert store.exists(f"derivatives/{outs[0].name}", tier="cold")

    # idempotency across both pipelines
    for name in ("bias_correct", "segment_unest"):
        work, _ = query_available_work(ds, pipes[name])
        assert work == []


def test_train_restart_end_to_end(tmp_path):
    """Train a tiny LM from the sharded data pipeline, checkpoint async,
    'crash', restore, and verify continuation equals the uninterrupted run."""
    cfg = get_config("llama3.2-1b").reduced(n_layers=2, vocab_size=256)
    src = ShardedTokenSource.synthesize(tmp_path / "data", n_shards=2,
                                        tokens_per_shard=8192, vocab_size=256)
    pipe = DataPipeline(src, batch=2, seq_len=64, seed=0)
    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3)))

    # uninterrupted run: 4 steps
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    losses_ref = []
    for s in range(4):
        params, opt, m = step_fn(params, opt, pipe.batch_at(s))
        losses_ref.append(float(m["loss"]))

    # interrupted run: 2 steps, checkpoint, restart, 2 more
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path / "ckpt", keep=2)
    for s in range(2):
        params, opt, m = step_fn(params, opt, pipe.batch_at(s))
    mgr.save_async(2, {"params": params, "opt": opt})
    mgr.wait()
    tmpl = jax.eval_shape(lambda: {
        "params": init_train_state(cfg, jax.random.PRNGKey(0))[0],
        "opt": init_train_state(cfg, jax.random.PRNGKey(0))[1]})
    restored, step, _ = restore_checkpoint(tmp_path / "ckpt", tmpl)
    params = jax.tree.map(jnp.asarray, restored["params"])
    opt = jax.tree.map(jnp.asarray, restored["opt"])
    losses_resumed = []
    for s in range(step, 4):
        params, opt, m = step_fn(params, opt, pipe.batch_at(s))
        losses_resumed.append(float(m["loss"]))
    assert np.allclose(losses_resumed, losses_ref[2:], rtol=1e-5), \
        (losses_resumed, losses_ref[2:])
