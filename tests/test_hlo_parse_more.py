"""Loop-aware HLO accounting: nested loops, f32 adjustment, breakdown tool."""
from repro.analysis.hlo_parse import HloCosts, loop_trip_summary


NESTED = """
inner_cond {
  t = s32[] constant(4)
  ROOT lt = pred[] compare(i, t), direction=LT
}

inner_body {
  ar = bf16[1000] all-gather(x), dimensions={0}
  ROOT out = (s32[]) tuple(i)
}

outer_cond {
  t = s32[] constant(8)
  ROOT lt = pred[] compare(i, t), direction=LT
}

outer_body {
  w = (s32[]) while(init), condition=inner_cond, body=inner_body
  ar2 = f32[500] all-reduce(y), to_apply=add
  ROOT out = (s32[]) tuple(i)
}

ENTRY main {
  w = (s32[]) while(init), condition=outer_cond, body=outer_body
  ROOT r = s32[] get-tuple-element(w), index=0
}
"""


def test_nested_loop_multiplication():
    c = HloCosts(NESTED).collective_bytes()
    # inner all-gather: 8 outer x 4 inner x 1000 bf16 = 64000 bytes
    assert c["per_op"]["all-gather"] == 8 * 4 * 1000 * 2
    # outer all-reduce: 8 x 500 f32
    assert c["per_op"]["all-reduce"] == 8 * 500 * 4
    # weighted: AR x2
    assert c["weighted_bytes"] == 64000 + 2 * 8 * 500 * 4
    # f32 adjustment halves only the f32 share
    assert c["tpu_bf16_adjusted_bytes"] == c["weighted_bytes"] - (2 * 8 * 500 * 4) // 2


def test_loop_trip_summary():
    trips = dict(loop_trip_summary(NESTED))
    assert trips["inner_body"] == 4
    assert trips["outer_body"] == 8


def test_collective_breakdown_orders_by_total():
    from repro.analysis.report import collective_breakdown
    rows = collective_breakdown(NESTED)
    assert rows[0]["total"] >= rows[-1]["total"]
    ops = {r["op"] for r in rows}
    assert "all-gather" in ops and "all-reduce" in ops
