"""Fault-injection proxy + chaos runs of the cluster invariant: the proxy's
own passthrough/fault/partition behaviour, then the full harness under
coordinator kill-and-recover and injected network weather — both rpc
framings."""
import threading
import time

import pytest

from repro.core import builtin_pipelines, query_available_work, \
    synthesize_dataset
from repro.dist import ChaosProxy, QueueClient, QueueServer, WorkQueue


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path / "ds", "chds", n_subjects=4,
                              sessions_per_subject=2, shape=(10, 10, 10))


def _queue(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    return WorkQueue(units, ["a"])


# ---------------------------------------------------------------------------
# the proxy itself
# ---------------------------------------------------------------------------

def test_proxy_is_transparent_by_default(dataset):
    q = _queue(dataset)
    with QueueServer(q) as srv, ChaosProxy(srv.address) as px:
        c = QueueClient(px.address)
        assert c.finished() is False
        unit, lease = c.next_unit("a")
        c.complete(lease.unit_idx, "a", "ok")
        assert c.done_status()[lease.unit_idx] == "ok"
        c.close()
        st = px.stats()
        assert st["conns"] == 1 and st["chunks"] > 0
        assert st["dropped"] == st["duplicated"] == st["truncated"] == 0


def test_client_survives_drops_dups_and_truncates(dataset):
    q = _queue(dataset)
    with QueueServer(q) as srv, \
            ChaosProxy(srv.address, seed=7, drop_rate=0.10, dup_rate=0.05,
                       truncate_rate=0.05, delay_rate=0.10,
                       delay_s=0.005) as px:
        c = QueueClient(px.address, timeout_s=1.0, reconnect_window_s=60.0)
        for _ in range(40):
            c.pending()                  # every call must come back correct
        assert c.pending() == len(q.units)
        c.close()
        st = px.stats()
        assert st["dropped"] + st["duplicated"] + st["truncated"] > 0, \
            f"weather never fired: {st}"


def test_close_mid_frame_forces_clean_redial(dataset):
    q = _queue(dataset)
    # truncate-only weather: every fault is a connection torn mid-frame
    with QueueServer(q) as srv, \
            ChaosProxy(srv.address, seed=3, truncate_rate=0.2) as px:
        c = QueueClient(px.address, timeout_s=1.0, reconnect_window_s=60.0)
        for _ in range(30):
            assert c.finished() is False
        c.close()
        st = px.stats()
        assert st["truncated"] > 0 and st["conns"] > 1


def test_partition_stalls_then_heals(dataset):
    q = _queue(dataset)
    with QueueServer(q) as srv, ChaosProxy(srv.address) as px:
        c = QueueClient(px.address, timeout_s=1.0, reconnect_window_s=60.0)
        assert c.finished() is False
        px.partition(True)
        res = {}

        def call():
            res["pending"] = c.pending()
        t = threading.Thread(target=call, daemon=True)
        t.start()
        time.sleep(0.3)
        assert "pending" not in res      # the network is gone, not erroring
        px.partition(False)
        t.join(timeout=30)
        assert res.get("pending") == len(q.units)
        c.close()


def test_proxy_stop_is_idempotent(dataset):
    q = _queue(dataset)
    with QueueServer(q) as srv:
        px = ChaosProxy(srv.address).start()
        c = QueueClient(px.address)
        assert c.finished() is False
        px.stop()
        px.stop()
        c.close()


def test_proxy_refuses_nothing_when_upstream_is_down(dataset):
    """Upstream dead (mid-restart): the proxy closes the client connection
    instead of hanging it, so the client's reconnect loop keeps driving."""
    q = _queue(dataset)
    srv = QueueServer(q).start()
    addr = srv.address
    with ChaosProxy(addr) as px:
        c = QueueClient(px.address, timeout_s=1.0, reconnect=False)
        assert c.finished() is False
        srv.crash()
        with pytest.raises(ConnectionError):
            for _ in range(3):
                c.pending()
        c.close()


# ---------------------------------------------------------------------------
# the invariant under chaos: kill + recover the coordinator, mangle the wire
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framing", ["binary", "json"])
def test_cluster_invariant_survives_coordinator_restart(framing):
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(5, 2, 3, True, 1, transport="rpc",
                            harass_coordinator=True, framing=framing)


def test_cluster_invariant_survives_network_chaos():
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(4, 2, 3, True, 1, transport="rpc",
                            netchaos=True)


def test_cluster_invariant_survives_restart_under_network_chaos():
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(4, 2, 3, False, 0, transport="rpc",
                            harass_coordinator=True, netchaos=True)
