"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.rwkv6 import wkv6_chunked, wkv6_ref
from repro.kernels.mamba2_ssd import ssd_chunked, ssd_ref
from repro.kernels.checksum import device_checksum, device_checksum_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,KV,S,D", [
    (2, 4, 2, 256, 64), (1, 8, 8, 128, 32), (2, 4, 1, 200, 64),
    (1, 2, 2, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, KV, S, D, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    err = np.max(np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32)))
    assert err < tol, err


def test_flash_attention_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64))
    k = jax.random.normal(ks[1], (2, 2, 256, 64))
    v = jax.random.normal(ks[2], (2, 2, 256, 64))
    out = flash_attention(q, k, v, causal=True, window=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=64)
    assert np.max(np.abs(np.asarray(out) - np.asarray(ref))) < 2e-5


@pytest.mark.parametrize("shape", [(8, 64, 128), (3, 100), (512, 256), (1, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jnp.abs(jax.random.normal(KEY, shape[-1:], jnp.float32)) + 0.5
    out = rmsnorm(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    err = np.max(np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32)))
    assert err < tol


@pytest.mark.parametrize("B,H,S,dh,chunk", [
    (2, 3, 96, 32, 32), (1, 2, 128, 64, 128), (2, 2, 200, 16, 64),
])
def test_wkv6_kernel_vs_exact(B, H, S, dh, chunk):
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, dh)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, dh)) * 0.5 - 2)
    u = jax.random.normal(ks[4], (H, dh)) * 0.3
    out = wkv6_chunked(r, k, v, logw, u, chunk=chunk, interpret=True)
    ref = wkv6_ref(r, k, v, logw, u)
    rel = np.max(np.abs(np.asarray(out) - np.asarray(ref))) / \
        max(1.0, float(np.max(np.abs(np.asarray(ref)))))
    assert rel < 1e-4


@pytest.mark.parametrize("B,H,S,dh,N,chunk", [
    (2, 3, 96, 32, 16, 32), (1, 2, 128, 64, 64, 128), (2, 2, 200, 32, 64, 64),
])
def test_ssd_kernel_vs_exact(B, H, S, dh, N, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, H, S, dh))
    lw = -jnp.abs(jax.random.normal(ks[1], (B, H, S))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    out = ssd_chunked(x, lw, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref(x, lw, Bm, Cm)
    rel = np.max(np.abs(np.asarray(out) - np.asarray(ref))) / \
        max(1.0, float(np.max(np.abs(np.asarray(ref)))))
    assert rel < 1e-4


@pytest.mark.parametrize("shape,dtype", [
    ((1000,), jnp.float32), ((33, 7), jnp.bfloat16), ((5,), jnp.int32),
    ((4096,), jnp.float32), ((1,), jnp.float32),
])
def test_device_checksum_bit_exact(shape, dtype):
    if dtype == jnp.int32:
        x = jax.random.randint(KEY, shape, -1000, 1000)
    else:
        x = (jax.random.normal(KEY, shape, jnp.float32) * 100).astype(dtype)
    got = np.asarray(device_checksum(x, interpret=True))
    ref = device_checksum_ref(np.asarray(x))
    assert np.array_equal(got, ref)


def test_device_checksum_detects_corruption():
    x = jax.random.normal(KEY, (256,))
    a = np.asarray(device_checksum(x, interpret=True))
    xc = np.asarray(x).copy()
    xc[17] += 1e-3
    b = np.asarray(device_checksum(jnp.asarray(xc), interpret=True))
    assert not np.array_equal(a, b)


def test_model_chunked_paths_match_kernel_oracles():
    """The model stack's XLA chunked implementations agree with the same
    oracles the kernels are validated against (triangulation)."""
    from repro.models.rwkv6 import wkv_chunked
    ks = jax.random.split(KEY, 5)
    B, H, S, dh = 2, 2, 64, 16
    r, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dh)) * 0.5 - 2)
    u = jax.random.normal(ks[4], (H, dh)) * 0.3
    out, _ = wkv_chunked(r, k, v, logw, u, jnp.zeros((B, H, dh, dh)), 32)
    # oracle layout (B,H,S,dh)
    tr = lambda a: jnp.transpose(a, (0, 2, 1, 3))
    ref = wkv6_ref(tr(r), tr(k), tr(v), tr(logw), u)
    rel = np.max(np.abs(np.asarray(tr(out)) - np.asarray(ref))) / \
        max(1.0, float(np.max(np.abs(np.asarray(ref)))))
    assert rel < 1e-4
