"""Coordinator write-ahead journal: WAL framing + torn-tail repair,
snapshot/compaction crash windows, WorkQueue journal->recover equivalence
(including DAG gates, dead nodes, and epoch fencing across the restart),
the stale-lease double-commit regression, version-skew interop in both
directions, and the read-only inspect CLI."""
import json
import subprocess
import sys
import time
import zlib
from pathlib import Path

import pytest

from conftest import wait_until

from repro.core import builtin_pipelines, query_available_work, \
    synthesize_dataset
from repro.dist import Journal, JournalCorrupt, WorkQueue
from repro.dist.journal import _HEADER, _MAGIC

REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path / "ds", "jds", n_subjects=4,
                              sessions_per_subject=2, shape=(10, 10, 10))


def _work(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    return pipe, units


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------

def test_wal_roundtrip_assigns_monotonic_seq(tmp_path):
    j = Journal(tmp_path / "j", fsync="never")
    for i in range(5):
        j.append({"t": "grant", "i": i, "n": "a", "e": 1, "lb": 0})
    j.close()
    records, torn, reason = Journal(tmp_path / "j").scan_wal()
    assert [r["i"] for r in records] == list(range(5))
    assert [r["q"] for r in records] == [1, 2, 3, 4, 5]
    assert torn == 0 and reason is None


def test_torn_payload_is_truncated_on_replay(tmp_path):
    j = Journal(tmp_path / "j", fsync="never")
    j.write_units([])
    for i in range(3):
        j.append({"t": "dead", "n": f"node-{i}"})
    j.close()
    wal = tmp_path / "j" / "wal.log"
    # a record the crash cut short: honest header, half a payload
    body = json.dumps({"t": "dead", "n": "node-torn", "q": 4}).encode()
    with open(wal, "ab") as f:
        f.write(len(body).to_bytes(4, "big")
                + zlib.crc32(body).to_bytes(4, "big") + body[: len(body) // 2])
    before = wal.stat().st_size
    j2 = Journal(tmp_path / "j")
    rows, state, tail, torn = j2.replay()
    assert [r["n"] for r in tail] == ["node-0", "node-1", "node-2"]
    assert torn == _HEADER + len(body) // 2
    assert wal.stat().st_size == before - torn        # tail physically cut
    # the journal keeps appending after the repair, seq continuing
    j2.append({"t": "dead", "n": "node-3"})
    j2.close()
    records, torn, _ = Journal(tmp_path / "j").scan_wal()
    assert [r["n"] for r in records][-1] == "node-3"
    assert records[-1]["q"] == 4 and torn == 0


def test_crc_mismatch_ends_the_trusted_prefix(tmp_path):
    j = Journal(tmp_path / "j", fsync="never")
    for i in range(4):
        j.append({"t": "dead", "n": f"node-{i}"})
    j.close()
    wal = tmp_path / "j" / "wal.log"
    data = bytearray(wal.read_bytes())
    # flip one payload byte of the third record: records 0-1 stay good
    off = len(_MAGIC)
    for _ in range(2):
        n = int.from_bytes(data[off:off + 4], "big")
        off += _HEADER + n
    data[off + _HEADER + 4] ^= 0xFF
    wal.write_bytes(bytes(data))
    records, torn, reason = Journal(tmp_path / "j").scan_wal()
    assert [r["n"] for r in records] == ["node-0", "node-1"]
    assert reason == "crc mismatch" and torn > 0


def test_bad_magic_is_corrupt_not_torn(tmp_path):
    j = Journal(tmp_path / "j", fsync="never")
    j.append({"t": "dead", "n": "a"})
    j.close()
    wal = tmp_path / "j" / "wal.log"
    wal.write_bytes(b"NOTAWAL0" + wal.read_bytes()[len(_MAGIC):])
    with pytest.raises(JournalCorrupt, match="bad magic"):
        Journal(tmp_path / "j").scan_wal()


def test_oversize_length_field_ends_prefix(tmp_path):
    from repro.dist.journal import MAX_RECORD_BYTES
    j = Journal(tmp_path / "j", fsync="never")
    j.append({"t": "dead", "n": "a"})
    j.close()
    wal = tmp_path / "j" / "wal.log"
    with open(wal, "ab") as f:
        f.write((MAX_RECORD_BYTES + 1).to_bytes(4, "big") + b"\0\0\0\0junk")
    records, torn, reason = Journal(tmp_path / "j").scan_wal()
    assert len(records) == 1 and "exceeds cap" in reason


def test_fsync_policies(tmp_path):
    for policy in ("always", "interval", "never"):
        j = Journal(tmp_path / policy, fsync=policy)
        j.append({"t": "dead", "n": "a"})
        j.close()
        records, _, _ = Journal(tmp_path / policy).scan_wal()
        assert len(records) == 1
    with pytest.raises(ValueError, match="unknown fsync policy"):
        Journal(tmp_path / "bad", fsync="sometimes")


def test_closed_journal_drops_appends_silently(tmp_path):
    """The zombie fence: a dead incarnation's queue keeps calling append()
    harmlessly while the new incarnation owns the files."""
    j = Journal(tmp_path / "j", fsync="never")
    j.append({"t": "dead", "n": "a"})
    j.close()
    j.append({"t": "dead", "n": "zombie"})     # no error, no write
    j.close()                                   # idempotent
    records, _, _ = Journal(tmp_path / "j").scan_wal()
    assert [r["n"] for r in records] == ["a"]


# ---------------------------------------------------------------------------
# snapshot + compaction crash windows
# ---------------------------------------------------------------------------

def test_replay_skips_records_covered_by_snapshot(tmp_path):
    """The rename-before-truncate crash window: a snapshot at seq N with the
    old WAL still on disk must not double-apply records q <= N."""
    j = Journal(tmp_path / "j", fsync="never")
    j.write_units([])
    for i in range(3):
        j.append({"t": "dead", "n": f"node-{i}"})
    pre_truncate_wal = (tmp_path / "j" / "wal.log").read_bytes()
    j.compact({"nodes": [], "dead": [f"node-{i}" for i in range(3)]})
    j.append({"t": "dead", "n": "node-after"})
    j.close()
    # resurrect the pre-compaction records in front of the post-compaction
    # one — exactly what a crash between state.json rename and WAL truncate
    # leaves behind
    wal = tmp_path / "j" / "wal.log"
    post = wal.read_bytes()[len(_MAGIC):]
    wal.write_bytes(pre_truncate_wal + post)
    rows, state, tail, torn = Journal(tmp_path / "j").replay()
    assert state["seq"] == 3 and state["v"] == 1
    assert [r["n"] for r in tail] == ["node-after"]   # q 1..3 skipped
    assert torn == 0


def test_compaction_continues_seq_across_snapshots(tmp_path):
    j = Journal(tmp_path / "j", fsync="never")
    j.write_units([])
    j.append({"t": "dead", "n": "a"})
    j.compact({})
    j.append({"t": "dead", "n": "b"})
    j.close()
    rows, state, tail, _ = Journal(tmp_path / "j").replay()
    assert state["seq"] == 1
    assert [(r["n"], r["q"]) for r in tail] == [("b", 2)]


def test_should_compact_threshold(tmp_path):
    j = Journal(tmp_path / "j", fsync="never", compact_every=3)
    assert not j.should_compact()
    for _ in range(3):
        j.append({"t": "dead", "n": "a"})
    assert j.should_compact()
    j.compact({})
    assert not j.should_compact()
    j.close()


def test_replay_without_units_is_corrupt(tmp_path):
    (tmp_path / "j").mkdir()
    with pytest.raises(JournalCorrupt, match="no units.json"):
        Journal(tmp_path / "j").replay()


# ---------------------------------------------------------------------------
# WorkQueue journal -> recover equivalence
# ---------------------------------------------------------------------------

def _drive(queue):
    """A deterministic little history: grants, ok/failed completes, a dead
    node with an orphaned lease. Returns (ok_idxs, failed_idx, orphan_idx)."""
    assert queue.register("a") and queue.register("b")
    ua, la = queue.next_unit("a")
    ub, lb = queue.next_unit("b")
    queue.complete(la.unit_idx, "a", "ok", meta={"seconds": 0.1,
                                                 "status": "ok"})
    queue.complete(lb.unit_idx, "b", "failed")
    u2, l2 = queue.next_unit("a")
    queue.complete(l2.unit_idx, "a", "ok")
    uo, lo = queue.next_unit("b")        # orphaned: b dies holding it
    queue.mark_dead("b")
    return ([la.unit_idx, l2.unit_idx], lb.unit_idx, lo.unit_idx)


def test_recover_rebuilds_queue_state(dataset, tmp_path):
    pipe, units = _work(dataset)
    q = WorkQueue(units, (), lease_ttl_s=5.0,
                  journal=Journal(tmp_path / "j", fsync="never"))
    ok_idxs, failed_idx, orphan_idx = _drive(q)

    q2 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=5.0)
    assert q2.done_status() == q.done_status()
    assert set(q2.alive_nodes()) == {"a"}
    assert q2.pending() == q.pending()
    # the dead node's orphaned lease was requeued at mark_dead time (the
    # record replays), so the orphan is grantable again — at a higher epoch
    snap = q2.results_snapshot()
    assert snap["primaries"][ok_idxs[0]]["node_id"] == "a"
    grants = {}
    while True:
        got = q2.next_unit("a")
        if got is None:
            break
        unit, lease = got
        grants[lease.unit_idx] = lease
    assert orphan_idx in grants
    # terminal statuses stay terminal: the failed unit (node-side retries
    # already exhausted) and the oks are never re-granted
    assert q2.done_status()[failed_idx] == "failed"
    for i in [failed_idx, *ok_idxs]:
        assert i not in grants


def test_recover_fences_pre_crash_epochs(dataset, tmp_path):
    """A lease epoch granted before the crash must never be re-issued
    after it: the zombie's renew is rejected, its complete is a dup."""
    pipe, units = _work(dataset)
    q = WorkQueue(units, (), lease_ttl_s=0.3,
                  journal=Journal(tmp_path / "j", fsync="never"))
    assert q.register("a")
    _, lease = q.next_unit("a")
    q2 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=0.3)
    # "a" never reconnects: one ttl of grace, then the reaper collects it
    wait_until(lambda: q2.reap() or "a" not in q2.alive_nodes(), timeout=5)
    assert q2.register("b")
    unit2, lease2 = q2.next_unit("b")
    # b may be handed a different unit first; drain until the orphan shows
    while lease2.unit_idx != lease.unit_idx:
        q2.complete(lease2.unit_idx, "b", "ok")
        unit2, lease2 = q2.next_unit("b")
    assert lease2.epoch > lease.epoch
    assert q2.renew(lease.unit_idx, "a", lease.epoch) is False


def test_recover_releases_dag_children_of_pre_crash_parents(dataset,
                                                            tmp_path):
    pipe, units = _work(dataset)
    units[2].depends_on = [units[0].job_id]
    units[3].depends_on = [units[2].job_id]
    q = WorkQueue(units, (), lease_ttl_s=5.0,
                  journal=Journal(tmp_path / "j", fsync="never"))
    assert q.register("a")
    got = q.next_unit("a")
    while got[1].unit_idx != 0:
        q.complete(got[1].unit_idx, "a", "ok")
        got = q.next_unit("a")
    q.complete(0, "a", "ok")             # releases unit 2, not yet unit 3

    q2 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=5.0)
    assert q2.register("b")
    grantable = set()
    while (got := q2.next_unit("b")) is not None:
        grantable.add(got[1].unit_idx)
    assert 2 in grantable                # parent's ok survived the crash
    assert 3 not in grantable            # still parked behind unit 2
    q2.complete(2, "b", "ok")
    unit3 = q2.next_unit("b")
    assert unit3 is not None and unit3[1].unit_idx == 3


def test_expired_lease_is_not_resurrected_by_recovery(dataset, tmp_path):
    """reap()'s per-lease expiry (the lost-grant case) journals an expire
    record: a recovered coordinator must see the unit as grantable, not as
    still leased to the node that never learned of it."""
    pipe, units = _work(dataset)
    t = {"now": 0.0}
    q = WorkQueue(units, (), lease_ttl_s=1.0, now=lambda: t["now"],
                  journal=Journal(tmp_path / "j", fsync="never"))
    assert q.register("a")
    _, lease = q.next_unit("a")
    t["now"] = 1.1
    q.heartbeat("a")                         # the holder stays alive...
    assert q.reap() == [lease.unit_idx]      # ...the orphan lease expires

    q2 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=60.0)
    grants = {}
    while (got := q2.next_unit("a")) is not None:
        grants[got[1].unit_idx] = got[1]
    # grantable immediately — no 60s reap wait — and fenced above the lost
    # lease's epoch
    assert lease.unit_idx in grants
    assert grants[lease.unit_idx].epoch > lease.epoch


def test_cluster_runner_refuses_to_overwrite_existing_journal(dataset,
                                                              tmp_path):
    """A leftover journal is a crashed run's only recoverable state:
    run() must refuse it (and leave it intact) unless told to discard."""
    from repro.dist import ClusterRunner
    pipe, units = _work(dataset)
    jdir = tmp_path / "j"
    q = WorkQueue(units, (), journal=Journal(jdir, fsync="never"))
    assert q.register("a")
    _, lease = q.next_unit("a")
    q.complete(lease.unit_idx, "a", "ok")    # durable history worth keeping

    runner = ClusterRunner(pipe, dataset.root, nodes=1, journal_dir=jdir)
    with pytest.raises(RuntimeError, match="already holds"):
        runner.run(units)
    # the refusal destroyed nothing: the journal still recovers
    q2 = WorkQueue.recover(Journal(jdir, fsync="never"))
    assert q2.done_status() == {lease.unit_idx: "ok"}

    results = ClusterRunner(pipe, dataset.root, nodes=1, journal_dir=jdir,
                            journal_overwrite=True).run(units)
    assert sum(r.status == "ok" for r in results) == len(units)


def test_double_recover_is_idempotent(dataset, tmp_path):
    pipe, units = _work(dataset)
    q = WorkQueue(units, (), lease_ttl_s=5.0,
                  journal=Journal(tmp_path / "j", fsync="never"))
    _drive(q)
    q2 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=5.0)
    q3 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=5.0)
    assert q3.done_status() == q2.done_status()
    assert q3.pending() == q2.pending()
    assert set(q3.alive_nodes()) == set(q2.alive_nodes())
    assert q3.results_snapshot() == q2.results_snapshot()


def test_recovered_queue_journals_onward(dataset, tmp_path):
    """Recovery attaches the journal and compacts immediately, so the new
    incarnation's own mutations are durable for the *next* recovery."""
    pipe, units = _work(dataset)
    q = WorkQueue(units, (), lease_ttl_s=5.0,
                  journal=Journal(tmp_path / "j", fsync="never"))
    assert q.register("a")
    _, lease = q.next_unit("a")
    q.complete(lease.unit_idx, "a", "ok")
    q2 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=5.0)
    _, l2 = q2.next_unit("a")
    q2.complete(l2.unit_idx, "a", "ok")
    q3 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=5.0)
    assert q3.done_status() == {lease.unit_idx: "ok", l2.unit_idx: "ok"}


# ---------------------------------------------------------------------------
# the stale-lease double-commit regression (satellite): a worker that held
# a live lease across a coordinator restart must not be able to double-commit
# ---------------------------------------------------------------------------

def test_stale_lease_across_restart_cannot_double_commit(dataset, tmp_path):
    pipe, units = _work(dataset)
    q = WorkQueue(units, (), lease_ttl_s=0.3,
                  journal=Journal(tmp_path / "j", fsync="never"))
    assert q.register("a")
    unit, lease_a = q.next_unit("a")
    idx = lease_a.unit_idx

    # coordinator dies and recovers; a's lease rides along with one ttl of
    # grace, but a never heartbeats the new incarnation
    q2 = WorkQueue.recover(Journal(tmp_path / "j", fsync="never"),
                           lease_ttl_s=0.3)
    wait_until(lambda: q2.reap() or "a" not in q2.alive_nodes(), timeout=5)
    assert q2.register("b")
    got = q2.next_unit("b")
    while got[1].unit_idx != idx:
        q2.complete(got[1].unit_idx, "b", "ok")
        got = q2.next_unit("b")
    q2.complete(idx, "b", "ok", meta={"status": "ok", "seconds": 0.1})

    # a finally wakes up and reports its (stale) success
    q2.complete(idx, "a", "ok", meta={"status": "ok", "seconds": 9.9})

    assert q2.done_status()[idx] == "ok"
    snap = q2.results_snapshot()
    assert snap["primaries"][idx]["node_id"] == "b"   # exactly one winner
    dup_nodes = [m["node_id"] for m in snap["duplicates"] if m["idx"] == idx]
    assert dup_nodes == ["a"]                         # the zombie is a dup


# ---------------------------------------------------------------------------
# version-skew interop: journal-disabled coordinators stay first-class
# ---------------------------------------------------------------------------

def test_journal_disabled_queue_unchanged(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ("a",))
    u, lease = q.next_unit("a")
    q.complete(lease.unit_idx, "a", "ok")
    assert q.done_status()[lease.unit_idx] == "ok"
    assert q._journal is None


def test_recover_requires_a_journal_directory(tmp_path):
    with pytest.raises(JournalCorrupt):
        WorkQueue.recover(Journal(tmp_path / "empty"))


# ---------------------------------------------------------------------------
# inspect CLI
# ---------------------------------------------------------------------------

def _inspect(path):
    return subprocess.run(
        [sys.executable, "-m", "repro.dist.journal", "inspect", str(path)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"})


def test_inspect_cli_reports_replay_summary(dataset, tmp_path):
    pipe, units = _work(dataset)
    q = WorkQueue(units, (), lease_ttl_s=5.0,
                  journal=Journal(tmp_path / "j", fsync="never"))
    _drive(q)
    wal = tmp_path / "j" / "wal.log"
    size_before = wal.stat().st_size
    wal.write_bytes(wal.read_bytes() + b"\x00\x00")   # torn header bytes
    proc = _inspect(tmp_path / "j")
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout
    assert f"units           : {len(units)}" in out
    assert "complete=" in out and "grant=" in out
    assert "torn tail       : 2 byte(s)" in out
    assert "ok=2" in out and "failed=1" in out
    # read-only: inspect never repairs the file
    assert wal.stat().st_size == size_before + 2


def test_inspect_cli_exit_codes(tmp_path):
    (tmp_path / "notajournal").mkdir()
    assert _inspect(tmp_path / "notajournal").returncode == 2
    j = Journal(tmp_path / "j", fsync="never")
    j.write_units([])
    j.close()
    (tmp_path / "j" / "units.json").write_text("{not json")
    proc = _inspect(tmp_path / "j")
    assert proc.returncode == 1 and "CORRUPT" in proc.stdout
