"""Shared body of the distributed-executor safety invariant, used by the
hypothesis property test (random parameters), a deterministic sweep in
``test_cluster.py`` (so the invariant still runs where hypothesis is absent),
and the transport/cache/renewal variants in ``test_rpc.py``.
"""
import itertools
import json
import tempfile
import threading
import time
from pathlib import Path

import numpy as np


def dag_closure(edges, start):
    """Transitive descendants of ``start`` under ``{child: [parents]}``."""
    out, frontier = set(), {start}
    while frontier:
        frontier = {c for c, ps in edges.items()
                    if c not in out and frontier.intersection(ps)}
        out |= frontier
    return out


def check_cluster_invariant(n_subjects: int, sessions: int, nodes: int,
                            flaky: bool, die: int, *,
                            transport: str = "local", cache: bool = False,
                            harass_renew: bool = False,
                            harass_locality: bool = False,
                            harass_peers: bool = False,
                            harass_coordinator: bool = False,
                            netchaos: bool = False,
                            framing: str = "binary",
                            dag_edges=None, fail_idx=None):
    """For the given unit list / node count / injected failures: every unit
    must end with exactly one committed ok provenance, and a concurrent
    reader must never observe a partial output file or torn provenance.

    ``transport="rpc"`` runs the same schedule over the socket transport;
    ``cache=True`` serves inputs through a host :class:`InputCache`;
    ``harass_renew=True`` floods the queue with renewals carrying cycling
    (mostly stale) epochs while the run is live — a renewal racing a reap or
    a re-grant must be rejected without ever disturbing retirement.
    ``harass_locality=True`` runs locality-aware placement over per-node
    caches while a thread floods the queue with hostile digest summaries —
    wrong versions, garbage wires, random digests, ghost and dead node ids.
    Summaries only ever shape placement *scores*, so no summary content may
    break retirement, ok-counts, or commit atomicity.
    ``harass_peers=True`` runs the peer blob fabric (per-node caches +
    BlobServers) under hostile conditions: ghost nodes advertising dead
    blob addresses with claim-everything summaries (guaranteed routing at
    unreachable peers), blob bodies corrupted on disk mid-run (digest
    mismatch on serve, verified-miss on local hit), summaries flooded with
    false-positive digests (peer 404s), and — via ``die``+``nodes>1`` —
    serving nodes killed mid-run. Every peer-path failure must fall back to
    shared storage: exactly one ok provenance per unit, and the committed
    input digests byte-identical to the manifest regardless of which link
    the bytes crossed.

    ``dag_edges`` (``{child_pos: [parent_pos, ...]}`` over the queried unit
    list, parents strictly smaller so the topology is acyclic by
    construction) attaches ``depends_on`` edges before the run and extends
    the invariant to DAGs: still exactly one ok provenance per runnable
    unit, and additionally *no child provenance timestamped before its last
    parent's commit* — under the same steal/reap/speculation/harassment
    machinery. ``fail_idx`` makes that unit's fault hook raise on every
    attempt (retries exhaust): the unit must end terminally ``failed``, its
    transitive descendants terminally ``blocked`` — never granted, no
    output files, no provenance — and the blocked count surfaced in
    ``stats_snapshot()['dag']``.

    ``harass_coordinator=True`` (requires ``transport="rpc"``) journals the
    queue and hard-kills + recovers the coordinator mid-run — twice, at
    different progress points — via ``ClusterRunner.restart_coordinator``:
    clients must reconnect and re-register on their own, leases held across
    the kill must resolve through epoch fencing (no double-commit), and the
    run must still end with exactly one ok per unit. ``netchaos=True`` puts
    a :class:`~repro.dist.faults.ChaosProxy` between every client and the
    coordinator (drops, delays, duplicates, close-mid-frame) and asserts
    faults actually fired. ``framing`` pins the rpc wire (``"binary"``
    negotiates frames, ``"json"`` forbids the upgrade) so both framings run
    through the same chaos."""
    from repro.core import (Provenance, builtin_pipelines,
                            query_available_work, synthesize_dataset)
    from repro.dist import ClusterRunner

    with tempfile.TemporaryDirectory() as td:
        ds = synthesize_dataset(Path(td), "prop", n_subjects=n_subjects,
                                sessions_per_subject=sessions, shape=(6, 6, 6),
                                seed=n_subjects * 10 + sessions)
        pipe = builtin_pipelines()["bias_correct"]
        units, _ = query_available_work(ds, pipe)
        deriv = Path(ds.root) / "derivatives"

        # DAG topology: attach depends_on edges over the queried list.
        # Parent positions must be < child position (acyclic by
        # construction); anything out of range is dropped, so hypothesis
        # can draw edges without knowing the exact unit count.
        dag_edges = {c: sorted({p for p in ps if 0 <= p < c})
                     for c, ps in (dag_edges or {}).items()
                     if 0 < c < len(units)}
        dag_edges = {c: ps for c, ps in dag_edges.items() if ps}
        for c, ps in dag_edges.items():
            units[c].depends_on = [units[p].job_id for p in ps]
        if fail_idx is not None and units:
            fail_idx %= len(units)
        fail_job = units[fail_idx].job_id if fail_idx is not None else None
        blocked = dag_closure(dag_edges, fail_idx) \
            if fail_idx is not None else set()
        runnable = [i for i in range(len(units))
                    if i not in blocked and i != fail_idx]

        violations = []
        stop = threading.Event()

        def watcher():
            # any visible output must always be whole: loadable .npy, valid
            # JSON provenance (atomic tmp+rename keeps dot-tmps invisible)
            while not stop.is_set():
                for p in list(deriv.rglob("*")) if deriv.exists() else []:
                    try:
                        if p.name == "provenance.json":
                            json.loads(p.read_text())
                        elif p.suffix == ".npy":
                            np.load(p, allow_pickle=False)
                    except FileNotFoundError:
                        pass               # completed+renamed mid-scan: fine
                    except Exception as e:  # noqa: BLE001
                        violations.append(f"{p}: {type(e).__name__}: {e}")

        def fault(unit, attempt):
            if fail_job is not None and unit.job_id == fail_job:
                raise RuntimeError("permanent injected failure")
            if flaky and attempt == 1:
                raise RuntimeError("transient")

        die_after = {f"node-{die % nodes}": 1} if nodes > 1 else {}
        w = threading.Thread(target=watcher, daemon=True)
        w.start()
        use_cache = cache or harass_locality or harass_peers
        cache_root = Path(td) / "host-cache"
        if harass_coordinator or netchaos:
            assert transport == "rpc", \
                "coordinator/network chaos needs the socket transport"
        client_kwargs = {}
        if framing == "json":
            client_kwargs["binary"] = False
        elif framing != "binary":
            raise ValueError(f"unknown framing {framing!r}")
        if netchaos:
            # dropped chunks stall a call until the socket timeout: keep it
            # short so the reconnect loop (not the test timeout) pays for it
            client_kwargs.update(timeout_s=1.0, reconnect_window_s=60.0)
        if harass_coordinator:
            client_kwargs.setdefault("reconnect_window_s", 60.0)
        proxy_box = {}
        proxy_lock = threading.Lock()

        def client_dial(upstream):
            # one proxy for the whole run, built on first dial (the server
            # address is only known once run() serves); the coordinator
            # restarts on the *same* port, so the upstream stays valid
            with proxy_lock:
                if "proxy" not in proxy_box:
                    from repro.dist.faults import ChaosProxy
                    proxy_box["proxy"] = ChaosProxy(
                        upstream, seed=die * 31 + nodes,
                        drop_rate=0.02, delay_rate=0.05, delay_s=0.01,
                        dup_rate=0.02, truncate_rate=0.02).start()
                return proxy_box["proxy"].address

        runner = ClusterRunner(
            pipe, ds.root, nodes=nodes, fault_hook=fault, die_after=die_after,
            # restart + reconnect take real wall time: chaos modes widen the
            # lease ttl so recovery/stall latency alone never expires a
            # lease (netchaos: a dropped chunk silences a healthy node for
            # a full socket timeout + redial before its next heartbeat)
            lease_ttl_s=(5.0 if netchaos
                         else 1.5 if harass_coordinator else 0.4),
            hb_interval_s=0.1, straggler_factor=100.0,
            poll_s=0.02, transport=transport,
            cache_dir=cache_root if use_cache else None,
            cache_per_node=harass_locality or harass_peers,
            peer_fabric=harass_peers,
            partition="backlog" if harass_locality else "round_robin",
            journal_dir=(Path(td) / "journal") if harass_coordinator else None,
            client_kwargs=client_kwargs or None,
            client_dial=client_dial if netchaos else None)

        wrongly_renewed = []

        def harasser():
            # cycling unit idx / node id, epochs far past any real grant:
            # every renewal is stale (post-epoch-bump) and must be rejected
            # without disturbing leases, heartbeats, or retirement. Failures
            # are collected, not asserted — an assert in a daemon thread
            # would die silently and the test would still pass.
            for i in itertools.count():
                if stop.is_set():
                    return
                q = runner.queue
                if q is not None and units:
                    if q.renew(i % len(units), f"node-{i % nodes}",
                               1000 + (i % 3)):
                        wrongly_renewed.append((i % len(units), 1000 + (i % 3)))

        def locality_harasser():
            # hostile summary traffic: future wire versions, garbage, random
            # digests claimed for real / ghost / soon-dead nodes, and empty
            # deltas — placement scoring may be fooled, retirement must not
            wires = [
                {"v": 999, "full": {"m": 8, "k": 2, "n": 1, "nz": [[0, 1]]}},
                "garbage", {"v": 1}, {"v": 1, "full": "nope"},
                {"v": 1, "add": None, "drop": None},
            ]
            for i in itertools.count():
                if stop.is_set():
                    return
                q = runner.queue
                if q is None:
                    continue
                node = f"node-{i % (nodes + 2)}"     # includes ghost ids
                if i % 3 == 0:
                    # put_summary never refreshes liveness: safe to name
                    # real nodes (including ones about to be reaped)
                    q.put_summary(node, wires[i % len(wires)])
                elif i % 3 == 1:
                    # heartbeat DOES refresh liveness, so only ghost ids —
                    # a harasser impersonating a crashed node's heartbeat
                    # would defeat the reaper by design (fail-stop model:
                    # silence is the one crash signal)
                    q.heartbeat(f"ghost-{i % 5}", summary_delta={
                        "v": 1, "add": [f"bogus-{i % 7}"],
                        "drop": [f"bogus-{(i + 3) % 7}"],
                        "stats": {"hits": i, "misses": -i}})
                else:
                    # stale epochs are rejected before any state is touched,
                    # so real node ids are fair game here
                    q.renew(i % max(1, len(units)), node, 1_000_000,
                            summary_delta={"v": 1, "add": [f"x{i % 5}"],
                                           "drop": []})

        def peer_harasser():
            # hostile peer-fabric traffic, every flavour of lying peer:
            # ghosts advertising unreachable blob addrs with a summary whose
            # every Bloom cell is hot (claims ALL digests -> locate routes
            # fetches at a dead address -> connection error -> storage
            # fallback); real summaries flooded with bogus digests (404s /
            # false positives); and blob bodies corrupted on disk mid-run
            # (digest mismatch when served to a peer, verified-miss when hit
            # locally). None of it may disturb retirement or output bytes.
            claims_everything = {"v": 1, "full": {
                "m": 8, "k": 2, "n": 4, "nz": [[i, 9] for i in range(8)]}}
            for i in itertools.count():
                if stop.is_set():
                    return
                q = runner.queue
                if q is None:
                    continue
                if i % 3 == 0:
                    # ghost peer at a port nothing listens on; it never
                    # heartbeats again, so the reaper collects it in one ttl
                    q.register(f"liar-{i % 4}", summary=claims_everything,
                               blob_addr=f"127.0.0.1:{1 + i % 3}")
                elif i % 3 == 1:
                    q.put_summary(f"node-{i % nodes}", {
                        "v": 1, "add": [f"bogus-{i % 11}"], "drop": [],
                        "stats": {}})
                else:
                    for blob in (list(cache_root.rglob("blobs/*"))
                                 if cache_root.exists() else [])[:2]:
                        if blob.name.startswith("."):
                            continue           # in-flight atomic-write tmps
                        try:
                            blob.write_bytes(b"corrupted mid-run")
                        except OSError:
                            pass               # evicted under us: fine

        coordinator_restarts = []

        def coordinator_harasser():
            # kill + recover the coordinator twice, at different progress
            # points, so recovery is exercised both nearly-cold and
            # mostly-done; a None from restart_coordinator means the run
            # beat us to shutdown — fine, the restart count is asserted
            # only to be >= 1 below
            targets = [max(1, len(units) // 4), max(2, len(units) // 2)]
            for want_done in targets:
                deadline = time.monotonic() + 30
                while not stop.is_set() and time.monotonic() < deadline:
                    q = runner.queue
                    if (q is not None and runner.server is not None
                            and len(q.done_status()) >= want_done):
                        break
                    time.sleep(0.02)
                if stop.is_set():
                    return
                info = runner.restart_coordinator()
                if info is not None:
                    coordinator_restarts.append(info)
                time.sleep(0.3)      # let reconnects land before round two

        threads = []
        if harass_renew:
            threads.append(threading.Thread(target=harasser, daemon=True))
        if harass_coordinator:
            threads.append(threading.Thread(target=coordinator_harasser,
                                            daemon=True))
        if harass_locality:
            threads.append(threading.Thread(target=locality_harasser,
                                            daemon=True))
        if harass_peers:
            threads.append(threading.Thread(target=peer_harasser,
                                            daemon=True))
        for t in threads:
            t.start()
        try:
            results = runner.run(units)
        finally:
            stop.set()
            w.join(timeout=5)
            for t in threads:
                t.join(timeout=5)
            if "proxy" in proxy_box:
                proxy_box["proxy"].stop()
        assert wrongly_renewed == []
        if harass_coordinator:
            # a chaos run that never managed to inject its chaos must fail
            # loudly, not pass greenly
            assert coordinator_restarts, "coordinator was never restarted"
        if netchaos:
            st = proxy_box["proxy"].stats()
            assert st["chunks"] > 0, "no traffic crossed the chaos proxy"
            assert (st["dropped"] + st["delayed"] + st["duplicated"]
                    + st["truncated"]) > 0, f"no faults fired: {st}"

        assert violations == []
        assert sum(r.status == "ok" for r in results) == len(runnable)
        ok_ids = [r.unit.job_id for r in results if r.status == "ok"]
        assert len(ok_ids) == len(set(ok_ids))
        assert set(ok_ids) == {units[i].job_id for i in runnable}
        provs = {}
        for i in runnable:
            u = units[i]
            prov = Provenance.load(Path(u.out_dir))
            assert prov is not None and prov.status == "ok"
            assert prov.pipeline_digest == pipe.digest()
            provs[i] = prov
            if use_cache:
                # committed input digests are byte-identical to the manifest
                # no matter which link (cache / peer / storage) served them
                for suffix, rel in u.inputs.items():
                    want = (u.input_digests or {}).get(suffix)
                    if want:
                        assert prov.inputs[rel] == want
        # DAG ordering: a child's run began only after its last parent's
        # provenance commit was durable (the queue released it at retirement)
        for c, ps in dag_edges.items():
            if c not in provs:
                continue
            for p in ps:
                assert provs[c].started_at >= provs[p].finished_at - 1e-6, \
                    (f"unit {c} started at {provs[c].started_at} before "
                     f"parent {p} committed at {provs[p].finished_at}")
        # failure policy: the poisoned unit ends terminally failed, its
        # descendants terminally blocked — never granted, no output files,
        # no provenance — and the counts surface in the DAG stats
        if fail_idx is not None:
            status_by_id = {}
            for r in results:
                if r.status != "speculative":
                    status_by_id.setdefault(r.unit.job_id, r.status)
            assert status_by_id[fail_job] == "failed"
            fprov = Provenance.load(Path(units[fail_idx].out_dir))
            assert fprov is not None and fprov.status == "failed"
            for b in sorted(blocked):
                bu = units[b]
                assert status_by_id[bu.job_id] == "blocked"
                bdir = Path(bu.out_dir)
                assert Provenance.load(bdir) is None
                assert not bdir.exists() or not any(bdir.iterdir())
        if dag_edges or fail_idx is not None:
            dag_stats = runner.queue.stats_snapshot()["dag"]
            assert dag_stats["cancelled"] == len(blocked)
            assert dag_stats["blocked"] == 0
            assert dag_stats["ready"] == 0
        assert not list(deriv.rglob("*.tmp-*"))      # all commits atomic
        if harass_peers:
            # fallbacks must be visible, not silent: the harasser guaranteed
            # peer failures, yet every unit ended ok — so the storage path
            # carried real bytes and the routing counters were exercised
            assert runner.stats.fabric is not None
            assert (runner.stats.cache or {}).get("bytes_from_storage", 0) > 0
