"""Shared body of the distributed-executor safety invariant, used by the
hypothesis property test (random parameters), a deterministic sweep in
``test_cluster.py`` (so the invariant still runs where hypothesis is absent),
and the transport/cache/renewal variants in ``test_rpc.py``.
"""
import itertools
import json
import tempfile
import threading
from pathlib import Path

import numpy as np


def check_cluster_invariant(n_subjects: int, sessions: int, nodes: int,
                            flaky: bool, die: int, *,
                            transport: str = "local", cache: bool = False,
                            harass_renew: bool = False):
    """For the given unit list / node count / injected failures: every unit
    must end with exactly one committed ok provenance, and a concurrent
    reader must never observe a partial output file or torn provenance.

    ``transport="rpc"`` runs the same schedule over the socket transport;
    ``cache=True`` serves inputs through a host :class:`InputCache`;
    ``harass_renew=True`` floods the queue with renewals carrying cycling
    (mostly stale) epochs while the run is live — a renewal racing a reap or
    a re-grant must be rejected without ever disturbing retirement."""
    from repro.core import (Provenance, builtin_pipelines,
                            query_available_work, synthesize_dataset)
    from repro.dist import ClusterRunner

    with tempfile.TemporaryDirectory() as td:
        ds = synthesize_dataset(Path(td), "prop", n_subjects=n_subjects,
                                sessions_per_subject=sessions, shape=(6, 6, 6),
                                seed=n_subjects * 10 + sessions)
        pipe = builtin_pipelines()["bias_correct"]
        units, _ = query_available_work(ds, pipe)
        deriv = Path(ds.root) / "derivatives"

        violations = []
        stop = threading.Event()

        def watcher():
            # any visible output must always be whole: loadable .npy, valid
            # JSON provenance (atomic tmp+rename keeps dot-tmps invisible)
            while not stop.is_set():
                for p in list(deriv.rglob("*")) if deriv.exists() else []:
                    try:
                        if p.name == "provenance.json":
                            json.loads(p.read_text())
                        elif p.suffix == ".npy":
                            np.load(p, allow_pickle=False)
                    except FileNotFoundError:
                        pass               # completed+renamed mid-scan: fine
                    except Exception as e:  # noqa: BLE001
                        violations.append(f"{p}: {type(e).__name__}: {e}")

        def fault(unit, attempt):
            if flaky and attempt == 1:
                raise RuntimeError("transient")

        die_after = {f"node-{die % nodes}": 1} if nodes > 1 else {}
        w = threading.Thread(target=watcher, daemon=True)
        w.start()
        runner = ClusterRunner(
            pipe, ds.root, nodes=nodes, fault_hook=fault, die_after=die_after,
            lease_ttl_s=0.4, hb_interval_s=0.1, straggler_factor=100.0,
            poll_s=0.02, transport=transport,
            cache_dir=(Path(td) / "host-cache") if cache else None)

        wrongly_renewed = []

        def harasser():
            # cycling unit idx / node id, epochs far past any real grant:
            # every renewal is stale (post-epoch-bump) and must be rejected
            # without disturbing leases, heartbeats, or retirement. Failures
            # are collected, not asserted — an assert in a daemon thread
            # would die silently and the test would still pass.
            for i in itertools.count():
                if stop.is_set():
                    return
                q = runner.queue
                if q is not None and units:
                    if q.renew(i % len(units), f"node-{i % nodes}",
                               1000 + (i % 3)):
                        wrongly_renewed.append((i % len(units), 1000 + (i % 3)))

        h = None
        if harass_renew:
            h = threading.Thread(target=harasser, daemon=True)
            h.start()
        try:
            results = runner.run(units)
        finally:
            stop.set()
            w.join(timeout=5)
            if h is not None:
                h.join(timeout=5)
        assert wrongly_renewed == []

        assert violations == []
        assert sum(r.status == "ok" for r in results) == len(units)
        ok_ids = [r.unit.job_id for r in results if r.status == "ok"]
        assert len(ok_ids) == len(set(ok_ids))
        for u in units:
            prov = Provenance.load(Path(u.out_dir))
            assert prov is not None and prov.status == "ok"
            assert prov.pipeline_digest == pipe.digest()
        assert not list(deriv.rglob("*.tmp-*"))      # all commits atomic
