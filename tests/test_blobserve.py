"""Peer-to-peer blob fabric: server wire format, pinned serving, receiver
re-verification, coordinator routing (locate_blobs/best_peers), every
failure mode's fallback to shared storage, and the end-to-end ClusterRunner
``peer_fabric`` path."""
import hashlib
import io
import json
import socket
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import builtin_pipelines, query_available_work, synthesize_dataset
from repro.core.provenance import Provenance
from repro.core.workflow import load_unit_inputs
from repro.dist import (BlobServer, ClusterRunner, DigestSummary, InputCache,
                        PeerFabric, WorkQueue, best_peers, fetch_blob)
from repro.dist.blobserve import BlobNotFound, advertised_addr, parse_blob_addr


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    return synthesize_dataset(tmp_path_factory.mktemp("ds"), "blobfab",
                              n_subjects=3, sessions_per_subject=2,
                              shape=(6, 6, 6), seed=11)


def _work(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    return pipe, units


def _npy_bytes(arr) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr)
    return buf.getvalue()


def _seed_blob(cache: InputCache, data: bytes) -> str:
    digest = hashlib.sha256(data).hexdigest()
    cache._insert_blob(digest, data, None)
    return digest


# ---------------------------------------------------------------------------
# wire format + server basics
# ---------------------------------------------------------------------------

def test_blob_server_roundtrip_and_404(tmp_path):
    cache = InputCache(tmp_path / "c")
    data = _npy_bytes(np.arange(64, dtype=np.float32))
    digest = _seed_blob(cache, data)
    with BlobServer(cache) as srv:
        assert fetch_blob(srv.addr_str, digest) == data
        with pytest.raises(BlobNotFound):
            fetch_blob(srv.addr_str, "0" * 64)     # Bloom false positive path
    st = cache.stats()
    assert st["peer_serves"] == 1
    assert st["bytes_to_peers"] == len(data)


def test_blob_wire_is_json_header_plus_raw_body(tmp_path):
    """The framing contract docs/cluster.md documents: one JSON line, then
    exactly ``size`` raw bytes — blob bodies never pass through json."""
    cache = InputCache(tmp_path / "c")
    data = _npy_bytes(np.ones(32, dtype=np.float64))
    digest = _seed_blob(cache, data)
    with BlobServer(cache) as srv:
        with socket.create_connection(srv.address) as sock:
            f = sock.makefile("rb")
            sock.sendall(json.dumps({"id": 7, "method": "get",
                                     "digest": digest}).encode() + b"\n")
            head = json.loads(f.readline())
            assert head == {"id": 7, "ok": True, "size": len(data)}
            assert f.read(len(data)) == data
            # connection stays usable: a second request on the same socket
            sock.sendall(json.dumps({"id": 8, "method": "get",
                                     "digest": "nope"}).encode() + b"\n")
            head2 = json.loads(f.readline())
            assert head2["ok"] is False and "not found" in head2["error"]


def test_blob_server_rejects_unknown_method_and_garbage(tmp_path):
    cache = InputCache(tmp_path / "c")
    with BlobServer(cache) as srv:
        with socket.create_connection(srv.address) as sock:
            f = sock.makefile("rb")
            sock.sendall(b'{"id": 1, "method": "evil"}\n')
            assert json.loads(f.readline())["ok"] is False
            sock.sendall(b"not json at all\n")
            assert json.loads(f.readline())["ok"] is False


def test_parse_and_advertised_addr():
    assert parse_blob_addr("host:9") == ("host", 9)
    assert parse_blob_addr(":9") == ("0.0.0.0", 9)
    assert advertised_addr(("10.0.0.2", 7)) == "10.0.0.2:7"
    host = advertised_addr(("0.0.0.0", 7))
    assert host.endswith(":7") and not host.startswith("0.0.0.0")


# ---------------------------------------------------------------------------
# satellite 1: pin/refcount vs eviction
# ---------------------------------------------------------------------------

def test_eviction_never_unlinks_pinned_blob(tmp_path):
    """Regression: a blob held open by a slow reader (local fetch or peer
    serve) must survive eviction pressure — the cache overshoots its budget
    instead of unlinking a file mid-read."""
    data = _npy_bytes(np.zeros(256, dtype=np.float64))
    size = len(data)
    cache = InputCache(tmp_path / "c", max_bytes=int(size * 1.5))
    pinned = _seed_blob(cache, data)
    with cache.hold(pinned) as ok:
        assert ok
        # churn: eviction drops the *unpinned* newcomers, never the pinned LRU
        for i in range(4):
            _seed_blob(cache, _npy_bytes(np.full(256, i + 1, np.float64)))
            assert (cache.blob_dir / pinned).exists()
            assert cache.total_bytes() <= cache.max_bytes
        assert cache.read_blob(pinned) == data   # still byte-identical
        # when every resident blob is pinned, eviction overshoots the byte
        # budget instead of unlinking a file a reader has open
        cache.max_bytes = size // 2
        _seed_blob(cache, _npy_bytes(np.full(256, 77, np.float64)))
        assert (cache.blob_dir / pinned).exists()
        assert cache.total_bytes() > cache.max_bytes   # overshoot, by design
    # pin released: the next insert finally evicts it back under budget
    cache.max_bytes = int(size * 1.5)
    _seed_blob(cache, _npy_bytes(np.full(256, 99, np.float64)))
    assert cache.total_bytes() <= cache.max_bytes
    assert not (cache.blob_dir / pinned).exists()


def test_eviction_racing_slow_reader_thread(tmp_path):
    """The concurrent shape of the regression: a reader thread that pins,
    then dawdles mid-read while eviction churns, always gets whole bytes."""
    data = _npy_bytes(np.arange(512, dtype=np.float64))
    cache = InputCache(tmp_path / "c", max_bytes=int(len(data) * 1.5))
    digest = _seed_blob(cache, data)
    got, errors = [], []

    def slow_reader():
        try:
            with cache.hold(digest) as ok:
                assert ok
                time.sleep(0.05)                 # dawdle while evictions run
                got.append((cache.blob_dir / digest).read_bytes())
        except Exception as e:  # noqa: BLE001 — collected, not asserted here
            errors.append(repr(e))

    t = threading.Thread(target=slow_reader)
    t.start()
    deadline = time.time() + 2.0
    while t.is_alive() and time.time() < deadline:
        _seed_blob(cache, _npy_bytes(np.random.default_rng(
            int(time.time() * 1e6) % 2**32).normal(size=256)))
    t.join(timeout=5)
    assert errors == []
    assert got == [data]


def test_unpin_without_pin_is_harmless(tmp_path):
    cache = InputCache(tmp_path / "c")
    cache.unpin("never-pinned")
    with cache.hold("absent-digest") as ok:
        assert not ok


# ---------------------------------------------------------------------------
# placement.best_peers + WorkQueue.locate_blobs routing
# ---------------------------------------------------------------------------

def test_best_peers_ranks_holders_by_load_then_name():
    summaries = {"a": {"d1"}, "b": {"d1", "d2"}, "c": {"d2"}}
    assert best_peers("d1", ["a", "b", "c"], summaries) == ["a", "b"]
    assert best_peers("d1", ["a", "b"], summaries, load={"a": 5}) == ["b", "a"]
    assert best_peers("d1", ["a", "b"], summaries, limit=1) == ["a"]
    assert best_peers("dX", ["a", "b", "c"], summaries) == []
    assert best_peers("d1", ["a"], {"a": None}) == []


def _mini_units(dataset):
    _, units = _work(dataset)
    return units


def test_locate_blobs_routes_only_advertised_alive_non_self(dataset):
    units = _mini_units(dataset)
    q = WorkQueue(units, ["a", "b", "c"], lease_ttl_s=30.0)
    s = DigestSummary()
    s.add("deadbeef")
    q.register("a", summary={"v": 1, "full": s.to_wire()}, blob_addr="ha:1")
    q.register("b", summary={"v": 1, "full": s.to_wire()})   # no blob server
    q.register("c", blob_addr="hc:3")                        # no summary
    # only "a" both holds the digest and serves blobs
    assert q.locate_blobs(["deadbeef"]) == {"deadbeef": ["ha:1"]}
    # the requester never gets itself back
    assert q.locate_blobs(["deadbeef"], node_id="a") == {}
    # unknown digests are simply absent
    assert q.locate_blobs(["deadbeef", "bogus"])["deadbeef"] == ["ha:1"]
    st = q.stats_snapshot()
    assert st["fabric_nodes"] == ["a", "c"]
    assert st["fabric"]["locates"] == 3
    assert st["fabric"]["unlocated_digests"] >= 2
    # a dead node stops being a candidate immediately
    q.mark_dead("a")
    assert q.locate_blobs(["deadbeef"]) == {}
    assert q.stats_snapshot()["fabric_nodes"] == ["c"]


def test_locate_blobs_heartbeat_advertisement(dataset):
    units = _mini_units(dataset)
    q = WorkQueue(units, ["a", "b"], lease_ttl_s=30.0)
    s = DigestSummary()
    s.add("cafe")
    q.put_summary("a", {"v": 1, "full": s.to_wire()})
    assert q.locate_blobs(["cafe"], node_id="b") == {}       # not advertised
    q.heartbeat("a", blob_addr="ha:9")                       # late advert
    assert q.locate_blobs(["cafe"], node_id="b") == {"cafe": ["ha:9"]}


# ---------------------------------------------------------------------------
# PeerFabric: success + every failure mode falls back (returns None)
# ---------------------------------------------------------------------------

def test_fabric_fetch_verifies_and_falls_back(tmp_path):
    cache = InputCache(tmp_path / "serve")
    data = _npy_bytes(np.arange(16, dtype=np.float32))
    digest = _seed_blob(cache, data)
    with BlobServer(cache) as srv:
        # success: ranked candidates, first is dead, second works
        fab = PeerFabric(lambda ds: {digest: ["127.0.0.1:1", srv.addr_str]},
                         timeout_s=2.0)
        assert fab.fetch(digest) == (data, srv.addr_str)
        assert fab.counters()["peer_dead"] == 1
        # false positive: peer 404s
        fab2 = PeerFabric(lambda ds: {d: [srv.addr_str] for d in ds})
        assert fab2.fetch("f" * 64) is None
        assert fab2.counters()["peer_false_positives"] == 1
        # digest mismatch: peer serves bytes that hash to something else
        # (blob stored under a name its content doesn't hash to — the shape
        # a corrupted body or lying peer presents on the wire)
        fab3 = PeerFabric(lambda ds: {d: [srv.addr_str] for d in ds},
                          timeout_s=2.0)
        wrong = hashlib.sha256(b"other").hexdigest()
        cache._insert_blob(wrong, data, None)
        assert fab3.fetch(wrong) is None
        assert fab3.counters()["peer_digest_mismatches"] == 1
        # self-exclusion: own addr is never dialed
        fab4 = PeerFabric(lambda ds: {d: [srv.addr_str] for d in ds},
                          self_addr=srv.addr_str)
        assert fab4.fetch(digest) is None


def test_fabric_disables_itself_on_unknown_method():
    calls = []

    def locate(ds):
        calls.append(ds)
        raise RuntimeError("queue rpc locate_blobs: unknown method")

    fab = PeerFabric(locate)
    assert fab.fetch("d1") is None
    assert fab.fetch("d2") is None               # no second locate attempt
    assert calls == [["d1"]]


def test_fabric_counts_locate_failures():
    def locate(ds):
        raise ConnectionError("coordinator gone")

    fab = PeerFabric(locate)
    assert fab.fetch("d1") is None
    assert fab.counters()["peer_locate_failures"] == 1


# ---------------------------------------------------------------------------
# cache + fabric integration: fetch_array origins, counters, freshness guard
# ---------------------------------------------------------------------------

def _two_caches_one_warm(tmp_path, src_arr):
    warm = InputCache(tmp_path / "warm")
    cold = InputCache(tmp_path / "cold")
    src = tmp_path / "input.npy"
    np.save(src, src_arr)
    _, digest, origin, *_ = warm.fetch_array(src)
    assert origin == "storage"
    return warm, cold, src, digest


def test_fetch_array_peer_origin_and_counters(tmp_path):
    arr = np.arange(128, dtype=np.float64)
    warm, cold, src, digest = _two_caches_one_warm(tmp_path, arr)
    with BlobServer(warm) as srv:
        cold.attach_fabric(PeerFabric(
            lambda ds: {d: [srv.addr_str] for d in ds}))
        got, d2, origin, nbytes, _ = cold.fetch_array(
            src, digest_hint=digest, size_hint=src.stat().st_size)
        assert origin == "peer" and d2 == digest
        assert np.array_equal(got, arr)
        st = cold.stats()
        assert st["peer_hits"] == 1 and st["bytes_from_peer"] == nbytes
        assert st["bytes_from_storage"] == 0
        assert st["peer_bytes_by_addr"] == {srv.addr_str: nbytes}
        assert warm.stats()["peer_serves"] == 1
        # the peer-fetched blob is now local: next fetch is a plain hit
        assert cold.fetch_array(src, digest_hint=digest)[2] == "cache"
        # provenance digests identical across origins
        assert warm.fetch_array(src)[1] == digest


def test_fetch_array_falls_back_to_storage_on_dead_peer(tmp_path):
    arr = np.arange(64, dtype=np.float32)
    _, cold, src, digest = _two_caches_one_warm(tmp_path, arr)
    cold.attach_fabric(PeerFabric(
        lambda ds: {d: ["127.0.0.1:1"] for d in ds}, timeout_s=1.0))
    got, d2, origin, *_ = cold.fetch_array(src, digest_hint=digest)
    assert origin == "storage" and d2 == digest
    assert np.array_equal(got, arr)
    st = cold.stats()
    assert st["peer_dead"] == 1 and st["bytes_from_storage"] > 0


def test_fetch_array_skips_peer_when_source_size_changed(tmp_path):
    """A source rewritten since the manifest scan must be read from storage
    (current bytes), not fetched content-addressed from a peer (old bytes)."""
    arr = np.arange(32, dtype=np.float64)
    warm, cold, src, digest = _two_caches_one_warm(tmp_path, arr)
    stale_size = src.stat().st_size
    np.save(src, np.arange(48, dtype=np.float64))     # rewritten: new size
    with BlobServer(warm) as srv:
        dialed = []

        def locate(ds):
            dialed.append(ds)
            return {d: [srv.addr_str] for d in ds}

        cold.attach_fabric(PeerFabric(locate))
        got, d2, origin, *_ = cold.fetch_array(src, digest_hint=digest,
                                               size_hint=stale_size)
        assert origin == "storage"
        assert dialed == []                           # peer path never tried
        assert d2 != digest                           # current content digest


def test_load_unit_inputs_stamps_peer_bytes(dataset, tmp_path):
    pipe, units = _work(dataset)
    warm = InputCache(tmp_path / "warm")
    load_unit_inputs(units[0], dataset.root, cache=warm)
    cold = InputCache(tmp_path / "cold")
    with BlobServer(warm) as srv:
        cold.attach_fabric(PeerFabric(
            lambda ds: {d: [srv.addr_str] for d in ds}))
        inputs, sums, cache_hit, hit_bytes, peer_bytes, _ = load_unit_inputs(
            units[0], dataset.root, cache=cold)
        assert cache_hit is False and hit_bytes == 0
        assert peer_bytes > 0
        assert cold.stats()["bytes_from_storage"] == 0
        # digests identical to a cache-less verify-load
        ref_inputs, ref_sums = {}, {}
        _, ref_sums, *_ = load_unit_inputs(units[0], dataset.root)
        assert sums == ref_sums


# ---------------------------------------------------------------------------
# end to end: ClusterRunner(peer_fabric=True)
# ---------------------------------------------------------------------------

def test_cluster_peer_fabric_end_to_end(dataset, tmp_path):
    """Warm one node's cache, then rerun cold siblings with the fabric on:
    units must complete ok with peer_fetch stamped in provenance, peer bytes
    in the stats, and strictly fewer storage bytes than the cold total."""
    pipe, units = _work(dataset)
    cache_root = tmp_path / "hostcaches"
    # pass 1: single node — everything lands in node-0's cache
    r1 = ClusterRunner(pipe, dataset.root, nodes=1, lease_ttl_s=10.0,
                       cache_dir=cache_root, cache_per_node=True,
                       peer_fabric=True)
    res1 = r1.run(units)
    assert all(r.status == "ok" for r in res1)
    cold_storage = r1.stats.cache["bytes_from_storage"]
    assert cold_storage > 0
    # wipe outputs so pass 2 recomputes (inputs stay put)
    import shutil
    shutil.rmtree(Path(dataset.root) / "derivatives")
    # pass 2: 3 nodes; node-0 warm, node-1/2 cold but fabric-connected
    r2 = ClusterRunner(pipe, dataset.root, nodes=3, lease_ttl_s=10.0,
                       cache_dir=cache_root, cache_per_node=True,
                       peer_fabric=True, partition="round_robin")
    res2 = r2.run(units)
    assert sum(r.status == "ok" for r in res2) == len(units)
    totals = r2.stats.cache
    assert totals["bytes_from_peer"] > 0
    assert totals["peer_hits"] > 0
    assert totals["bytes_from_storage"] < cold_storage
    assert r2.stats.peer_links                       # per-link meter populated
    assert r2.stats.fabric["locates"] > 0
    # provenance: at least one committed record stamps peer_fetch, and every
    # record's input digests match the manifest regardless of origin
    peer_stamped = 0
    for u in units:
        prov = Provenance.load(Path(u.out_dir))
        assert prov is not None and prov.status == "ok"
        peer_stamped += bool(prov.peer_fetch)
        if prov.peer_fetch:
            assert prov.bytes_from_peer > 0
    assert peer_stamped > 0
    assert sum(r.bytes_from_peer for r in res2) > 0


def test_cluster_peer_fabric_requires_per_node_caches(dataset):
    pipe, _ = _work(dataset)
    with pytest.raises(ValueError, match="peer_fabric"):
        ClusterRunner(pipe, dataset.root, peer_fabric=True)


def test_fabric_quarantines_dead_peer_then_retries_after_expiry(tmp_path):
    cache = InputCache(tmp_path / "serve")
    data = _npy_bytes(np.arange(16, dtype=np.float32))
    digest = _seed_blob(cache, data)
    with BlobServer(cache) as srv:
        dead = "127.0.0.1:1"
        fab = PeerFabric(lambda ds: {d: [dead, srv.addr_str] for d in ds},
                         timeout_s=2.0, quarantine_s=0.3)
        # first fetch pays the doomed dial once and quarantines the addr
        assert fab.fetch(digest) == (data, srv.addr_str)
        assert fab.counters()["peer_dead"] == 1
        # inside the window: the breaker skips the dial entirely
        assert fab.fetch(digest) == (data, srv.addr_str)
        assert fab.fetch(digest) == (data, srv.addr_str)
        c = fab.counters()
        assert c["peer_dead"] == 1 and c["peer_quarantine_skips"] == 2
        # after expiry: one half-open probe re-dials (and re-quarantines)
        time.sleep(0.35)
        assert fab.fetch(digest) == (data, srv.addr_str)
        c = fab.counters()
        assert c["peer_dead"] == 2 and c["peer_quarantine_skips"] == 2
        fab.close()


def test_fabric_quarantine_disabled_with_nonpositive_window(tmp_path):
    cache = InputCache(tmp_path / "serve")
    data = _npy_bytes(np.arange(8, dtype=np.float32))
    digest = _seed_blob(cache, data)
    with BlobServer(cache) as srv:
        fab = PeerFabric(lambda ds: {d: ["127.0.0.1:1", srv.addr_str]
                                     for d in ds},
                         timeout_s=2.0, quarantine_s=0)
        for want_dead in (1, 2):         # every fetch re-dials the dead peer
            assert fab.fetch(digest) == (data, srv.addr_str)
            assert fab.counters()["peer_dead"] == want_dead
        assert fab.counters()["peer_quarantine_skips"] == 0
        fab.close()
