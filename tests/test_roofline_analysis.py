"""Roofline machinery: analytic flops sanity, hardware terms, report loading."""
import numpy as np
import pytest

from repro.analysis.flops import step_flops, step_hbm_bytes
from repro.analysis.roofline import HW, model_flops, roofline_terms
from repro.configs import SHAPE_BY_NAME, get_config


def test_analytic_flops_close_to_6nd():
    """For dense training, implemented FLOPs should be within ~2.5x of 6·N·D
    (remat + attention + loss overheads), never below it."""
    for arch in ("llama3.2-1b", "glm4-9b", "granite-34b"):
        cfg = get_config(arch)
        shape = SHAPE_BY_NAME["train_4k"]
        fl = step_flops(cfg, shape, "train")["total"]
        mf = model_flops(cfg, shape, "train")
        assert mf <= fl < 3.0 * mf, (arch, fl / mf)


def test_moe_flops_use_active_params():
    cfg = get_config("llama4-scout-17b-a16e")
    shape = SHAPE_BY_NAME["train_4k"]
    mf = model_flops(cfg, shape, "train")
    dense_equiv = 6 * cfg.n_params() * shape.global_batch * shape.seq_len
    assert mf < 0.2 * dense_equiv          # top-1 of 16 experts


def test_decode_flops_tiny_vs_prefill():
    cfg = get_config("glm4-9b")
    pf = step_flops(cfg, SHAPE_BY_NAME["prefill_32k"], "prefill")["total"]
    dc = step_flops(cfg, SHAPE_BY_NAME["decode_32k"], "decode")["total"]
    assert dc < pf / 100


def test_roofline_terms_pick_dominant():
    t = roofline_terms(197e12, 0.0, 0.0)
    assert t["bound"] == "compute" and abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(0.0, 0.0, 50e9)
    assert t["bound"] == "collective" and abs(t["collective_s"] - 1.0) < 1e-9


def test_hbm_bytes_decode_dominated_by_cache_or_weights():
    cfg = get_config("granite-34b")
    b = step_hbm_bytes(cfg, SHAPE_BY_NAME["decode_32k"], "decode", 256, 16)
    # MQA cache ~188 GB over 256 chips + weights/16
    assert 1e9 < b < 2e10


def test_swa_decode_cheaper_than_full():
    full = step_hbm_bytes(get_config("glm4-9b"), SHAPE_BY_NAME["decode_32k"],
                          "decode", 256, 16)
    cfgd = get_config("h2o-danube-1.8b")
    swa = step_hbm_bytes(cfgd, SHAPE_BY_NAME["decode_32k"], "decode", 256, 16)
    assert swa < full


def test_dryrun_records_exist_and_parse():
    from repro.analysis.report import load_records
    recs = load_records("single")
    if not recs:
        pytest.skip("no dryrun records generated yet "
                    "(python -m repro.launch.dryrun --all)")
    assert len(recs) >= 40
    done = [r for r in recs if "roofline" in r]
    assert len(done) >= 33
    for r in done:
        assert r["roofline"]["step_s_lower_bound"] >= 0
        assert r["n_chips"] == 256


def test_every_committed_dryrun_record_parses():
    """Regression guard over the committed experiments/dryrun/ tree: every
    record (any mesh/tag) must be valid JSON with a coherent schema — a full
    record with roofline/memory/collectives, or an explicit skip. No failed
    cells may be committed."""
    import json
    from repro.analysis.report import DRYRUN_DIR
    paths = sorted(DRYRUN_DIR.glob("*.json"))
    assert len(paths) >= 40, "committed dryrun sweep went missing"
    for p in paths:
        r = json.loads(p.read_text())
        assert {"arch", "shape"} <= set(r), p.name
        assert "error" not in r, f"{p.name} committed a failed cell: {r}"
        if "skipped" in r:
            continue
        assert r["n_chips"] == 256, p.name
        t = r["roofline"]
        assert t["bound"] in ("compute", "memory", "collective")
        assert t["step_s_lower_bound"] == pytest.approx(
            max(t["compute_s"], t["memory_s"], t["collective_s"]))
        assert r["flops_per_chip"] > 0 and r["hbm_per_chip_gb"] >= 0
        assert set(r["collectives"]) >= {"weighted_bytes", "per_op"}
        assert r["memory"].get("peak_est_bytes", 0) >= 0
        # the roofline table renderer must accept every committed record
    from repro.analysis.report import roofline_table
    table = roofline_table("single")
    assert table.count("\n") >= 40
