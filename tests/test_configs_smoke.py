"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement). Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, ARCH_IDS, SHAPES, cell_is_runnable
from repro.models import (init_params, forward_train, forward_prefill,
                          forward_decode, init_cache)


def _batch(cfg, key, B=2, S=64):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.vlm is not None:
        P = cfg.vlm.n_patches
        batch["tokens"] = batch["tokens"][:, :S - P]
        batch["embeds"] = jax.random.normal(key, (B, P, cfg.d_model))
        batch["targets"] = batch["targets"].at[:, :P].set(-100)
    if cfg.encoder is not None:
        batch["enc_embeds"] = jax.random.normal(key, (B, cfg.encoder.enc_seq,
                                                      cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(lambda p, b: forward_train(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    assert np.isfinite(float(metrics["acc"]))


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    batch.pop("targets")
    logits, cache = jax.jit(lambda p, b: forward_prefill(cfg, p, b))(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    dc = init_cache(cfg, B, 128)
    lg, dc2 = jax.jit(lambda p, c, t: forward_decode(cfg, p, c, t, jnp.int32(0)))(
        params, dc, batch["tokens"][:, :1])
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg, np.float32))), arch
    assert jax.tree.structure(dc) == jax.tree.structure(dc2)


def test_all_archs_present():
    assert len(ARCH_IDS) == 10
    assert len(SHAPES) == 4


def test_cell_runnability_matrix():
    runnable = {(a, s.name): cell_is_runnable(get_config(a), s)[0]
                for a in ARCH_IDS for s in SHAPES}
    assert sum(runnable.values()) == 33          # 40 cells - 7 long_500k skips
    skipped = [k for k, v in runnable.items() if not v]
    assert all(s == "long_500k" for _, s in skipped)


def test_config_digests_stable():
    d1 = get_config("glm4-9b").digest()
    d2 = get_config("glm4-9b").digest()
    assert d1 == d2
    assert d1 != get_config("llama3.2-1b").digest()


def test_param_counts_plausible():
    # published ballparks (active params)
    assert 8e9 < get_config("glm4-9b").n_params() < 11e9
    assert 1.0e9 < get_config("llama3.2-1b").n_params() < 1.6e9
    assert 9e9 < get_config("llama4-scout-17b-a16e").n_active_params() < 20e9
    assert 2.5e9 < get_config("moonshot-v1-16b-a3b").n_active_params() < 6e9
