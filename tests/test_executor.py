"""Parallel executor data plane: concurrent idempotency, retries under
parallelism, straggler accounting, single-pass integrity primitives, and the
fused QA+checksum Pallas kernel vs its numpy oracle."""
import dataclasses
import os
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import (LocalRunner, builtin_pipelines, dedupe_results,
                        fletcher64, fletcher64_file, is_complete,
                        query_available_work, sha256_file, sha256_load_array,
                        sha256_save_array, synthesize_dataset, verified_copy)
from repro.core import integrity as integrity_mod
from repro.core.integrity import IntegrityError
from repro.core.query import WorkUnit
from repro.core.workflow import UnitResult


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path, "exds", n_subjects=4,
                              sessions_per_subject=2, shape=(12, 12, 12))


def _work(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    return pipe, units


# ---------------------------------------------------------------------------
# parallel executor
# ---------------------------------------------------------------------------

def test_parallel_runner_completes_all_units(dataset):
    pipe, units = _work(dataset)
    results = LocalRunner(pipe, dataset.root, workers=4).run(units)
    assert len(results) >= len(units)
    ok = [r for r in results if r.status == "ok"]
    assert len(ok) == len(units) == 8
    for u in units:
        assert is_complete(Path(u.out_dir), pipe.digest())
    # idempotent: re-query finds nothing
    work2, _ = query_available_work(dataset, pipe)
    assert work2 == []


def test_concurrent_idempotency_exactly_one_commit(dataset):
    """Two workers racing the SAME unit: both compute, exactly one commits."""
    pipe, units = _work(dataset)
    unit = units[0]
    barrier = threading.Barrier(2)

    def rendezvous(u, attempt):
        # hold both workers past the is_complete fast path so they genuinely
        # race the commit; fall through if the runner serialized them
        try:
            barrier.wait(timeout=2)
        except threading.BrokenBarrierError:
            pass

    runner = LocalRunner(pipe, dataset.root, workers=2, fault_hook=rendezvous)
    results = runner.run([unit, unit])
    statuses = sorted(r.status for r in results)
    assert statuses == ["ok", "skipped"]
    assert is_complete(Path(unit.out_dir), pipe.digest())
    # exactly one committed ok-provenance on disk
    provs = list(Path(unit.out_dir).glob("provenance.json*"))
    assert len(provs) == 1


def test_fault_hook_retries_under_parallelism(dataset):
    pipe, units = _work(dataset)
    lock = threading.Lock()
    fails = {"n": 0}

    def flaky(unit, attempt):
        if attempt == 1:
            with lock:
                fails["n"] += 1
            raise RuntimeError("injected node failure")

    runner = LocalRunner(pipe, dataset.root, workers=4, max_retries=2,
                         fault_hook=flaky)
    results = runner.run(units)
    ok = [r for r in results if r.status == "ok"]
    assert len(ok) == len(units)
    assert fails["n"] == len(units)
    assert all(r.attempts == 2 for r in ok)


def _fake_unit(tag="u1"):
    return WorkUnit(dataset="d", subject=tag, session="01", pipeline="p",
                    pipeline_digest="x", inputs={}, out_dir=f"/tmp/{tag}")


def test_dedupe_results_marks_speculative_and_keeps_one_ok():
    u = _fake_unit()
    prim = [UnitResult(u, "ok", 1.0, 1)]
    spec = [(0, UnitResult(u, "skipped", 0.2, 3))]
    out = dedupe_results(prim, spec)
    assert [r.status for r in out] == ["ok", "speculative"]

    # speculative twin won the race: primary slot absorbs the committed run
    prim = [UnitResult(u, "skipped", 1.5, 1)]
    spec = [(0, UnitResult(u, "ok", 0.2, 3))]
    out = dedupe_results(prim, spec)
    assert [r.status for r in out] == ["ok", "speculative"]
    assert sum(r.status == "ok" for r in out) == 1


def test_straggler_speculation_end_to_end(dataset):
    """A unit sleeping far past the median gets a speculative twin; counts
    stay exact: one ok per unit, duplicates reported as 'speculative'."""
    pipe, units = _work(dataset)
    slow_id = units[0].job_id
    slept = {"n": 0}
    lock = threading.Lock()

    def slow_hook(u, attempt):
        if u.job_id == slow_id:
            with lock:
                first = slept["n"] == 0
                slept["n"] += 1
            if first:
                time.sleep(1.2)

    runner = LocalRunner(pipe, dataset.root, workers=2, fault_hook=slow_hook,
                         straggler_factor=1.5, straggler_min_s=0.15)
    results = runner.run(units)
    by_status = {s: sum(r.status == s for r in results)
                 for s in ("ok", "speculative", "failed")}
    assert by_status["ok"] == len(units)
    assert by_status["failed"] == 0
    ok_ids = [r.unit.job_id for r in results if r.status == "ok"]
    assert len(ok_ids) == len(set(ok_ids))    # no double-counted unit


# ---------------------------------------------------------------------------
# single-pass integrity
# ---------------------------------------------------------------------------

def _counting_open(monkeypatch, counters):
    real_open = open

    def counting(path, mode="r", *a, **k):
        p = str(path)
        if "r" in mode and "w" not in mode:
            counters[p] = counters.get(p, 0) + 1
        return real_open(path, mode, *a, **k)

    monkeypatch.setattr(integrity_mod, "open", counting, raising=False)


def test_verified_copy_reads_source_exactly_once(tmp_path, monkeypatch):
    src = tmp_path / "src.bin"
    src.write_bytes(os.urandom(1 << 16) * 3)
    dst = tmp_path / "out" / "dst.bin"
    counters = {}
    _counting_open(monkeypatch, counters)
    digest = verified_copy(src, dst)
    assert counters == {str(src): 1}          # ONE source read, no dst read
    assert dst.read_bytes() == src.read_bytes()
    assert digest == sha256_file(src)


def test_verified_copy_paranoid_rereads_destination_once(tmp_path, monkeypatch):
    src = tmp_path / "src.bin"
    src.write_bytes(os.urandom(4096))
    dst = tmp_path / "dst.bin"
    counters = {}
    _counting_open(monkeypatch, counters)
    verified_copy(src, dst, paranoid=True)
    assert counters[str(src)] == 1
    reread = {p: n for p, n in counters.items() if p != str(src)}
    assert sum(reread.values()) == 1          # exactly one verify read


def test_verified_copy_paranoid_detects_corruption(tmp_path, monkeypatch):
    src = tmp_path / "src.bin"
    src.write_bytes(os.urandom(8192))
    dst = tmp_path / "dst.bin"
    real_open = open

    class CorruptReads:
        def __init__(self, f):
            self.f = f

        def read(self, n=-1):
            b = self.f.read(n)
            return (bytes([b[0] ^ 1]) + b[1:]) if b else b

        def __enter__(self):
            return self

        def __exit__(self, *a):
            self.f.close()

    def flipping(path, mode="r", *a, **k):
        f = real_open(path, mode, *a, **k)
        if ".tmp-" in str(path) and "r" in mode:   # the verify read-back
            return CorruptReads(f)
        return f

    monkeypatch.setattr(integrity_mod, "open", flipping, raising=False)
    with pytest.raises(IntegrityError):
        verified_copy(src, dst, paranoid=True)
    assert not dst.exists()
    assert not list(tmp_path.glob("*.tmp-*"))      # temp file cleaned up


@pytest.mark.parametrize("size", [0, 1, 3, 4, 1023, 4096 + 5, (1 << 16) + 7])
def test_fletcher64_file_chunked_matches_one_shot(tmp_path, size):
    data = np.random.default_rng(size).integers(
        0, 256, size, dtype=np.uint8).tobytes()
    p = tmp_path / "f.bin"
    p.write_bytes(data)
    want = fletcher64(data)
    assert fletcher64_file(p) == want
    assert fletcher64_file(p, chunk=1031) == want   # odd chunk: tail carry
    assert fletcher64_file(p, chunk=4) == want


def test_sha256_save_load_array_single_pass_roundtrip(tmp_path):
    arr = np.random.default_rng(0).normal(size=(17, 9)).astype(np.float32)
    p = tmp_path / "a.npy"
    d_saved = sha256_save_array(p, arr)
    assert d_saved == sha256_file(p)
    loaded, d_loaded = sha256_load_array(p)
    assert d_loaded == d_saved
    assert np.array_equal(loaded, arr)


# ---------------------------------------------------------------------------
# fused QA + checksum kernel
# ---------------------------------------------------------------------------

import jax
import jax.numpy as jnp

from repro.kernels.checksum import (device_checksum, qa_checksum,
                                    qa_checksum_batched,
                                    qa_checksum_batched_ref, qa_checksum_ref,
                                    qa_stats)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape,dtype", [
    ((16, 16, 16), jnp.float32), ((33, 7), jnp.float32), ((1,), jnp.float32),
    ((129,), jnp.bfloat16), ((1000,), jnp.float16), ((77,), jnp.int8),
    ((5,), jnp.int32),
])
def test_qa_checksum_bit_exact_vs_ref(shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        x = jax.random.randint(KEY, shape, -100, 100).astype(dtype)
    else:
        x = (jax.random.normal(KEY, shape, jnp.float32) * 50).astype(dtype)
    got = qa_checksum(x, interpret=True)
    ref = qa_checksum_ref(np.asarray(x))
    for a, b in zip(got, ref):
        a = np.asarray(a)
        assert a.dtype == b.dtype
        assert np.array_equal(a, b), (a, b)
    # fused checksum words == the plain transfer checksum kernel
    assert np.array_equal(np.asarray(got[0]),
                          np.asarray(device_checksum(x, interpret=True)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qa_checksum_batched_matches_ref_and_rows(dtype):
    vols = (jax.random.normal(KEY, (5, 12, 12, 12), jnp.float32) * 40 + 100
            ).astype(dtype)
    vols = vols.at[3, 0, 0, 0].set(jnp.nan)
    got = qa_checksum_batched(vols, interpret=True)
    ref = qa_checksum_batched_ref(np.asarray(vols))
    for a, b in zip(got, ref):
        assert np.array_equal(np.asarray(a), b, equal_nan=True)
    # each batched row == the unbatched kernel on that volume
    for i in range(vols.shape[0]):
        s, q, c = qa_checksum(vols[i], interpret=True)
        assert np.array_equal(np.asarray(s), np.asarray(got[0][i]))
        assert np.array_equal(np.asarray(q), np.asarray(got[1][i]))
        assert np.array_equal(np.asarray(c), np.asarray(got[2][i]))


@pytest.mark.parametrize("shape,dtype", [
    ((2, 27), jnp.int16),      # row bytes not word-aligned: per-row padding
    ((2, 3), jnp.int8),
    ((3, 5, 5), jnp.bfloat16),
])
def test_qa_checksum_batched_subword_rows_match_unbatched(shape, dtype):
    """Rows whose byte extent is not a multiple of 4 must pad per-row, never
    letting checksum words straddle volume boundaries."""
    x = jax.random.randint(KEY, shape, -100, 100).astype(dtype)
    got = qa_checksum_batched(x, interpret=True)
    ref = qa_checksum_batched_ref(np.asarray(x))
    for a, b in zip(got, ref):
        assert np.array_equal(np.asarray(a), b), (np.asarray(a), b)
    for i in range(shape[0]):
        s, q, c = qa_checksum(x[i], interpret=True)
        assert np.array_equal(np.asarray(s), np.asarray(got[0][i]))


def test_qa_checksum_detects_corruption_and_counts_nonfinite():
    x = jax.random.normal(KEY, (256,))
    a = np.asarray(qa_checksum(x, interpret=True)[0])
    xc = np.asarray(x).copy()
    xc[17] += 1e-3
    b = np.asarray(qa_checksum(jnp.asarray(xc), interpret=True)[0])
    assert not np.array_equal(a, b)

    xn = np.asarray(x).copy()
    xn[3] = np.nan
    xn[200] = np.inf
    st = qa_stats(jnp.asarray(xn), interpret=True)
    assert st.finite_count == 254
    assert st.vmin <= st.vmax
    assert np.isfinite(st.vsum)


def test_ingest_device_qa_parity(tmp_path):
    from repro.core.ingest import ingest_directory, write_raw_dump
    rng = np.random.default_rng(0)
    d = tmp_path / "raw"
    good = rng.normal(100, 20, (16, 16, 16)).astype(np.float32)
    write_raw_dump(d / "a.npz", good, subject="001", session="01",
                   protocol="T1w")
    bad = good.copy()
    bad[0, 0, 0] = np.nan
    write_raw_dump(d / "b.npz", bad, subject="002", session="01",
                   protocol="T1w")
    write_raw_dump(d / "c.npz", np.ones((16, 16, 16), np.float32),
                   subject="003", session="01", protocol="T1w")

    _, rec_np = ingest_directory(d, tmp_path / "b1", "s", device_qa=False)
    _, rec_dev = ingest_directory(d, tmp_path / "b2", "s", device_qa=True)
    assert [(r.source, r.status) for r in rec_np] == \
        [(r.source, r.status) for r in rec_dev]
    by = {r.source: r for r in rec_dev}
    assert by["a.npz"].status == "ok" and len(by["a.npz"].checksum) == 16
    assert by["b.npz"].reason == "non-finite voxels"
    assert by["c.npz"].reason == "constant image"
